import os
import sys

# Make `repro` (src layout) and `benchmarks` importable regardless of how
# pytest is invoked. NOTE: no XLA_FLAGS here — tests must see 1 device;
# only launch/dryrun.py and benchmarks/probes.py force 512 fake devices
# (in their own processes).
_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
