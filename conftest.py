import os
import sys

# Make `repro` (src layout) and `benchmarks` importable regardless of how
# pytest is invoked. NOTE: no XLA_FLAGS here — tests must see 1 device;
# only launch/dryrun.py and benchmarks/probes.py force 512 fake devices
# (in their own processes).
_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

import pytest

# ---------------------------------------------------------------------------
# Hang guard for the concurrency lanes (`wallclock` and `proc` markers).
#
# A deadlocked thread rendezvous or a wedged worker-process handshake must
# fail its OWN test within REPRO_TEST_TIMEOUT seconds — not stall the lane
# until CI's 45-minute job limit kills the whole matrix cell with no junit
# output. When pytest-timeout is installed (requirements-ci.txt) each
# wallclock/proc test gets a timeout marker; the plugin dumps stacks of
# every thread and fails just that test. Locally, where installing it may
# not be possible, a daemon-timer fallback does the same thing the blunt
# way: faulthandler traceback to stderr, then hard process exit (a hung
# spawn-based child pool cannot be recovered from in-process anyway).
# ---------------------------------------------------------------------------

_GUARDED_MARKERS = ("wallclock", "proc")
_DEFAULT_TIMEOUT = float(os.environ.get("REPRO_TEST_TIMEOUT", "180"))


def _needs_guard(item):
    return any(item.get_closest_marker(m) is not None
               for m in _GUARDED_MARKERS)


def pytest_collection_modifyitems(config, items):
    if not config.pluginmanager.hasplugin("timeout"):
        return
    for item in items:
        if _needs_guard(item) and item.get_closest_marker("timeout") is None:
            # method thread: kills the test, not the process — worker
            # process/thread teardown still runs via the fixture finalizers
            item.add_marker(pytest.mark.timeout(_DEFAULT_TIMEOUT,
                                                method="thread"))


@pytest.fixture(autouse=True)
def _hang_guard_fallback(request):
    """Last-resort watchdog when pytest-timeout is unavailable locally."""
    if (request.config.pluginmanager.hasplugin("timeout")
            or not _needs_guard(request.node)):
        yield
        return
    import faulthandler
    import threading

    def _abort():
        sys.stderr.write(
            f"\n[conftest] hang guard: {request.node.nodeid} exceeded "
            f"{_DEFAULT_TIMEOUT:.0f}s (REPRO_TEST_TIMEOUT); dumping "
            f"stacks and aborting\n")
        faulthandler.dump_traceback(file=sys.stderr)
        sys.stderr.flush()
        os._exit(1)

    timer = threading.Timer(_DEFAULT_TIMEOUT, _abort)
    timer.daemon = True
    timer.start()
    try:
        yield
    finally:
        timer.cancel()
