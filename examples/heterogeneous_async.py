"""End-to-end driver: train a ~100M-class config (or the tiny default) for
a few hundred steps, comparing HeLoCo to the paper's baselines under a
chosen pace configuration. Demonstrates DyLU, compression, and stale-drop.

    PYTHONPATH=src python examples/heterogeneous_async.py \
        --paces 1,1,6,6,6 --methods async-heloco,async-mla --outer 30 \
        --engine wallclock
"""
import argparse

from benchmarks.common import METHODS, base_run, run_cached


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paces", default="0.74,1.5,3,6,7.5")
    ap.add_argument("--methods", default="async-heloco,async-mla,"
                                         "async-nesterov,sync-nesterov")
    ap.add_argument("--outer", type=int, default=30)
    ap.add_argument("--inner", type=int, default=8)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--dylu", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--drop-stale-after", type=int, default=None)
    ap.add_argument("--engine", default="sim", choices=["sim", "wallclock"],
                    help="wallclock = threaded concurrent runtime "
                         "(deterministic mode: same results, real overlap)")
    args = ap.parse_args()

    paces = tuple(float(p) for p in args.paces.split(","))
    print(f"paces={paces} non_iid={not args.iid} dylu={args.dylu} "
          f"compression={args.compression} engine={args.engine}")
    print("method,final_loss,mean_staleness,sim_time_s,comm_MB")
    for method in args.methods.split(","):
        rc = base_run(paces, method=method, non_iid=not args.iid,
                      outer_steps=args.outer, inner_steps=args.inner,
                      dylu=args.dylu, compression=args.compression,
                      drop_stale_after=args.drop_stale_after)
        r = run_cached(f"example_{method}", rc, engine=args.engine)
        tau = sum(r["staleness"]) / max(len(r["staleness"]), 1)
        print(f"{method},{r['final_loss']:.4f},{tau:.2f},"
              f"{r['final_time']:.0f},{r['comm_bytes'] / 1e6:.1f}")


if __name__ == "__main__":
    main()
