"""End-to-end driver: train a ~100M-class config (or the tiny default) for
a few hundred steps, comparing HeLoCo to the paper's baselines under a
chosen pace configuration. Demonstrates DyLU, compression, stale-drop,
and Dirichlet language mixtures. Runs are described as
``repro.scenarios`` specs — the same source of truth as the launcher and
the golden-trace CI gate; ``--scenario NAME`` replays a registered one.

    PYTHONPATH=src python examples/heterogeneous_async.py \
        --paces 1,1,6,6,6 --methods async-heloco,async-mla --outer 30 \
        --engine wallclock
    PYTHONPATH=src python examples/heterogeneous_async.py \
        --scenario paper_hetero_severe
"""
import argparse

from benchmarks.common import METHODS, run_cached_scenario, scenario_for
from repro.scenarios import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="",
                    help="replay a registered scenario instead of the "
                         "ad-hoc flags below")
    ap.add_argument("--paces", default="0.74,1.5,3,6,7.5")
    ap.add_argument("--methods", default="async-heloco,async-mla,"
                                         "async-nesterov,sync-nesterov")
    ap.add_argument("--outer", type=int, default=30)
    ap.add_argument("--inner", type=int, default=8)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--mixture-alpha", type=float, default=None)
    ap.add_argument("--dylu", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--drop-stale-after", type=int, default=None)
    ap.add_argument("--engine", default="sim", choices=["sim", "wallclock"],
                    help="wallclock = threaded concurrent runtime "
                         "(deterministic mode: same results, real overlap)")
    args = ap.parse_args()

    if args.scenario:
        scn = registry.get_scenario(args.scenario)
        print(f"scenario {scn.name}: {scn.description}")
        eng = scn.build()
        hist = eng.run()
        taus = [a["staleness"] for a in hist.arrivals] or [0]
        print(f"arrivals={len(hist.arrivals)} tokens={hist.tokens} "
              f"mean_staleness={sum(taus) / len(taus):.2f} "
              f"sim_time={hist.final_time:.0f}s")
        return

    paces = tuple(float(p) for p in args.paces.split(","))
    print(f"paces={paces} non_iid={not args.iid} dylu={args.dylu} "
          f"compression={args.compression} engine={args.engine}")
    print("method,final_loss,mean_staleness,sim_time_s,comm_MB")
    for method in args.methods.split(","):
        assert method in METHODS, method
        scn = scenario_for(paces, method=method, non_iid=not args.iid,
                           outer_steps=args.outer, inner_steps=args.inner,
                           dylu=args.dylu, compression=args.compression,
                           drop_stale_after=args.drop_stale_after,
                           mixture_alpha=args.mixture_alpha,
                           engine=args.engine)
        r = run_cached_scenario(f"example_{method}", scn)
        tau = sum(r["staleness"]) / max(len(r["staleness"]), 1)
        print(f"{method},{r['final_loss']:.4f},{tau:.2f},"
              f"{r['final_time']:.0f},{r['comm_bytes'] / 1e6:.1f}")


if __name__ == "__main__":
    main()
