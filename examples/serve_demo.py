"""Serving demo: prefill a batch of prompts and decode with the KV-cache
serving path (the same prefill/decode step functions the dry-run lowers at
32k/500k context on the production mesh).

    PYTHONPATH=src python examples/serve_demo.py --arch qwen2-7b-smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = args.prompt_len + args.gen

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    batch = {"tokens": prompts, "labels": prompts}
    if cfg.frontend.kind == "vision":
        npfx = cfg.frontend.n_prefix_tokens
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, npfx, cfg.d_model))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len=cache_len))
    decode = jax.jit(model.decode)

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    pos0 = args.prompt_len + (cfg.frontend.n_prefix_tokens
                              if cfg.frontend.kind == "vision" else 0)
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, 1)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")
    print(f"decode: {t_decode * 1e3:.1f} ms total, "
          f"{t_decode / (args.gen - 1) * 1e3:.2f} ms/token, "
          f"{args.batch * (args.gen - 1) / t_decode:.0f} tok/s")
    print("sample generations (token ids):")
    for row in gen[:2]:
        print("  ", row[:16], "...")


if __name__ == "__main__":
    main()
