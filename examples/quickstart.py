"""Quickstart: asynchronous HeLoCo training with 5 heterogeneous workers
on non-IID synthetic multilingual data (the paper's Fig. 2 setting, tiny).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config, reduced
from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
from repro.async_engine.simulator import AsyncSimulator, make_eval_fn


def main():
    run = RunConfig(
        model=reduced(get_config("tinygpt-15m")),
        inner=InnerOptConfig(lr=3e-3, warmup_steps=5, total_steps=400),
        outer=OuterOptConfig(method="heloco"),      # paper defaults (Table 3)
        n_workers=5,
        inner_steps=8,                              # H local steps per round
        outer_steps=30,
        batch_size=4,
        seq_len=64,
        worker_paces=(0.74, 1.5, 3.0, 6.0, 7.5),    # heterogeneous (sec/step)
        non_iid=True,
    )
    sim = AsyncSimulator(run)
    hist = sim.run(eval_every=6, eval_fn=make_eval_fn(sim, batch=8))

    print(f"\narrivals={len(hist.arrivals)} tokens={hist.tokens} "
          f"sim_time={hist.final_time:.0f}s")
    print("step  time(s)  mean-loss  per-language")
    for e in hist.evals:
        langs = " ".join(f"{k}:{v:.2f}" for k, v in e["per_lang"].items())
        print(f"{e['step']:4d}  {e['time']:7.0f}  {e['mean']:9.4f}  {langs}")
    taus = [a["staleness"] for a in hist.arrivals]
    print(f"staleness: mean={sum(taus)/len(taus):.2f} max={max(taus)}")


if __name__ == "__main__":
    main()
