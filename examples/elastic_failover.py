"""Fault-tolerance demo: a worker crashes mid-round (in-flight work lost),
rejoins later; another worker joins elastically; server checkpoints every
few outer steps and training restarts from the latest checkpoint.

    PYTHONPATH=src python examples/elastic_failover.py
"""
import os
import tempfile

from repro.configs import get_config, reduced
from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
from repro.async_engine.simulator import (
    AsyncSimulator, ElasticEvent, FailureEvent, make_eval_fn,
)
from repro.checkpoint import ckpt


def main():
    rc = RunConfig(
        model=reduced(get_config("tinygpt-15m")),
        inner=InnerOptConfig(lr=3e-3, warmup_steps=4, total_steps=400),
        outer=OuterOptConfig(method="heloco"),
        n_workers=4, inner_steps=6, outer_steps=24,
        batch_size=4, seq_len=64,
        worker_paces=(1.0, 2.0, 4.0, 8.0), non_iid=True)

    ckpt_dir = tempfile.mkdtemp(prefix="heloco_ckpt_")
    failures = [FailureEvent(time=20.0, wid=1, restart_delay=30.0)]
    elastic = [ElasticEvent(time=40.0, action="join", wid=9, pace=1.5, lang=2)]

    sim = AsyncSimulator(rc, failures=failures, elastic=elastic)
    eval_fn = make_eval_fn(sim, batch=8)
    hist = sim.run(eval_every=6, eval_fn=eval_fn, ckpt_every=6,
                   ckpt_dir=ckpt_dir)

    print("events observed:")
    w1 = [a for a in hist.arrivals if a["worker_id"] == 1]
    w9 = [a for a in hist.arrivals if a["worker_id"] == 9]
    print(f"  worker 1 crash at t=20, rejoin at t=50: "
          f"{len(w1)} arrivals (latest at t={max(a['sim_time'] for a in w1):.0f})")
    print(f"  worker 9 joined at t=40: {len(w9)} arrivals")
    print(f"  final loss: {hist.evals[-1]['mean']:.4f}")

    latest = ckpt.latest(ckpt_dir)
    print(f"\nrestarting from checkpoint {os.path.basename(latest)} ...")
    sim2 = AsyncSimulator(rc)
    sim2.restore(latest)
    print(f"  restored outer step {sim2.server.t}, sim time {sim2.time:.0f}s")
    sim2.cfg = RunConfig(**{**rc.__dict__, "outer_steps": sim2.server.t + 6})
    hist2 = sim2.run(eval_every=3, eval_fn=make_eval_fn(sim2, batch=8))
    print(f"  continued to step {sim2.server.t}; "
          f"loss {hist2.evals[-1]['mean']:.4f}")


if __name__ == "__main__":
    main()
