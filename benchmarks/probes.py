import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ before any jax import (same device-count discipline as dryrun).

"""Roofline cost probes.

XLA's cost analysis counts while-loop bodies ONCE, so the production
(scanned) compile underreports FLOPs/bytes by the trip counts. These
probes compile the same step functions with every loop UNROLLED at
pattern-unit depths {1, 2}; differencing gives exact per-unit costs:

    unit  = probe(depth=2) - probe(depth=1)
    fixed = probe(depth=1) - unit            (embed + loss + optimizer-fixed)
    total = accum * (grad_fixed + L * grad_unit) + opt_fixed + L * opt_unit

Train cells probe both the full train step and the grad-only step so the
once-per-step optimizer cost is not multiplied by grad_accum. xLSTM's
sLSTM blocks contain an S-step recurrent scan that cannot be unrolled at
full sequence length; they are probed at S=256 and scaled linearly in S
(every sLSTM cost term is linear in sequence length), documented in
EXPERIMENTS.md SRoofline.
"""
import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import InnerOptConfig, ModelConfig, ShapeConfig, shape_applicable
from repro.dist import sharding as shd
from repro.dist.steps import init_train_state, make_train_step
from repro.launch.dryrun import plan_for, _state_shardings
from repro.launch.inputs import abstract_params, batch_specs_struct, input_specs
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.utils.hlo import (collective_stats, hbm_traffic_estimate,
                             total_wire_bytes)

INNER = InnerOptConfig()
METRICS = ("flops", "bytes", "bytes_fused", "wire")


def _probe_cfg(cfg: ModelConfig, units: int) -> ModelConfig:
    """Shrink the arch to `units` pattern units (full width)."""
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=cfg.shared_attn_every * units)
    if cfg.family == "ssm":
        return dataclasses.replace(
            cfg, n_layers=units,
            xlstm=dataclasses.replace(cfg.xlstm, slstm_at=()))
    return dataclasses.replace(cfg, n_layers=units, scan_layers=True)


def _units_of(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every
    if cfg.family == "ssm":
        return cfg.n_layers - len(cfg.xlstm.slstm_at)   # mLSTM units
    return cfg.n_layers


def _metrics(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = collective_stats(text)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "bytes_fused": hbm_traffic_estimate(text),
            "wire": total_wire_bytes(coll)}


def _diff(a: Dict[str, float], b: Dict[str, float]) -> Dict[str, float]:
    return {k: a[k] - b[k] for k in METRICS}


def _scale(a: Dict[str, float], s: float) -> Dict[str, float]:
    return {k: a[k] * s for k in METRICS}


def _add(a: Dict[str, float], b: Dict[str, float]) -> Dict[str, float]:
    return {k: a[k] + b[k] for k in METRICS}


# --------------------------------------------------------------------------
# Lowering helpers (single-pod mesh, unrolled)
# --------------------------------------------------------------------------

def _lower_train(cfg: ModelConfig, batch: int, seq: int, mesh, *,
                 q_chunk: int, grad_only: bool,
                 attn_style: str = "tp") -> Dict[str, float]:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cfg = dataclasses.replace(cfg, act_batch_axes=("data",))
    params_sds = abstract_params(cfg)
    pspecs = shd.param_specs(params_sds, axis_sizes=axis_sizes,
                             attn_style=attn_style)
    psh = shd.shardings_of(pspecs, mesh)
    batch_sds = batch_specs_struct(cfg, batch, seq)
    bspecs = shd.batch_specs(batch_sds)
    bsh = shd.shardings_of(bspecs, mesh)
    model = build_model(cfg)

    with jax.set_mesh(mesh):
        if grad_only:
            def step(params, b):
                def lf(p):
                    return model.loss(p, b, unroll=True, q_chunk=q_chunk)[0]
                loss, g = jax.value_and_grad(lf)(params)
                g = jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(x, s),
                    g, pspecs)
                return loss, g
            lowered = jax.jit(step, in_shardings=(psh, bsh),
                              out_shardings=(NamedSharding(mesh, P()), psh)
                              ).lower(params_sds, batch_sds)
        else:
            fn = make_train_step(cfg, INNER, grad_accum=1, unroll=True,
                                 q_chunk=q_chunk, param_pspecs=pspecs)
            state_sds = jax.eval_shape(init_train_state, params_sds)
            state_sh = _state_shardings(pspecs, mesh)
            lowered = jax.jit(fn, in_shardings=(state_sh, bsh),
                              out_shardings=(state_sh, NamedSharding(mesh, P())),
                              donate_argnums=(0,)
                              ).lower(state_sds, batch_sds)
        return _metrics(lowered.compile())


def _lower_prefill(cfg: ModelConfig, batch: int, seq: int, mesh, *,
                   q_chunk: int) -> Dict[str, float]:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cfg = dataclasses.replace(cfg, act_batch_axes=("data",))
    params_sds = abstract_params(cfg)
    pspecs = shd.param_specs(params_sds, axis_sizes=axis_sizes)
    psh = shd.shardings_of(pspecs, mesh)
    batch_sds = batch_specs_struct(cfg, batch, seq, with_labels=False)
    bsh = shd.shardings_of(shd.batch_specs(batch_sds), mesh)
    model = build_model(cfg)
    with jax.set_mesh(mesh):
        def step(params, b):
            return model.prefill(params, b, cache_len=seq, unroll=True,
                                 q_chunk=q_chunk)
        lowered = jax.jit(step, in_shardings=(psh, bsh)).lower(
            params_sds, batch_sds)
        return _metrics(lowered.compile())


def _lower_decode(cfg: ModelConfig, batch: int, seq: int, mesh
                  ) -> Dict[str, float]:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cfg = dataclasses.replace(cfg, act_batch_axes=())
    params_sds = abstract_params(cfg)
    pspecs = shd.param_specs(params_sds, axis_sizes=axis_sizes)
    psh = shd.shardings_of(pspecs, mesh)
    model = build_model(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(batch, seq))
    batch_sharded = batch >= axis_sizes.get("data", 1)
    cspecs = shd.cache_specs(caches, batch_sharded=batch_sharded,
                             axis_sizes=axis_sizes)
    csh = shd.shardings_of(cspecs, mesh)
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    tok_sh = NamedSharding(mesh, P("data") if batch_sharded else P())
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    with jax.set_mesh(mesh):
        lowered = jax.jit(model.decode,
                          in_shardings=(psh, tok_sh, csh,
                                        NamedSharding(mesh, P()))
                          ).lower(params_sds, tok, caches, pos)
        return _metrics(lowered.compile())


# --------------------------------------------------------------------------
# Per-cell probe
# --------------------------------------------------------------------------

SLSTM_PROBE_SEQ = 256


def probe_cell(arch: str, shape_name: str,
               overrides: Optional[Dict] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    plan = plan_for(arch, shape, overrides)
    mesh = make_production_mesh(multi_pod=False)
    n_units = _units_of(cfg)
    t0 = time.time()

    def lower_at(units: int, *, kind: str, batch: int, seq: int,
                 grad_only: bool = False):
        pc = _probe_cfg(cfg, units)
        pc = dataclasses.replace(
            pc,
            act_model_axis=("model" if plan.get("head_tp") else ""),
            seq_parallel=bool(plan.get("seq_parallel")),
            remat_group=min(int(plan.get("remat_group", 1)), pc.n_layers) or 1)
        if pc.is_moe and plan.get("moe_vmap"):
            pc = dataclasses.replace(
                pc, moe=dataclasses.replace(pc.moe, group_mode="vmap"))
        if pc.is_moe and (plan.get("moe_group") or plan.get("moe_dispatch")):
            # moe_group: fewer, larger dispatch groups (keeps the unrolled
            # probe HLO small; MoE cost is linear in tokens either way).
            # moe_dispatch: scatter (O(T d), GSPMD-hostile) vs einsum
            # (O(T E C d), GSPMD-clean) — the right choice is per-arch.
            pc = dataclasses.replace(
                pc, moe=dataclasses.replace(
                    pc.moe,
                    group_size=int(plan.get("moe_group",
                                            pc.moe.group_size)),
                    dispatch=plan.get("moe_dispatch", pc.moe.dispatch)))
        if kind == "train":
            return _lower_train(
                pc, batch, seq, mesh, q_chunk=plan["q_chunk"],
                grad_only=grad_only,
                attn_style=("dp" if plan.get("attn_dp") else "tp"))
        if kind == "prefill":
            return _lower_prefill(pc, batch, seq, mesh,
                                  q_chunk=plan["q_chunk"])
        return _lower_decode(pc, batch, seq, mesh)

    out: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "plan": plan, "n_units": n_units}

    if shape.kind == "train":
        micro = shape.global_batch // plan["grad_accum"]
        g1 = lower_at(1, kind="train", batch=micro, seq=shape.seq_len,
                      grad_only=True)
        g2 = lower_at(2, kind="train", batch=micro, seq=shape.seq_len,
                      grad_only=True)
        t1 = lower_at(1, kind="train", batch=micro, seq=shape.seq_len)
        grad_unit = _diff(g2, g1)
        grad_fixed = _diff(g1, grad_unit)
        opt1 = _diff(t1, g1)                       # optimizer cost at depth 1
        # optimizer scales with params: unit share from param counts
        p1 = _count_params(_probe_cfg(cfg, 1))
        pu = (_count_params(_probe_cfg(cfg, 2)) - p1)
        opt_unit = _scale(opt1, pu / max(p1, 1))
        opt_fixed = _diff(opt1, opt_unit) if p1 > pu else _scale(opt1, 0.0)
        total = _add(
            _scale(_add(grad_fixed, _scale(grad_unit, n_units)),
                   plan["grad_accum"]),
            _add(opt_fixed, _scale(opt_unit, n_units)))
        out["detail"] = {"grad_unit": grad_unit, "grad_fixed": grad_fixed,
                         "opt_at_depth1": opt1}
        if cfg.family == "ssm" and cfg.xlstm.slstm_at:
            total = _add(total, _slstm_extra(
                cfg, micro, shape.seq_len, mesh, plan, train=True,
                accum=plan["grad_accum"]))
    elif shape.kind == "prefill":
        p1 = lower_at(1, kind="prefill", batch=shape.global_batch,
                      seq=shape.seq_len)
        p2 = lower_at(2, kind="prefill", batch=shape.global_batch,
                      seq=shape.seq_len)
        unit = _diff(p2, p1)
        fixed = _diff(p1, unit)
        total = _add(fixed, _scale(unit, n_units))
        if cfg.family == "ssm" and cfg.xlstm.slstm_at:
            total = _add(total, _slstm_extra(cfg, shape.global_batch,
                                             shape.seq_len, mesh, plan,
                                             train=False, accum=1))
    else:  # decode
        d1 = lower_at(1, kind="decode", batch=shape.global_batch,
                      seq=shape.seq_len)
        d2 = lower_at(2, kind="decode", batch=shape.global_batch,
                      seq=shape.seq_len)
        unit = _diff(d2, d1)
        fixed = _diff(d1, unit)
        total = _add(fixed, _scale(unit, n_units))
        if cfg.family == "ssm" and cfg.xlstm.slstm_at:
            s1 = _lower_decode(_xl_probe(cfg, 1, slstm=False),
                               shape.global_batch, shape.seq_len, mesh)
            s2 = _lower_decode(_xl_probe(cfg, 2, slstm=True),
                               shape.global_batch, shape.seq_len, mesh)
            total = _add(total, _scale(_diff(s2, s1),
                                       len(cfg.xlstm.slstm_at)))
    out["total_per_device"] = total
    out["probe_seconds"] = time.time() - t0
    return out


def _xl_probe(cfg: ModelConfig, units: int, slstm: bool) -> ModelConfig:
    sl = (1,) if slstm and units > 1 else ()
    return dataclasses.replace(
        cfg, n_layers=units,
        xlstm=dataclasses.replace(cfg.xlstm, slstm_at=sl))


def _slstm_extra(cfg, batch, seq, mesh, plan, *, train: bool, accum: int
                 ) -> Dict[str, float]:
    """sLSTM unit cost: probed at S=256 (recurrent scan unrolled), scaled
    linearly to S; multiplied by the number of sLSTM layers (and accum)."""
    s = SLSTM_PROBE_SEQ
    f = (_lower_train if train else _lower_prefill)
    kw = dict(q_chunk=plan["q_chunk"])
    if train:
        kw["grad_only"] = True
    m_only = f(dataclasses.replace(_xl_probe(cfg, 1, slstm=False)),
               batch, s, mesh, **kw)
    with_s = f(dataclasses.replace(_xl_probe(cfg, 2, slstm=True)),
               batch, s, mesh, **kw)
    # depth2-with-slstm minus depth1-mlstm = (mlstm unit + slstm unit);
    # subtract the mlstm unit measured at the same short seq
    m2 = f(dataclasses.replace(_xl_probe(cfg, 2, slstm=False)),
           batch, s, mesh, **kw)
    slstm_unit_short = _diff(with_s, m2)
    per_layer = _scale(slstm_unit_short, seq / s)
    mult = len(cfg.xlstm.slstm_at) * (accum if train else 1)
    return _scale(per_layer, mult)


def _count_params(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return sum(x.size for x in jax.tree.leaves(tree))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results/probes")
    ap.add_argument("--plan", default=None, help="JSON plan overrides")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    overrides = json.loads(args.plan) if args.plan else None
    from repro.configs import ASSIGNED
    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = shape_applicable(cfg, SHAPES[shape_name])
            path = os.path.join(
                args.out, f"{arch}__{shape_name}"
                + (f"__{args.tag}" if args.tag else "") + ".json")
            if not ok:
                json.dump({"arch": arch, "shape": shape_name, "skipped": why},
                          open(path, "w"), indent=1)
                print(f"SKIP {arch}/{shape_name}: {why}", flush=True)
                continue
            try:
                rec = probe_cell(arch, shape_name, overrides)
                json.dump(rec, open(path, "w"), indent=1)
                t = rec["total_per_device"]
                print(f"OK   {arch}/{shape_name}: flops={t['flops']:.3e} "
                      f"bytes={t['bytes']:.3e} wire={t['wire']:.3e} "
                      f"({rec['probe_seconds']:.0f}s)", flush=True)
            except Exception as e:
                import traceback
                json.dump({"arch": arch, "shape": shape_name,
                           "error": repr(e)}, open(path, "w"), indent=1)
                print(f"FAIL {arch}/{shape_name}: {e!r}", flush=True)
                traceback.print_exc()


if __name__ == "__main__":
    main()
