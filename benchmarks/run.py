"""Benchmark driver: one function per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (plus per-benchmark summary blocks).

Fast benches (overhead, kernels) always run; the paper-reproduction
training benches run with reduced budgets by default (pass --full for the
paper-scale budgets used in EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (slow)")
    ap.add_argument("--skip-training", action="store_true",
                    help="only micro-benchmarks")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    from benchmarks import bench_kernels, bench_overhead
    for r in bench_overhead.run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    for r in bench_kernels.run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    sys.stdout.flush()

    if args.skip_training:
        return

    outer, inner = (60, 15) if args.full else (24, 6)
    t0 = time.time()
    from benchmarks import (bench_convergence, bench_drop_stale,
                            bench_language, bench_pace_table)

    print(f"\n# Fig.2 convergence (outer={outer} inner={inner})")
    print(bench_convergence.summarize(bench_convergence.run(outer, inner)))
    sys.stdout.flush()

    print(f"\n# Table 1 pace sweep")
    cfgs = bench_pace_table.PACE_CONFIGS if args.full else \
        bench_pace_table.PACE_CONFIGS[:4]
    print(bench_pace_table.summarize(
        bench_pace_table.run(outer, inner, cfgs), cfgs))
    sys.stdout.flush()

    print(f"\n# Fig.3 per-language")
    print(bench_language.summarize(bench_language.run(outer, inner)))
    sys.stdout.flush()

    print(f"\n# Fig.8 drop-stale ablation")
    print(bench_drop_stale.summarize(bench_drop_stale.run(
        outer if args.full else 16, inner)))
    print(f"\n# total bench wall time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
