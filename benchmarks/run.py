"""Benchmark driver: one function per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows (plus per-benchmark summary blocks).

Fast benches (overhead, kernels) always run and their rows are persisted
to results/bench/BENCH_arrival.json (appending one entry per run, so the
arrival-path perf trajectory accumulates across PRs; histories from the
legacy repo-root location are carried forward automatically); the
paper-reproduction training benches run with reduced budgets by default
(pass --full for the paper-scale budgets used in EXPERIMENTS.md).
``benchmarks.check_regression`` gates the latest entries against
committed baselines (``make bench-check``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# Canonical location: results/ (one place for CI artifacts + local runs).
BENCH_DIR = os.environ.get("REPRO_BENCH_DIR",
                           os.path.join(_ROOT, "results", "bench"))
BENCH_JSON = os.path.join(BENCH_DIR, "BENCH_arrival.json")
BENCH_RUNTIME_JSON = os.path.join(BENCH_DIR, "BENCH_runtime.json")
BENCH_SCALE_JSON = os.path.join(BENCH_DIR, "BENCH_scale.json")
# Pre-PR-3 location (repo root): read-only fallback so accumulated
# histories carry forward without symlinks.
_LEGACY = {BENCH_JSON: os.path.join(_ROOT, "BENCH_arrival.json"),
           BENCH_RUNTIME_JSON: os.path.join(_ROOT, "BENCH_runtime.json")}


def _load_history(path) -> list:
    for candidate in (path, _LEGACY.get(path, "")):
        if candidate and os.path.exists(candidate):
            try:
                with open(candidate) as f:
                    return json.load(f)
            except (json.JSONDecodeError, OSError):
                return []
    return []


def _persist(rows, path=BENCH_JSON) -> None:
    history = _load_history(path)
    history.append({"unix_time": time.time(), "rows": rows})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(history, f, indent=1)
    os.replace(tmp, path)
    print(f"# persisted {len(rows)} rows -> {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale budgets (slow)")
    ap.add_argument("--skip-training", action="store_true",
                    help="only micro-benchmarks")
    ap.add_argument("--runtime", action="store_true",
                    help="wall-clock runtime benchmark (simulator vs "
                         "threaded ConcurrentRuntime) -> BENCH_runtime.json")
    ap.add_argument("--scale", action="store_true",
                    help="batched-arrival scale benchmark (launch "
                         "contracts, N in {64,1k,10k} bookkeeping, "
                         "transfer probe) -> BENCH_scale.json")
    args = ap.parse_args()

    if args.scale:
        from benchmarks import bench_scale
        print("name,us_per_call,derived")
        rows = bench_scale.run()
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        _persist(rows, BENCH_SCALE_JSON)
        return

    if args.runtime:
        from benchmarks import bench_runtime
        outer, inner = (24, 8) if args.full else (12, 3)
        print("name,us_per_call,derived")
        rows = bench_runtime.run(outer, inner)
        for r in rows:
            print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
        print("\n" + bench_runtime.summarize(rows))
        _persist(rows, BENCH_RUNTIME_JSON)
        return

    print("name,us_per_call,derived")
    from benchmarks import bench_kernels, bench_overhead
    micro = bench_overhead.run() + bench_kernels.run()
    for r in micro:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    _persist(micro)
    sys.stdout.flush()

    if args.skip_training:
        return

    outer, inner = (60, 15) if args.full else (24, 6)
    t0 = time.time()
    from benchmarks import (bench_convergence, bench_drop_stale,
                            bench_language, bench_pace_table)

    print(f"\n# Fig.2 convergence (outer={outer} inner={inner})")
    print(bench_convergence.summarize(bench_convergence.run(outer, inner)))
    sys.stdout.flush()

    print(f"\n# Table 1 pace sweep")
    cfgs = bench_pace_table.PACE_CONFIGS if args.full else \
        bench_pace_table.PACE_CONFIGS[:4]
    print(bench_pace_table.summarize(
        bench_pace_table.run(outer, inner, cfgs), cfgs))
    sys.stdout.flush()

    print(f"\n# Fig.3 per-language")
    print(bench_language.summarize(bench_language.run(outer, inner)))
    sys.stdout.flush()

    print(f"\n# Fig.8 drop-stale ablation")
    print(bench_drop_stale.summarize(bench_drop_stale.run(
        outer if args.full else 16, inner)))
    print(f"\n# total bench wall time: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
