"""Paper §3 overhead claim: the HeLoCo correction is one O(d) pass per
arrival. Measures wall-time per correction vs model size (jnp path on CPU)
and verifies linear scaling; reports bytes touched per arrival.

Packed-arrival rows compare the full arrival pipeline on an 8-block
synthetic model: per-leaf kernel path (2 pallas_calls per block + a second
full tree sweep) vs the packed fast path (one flat buffer, 2 pallas_calls
total) — both launch counts (counted by intercepting ``pl.pallas_call``)
and wall time per arrival. Kernels run in interpret mode on CPU, so the
times are correctness-path numbers; the launch counts and bytes-touched
accounting are the TPU-relevant quantities.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig
from repro.core import packing
from repro.core.heloco import (
    apply_arrival, apply_arrival_packed, block_correct, init_outer_state,
)

H = HeLoCoConfig()
N_BLOCKS = 8


def _blocks(d: int, seed: int = 0) -> Dict:
    key = jax.random.PRNGKey(seed)
    per = max(d // N_BLOCKS, 1)
    return {f"b{i}": jax.random.normal(jax.random.fold_in(key, seed * 100 + i),
                                      (per,))
            for i in range(N_BLOCKS)}


def time_correction(d: int, reps: int = 20) -> float:
    """us per correction of a d-parameter pseudo-gradient (8 tensor blocks)."""
    delta = _blocks(d, 0)
    mom = _blocks(d, 1)
    fn = jax.jit(lambda a, b: block_correct(a, b, H))
    out = fn(delta, mom)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(delta, mom)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def count_launches(fn, *args) -> int:
    """pallas_call equation instances in the traced program — the number
    of kernel dispatches one execution performs (trace-time interception
    undercounts: same-shape blocks share a jit cache entry)."""
    def walk(jx) -> int:
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        n += walk(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        n += walk(sub)
        return n
    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def _time_jit(fn, *args, reps: int = 30) -> float:
    """min-of-reps (robust to scheduler noise), us per call."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _arrival_timing_rows(d: int, reps: int, note: str) -> List[Dict]:
    params = _blocks(d, 0)
    delta = _blocks(d, 2)
    state = init_outer_state(params)

    def leaf_path(use_kernel):
        return jax.jit(lambda s, g: apply_arrival(
            s, g, method="heloco", outer_lr=0.7, mu=0.9, h=H,
            use_kernel=use_kernel))

    layout = packing.build_layout(params)
    pbuf = packing.pack(layout, params)
    mbuf = packing.zeros(layout)
    packed_fn = jax.jit(lambda p, m, g: apply_arrival_packed(
        p, m, g, layout, method="heloco", outer_lr=0.7, mu=0.9, h=H))
    return [
        {"name": f"arrival_per_leaf_jnp_d{d}",
         "us_per_call": _time_jit(leaf_path(False), state, delta, reps=reps),
         "derived": f"pure-jnp reference (no pallas); {note}"},
        {"name": f"arrival_per_leaf_kernel_d{d}",
         "us_per_call": _time_jit(leaf_path(True), state, delta, reps=reps),
         "derived": f"2 launches/block + jnp outer sweep; {note}"},
        {"name": f"arrival_packed_d{d}",
         "us_per_call": _time_jit(packed_fn, pbuf, mbuf, delta, reps=reps),
         "derived": f"2 launches total; {note}"},
    ]


def per_method_launch_rows(d: int = 1 << 13) -> List[Dict]:
    """Launch-count contract for EVERY registered outer method: the packed
    arrival path must stay <= 2 pallas_calls (one optional stats sweep +
    one fused correct+outer sweep) no matter which method is configured —
    including the buffered delayed-Nesterov/FedBuff schedules and the
    DC-ASGD quadratic compensation. And the contract must HOLD WITH
    TELEMETRY ON: the update-quality stats ride the fused sweep as an
    extra output (``with_stats``), so the telemetry rows assert the SAME
    count as the plain rows. Rows are exact-match gated (name contains
    "launches") so a method silently falling off the fused path — or
    telemetry sneaking in an extra sweep — fails ``make bench-check``."""
    from repro.core import methods as outer_methods
    from repro.core.heloco import apply_arrival_packed

    params = _blocks(d, 0)
    delta = _blocks(d, 2)
    layout = packing.build_layout(params)
    pbuf = packing.pack(layout, params)
    mbuf = packing.zeros(layout)
    abuf = packing.zeros(layout)
    rows = []
    for m in outer_methods.all_methods():
        def arrival(p, mm, g, b=None, name=m.name, stats=False):
            return apply_arrival_packed(p, mm, g, layout, method=name,
                                        outer_lr=0.7, mu=0.9, h=H, tau=3.0,
                                        abuf=b, phase=2, with_stats=stats)
        counts = {}
        for stats in (False, True):
            fn = jax.jit(functools.partial(arrival, stats=stats))
            if m.uses_buffer:
                counts[stats] = count_launches(fn, pbuf, mbuf, delta, abuf)
            else:
                counts[stats] = count_launches(fn, pbuf, mbuf, delta)
        n, nt = counts[False], counts[True]
        extra = "4R+3W (accumulator)" if m.uses_buffer else "3R+2W"
        rows.append({
            "name": f"arrival_launches_packed_{m.name}",
            "us_per_call": float(n),
            "derived": (f"pallas_calls={n} (<= 2 per arrival); fused "
                        f"sweep hbm={extra} of d floats")})
        rows.append({
            "name": f"arrival_launches_packed_telemetry_{m.name}",
            "us_per_call": float(nt),
            "derived": (f"pallas_calls={nt} == telemetry-off count "
                        "(stats are an extra output of the fused sweep, "
                        "zero added launches)")})
        assert n <= 2 and nt == n, (m.name, n, nt)
    return rows


def arrival_rows(reps: int = 30) -> List[Dict]:
    """Full-arrival comparison on the 8-block synthetic model.

    Two regimes: launch-bound (small d — dispatch overhead dominates;
    this is what the packed path eliminates, and where real transformers
    with hundreds of leaves live) and bandwidth-bound (large d). Times
    are CPU interpret-mode; the launch counts and byte accounting are the
    TPU-relevant quantities (the CPU interpreter favors the per-leaf path
    at cache-spilling sizes because each small block stays cache-resident,
    an artifact a TPU's explicit VMEM pipeline does not share).
    """
    d_small, d_large = 1 << 13, 1 << 20
    params = _blocks(d_small, 0)
    delta = _blocks(d_small, 2)
    state = init_outer_state(params)
    layout = packing.build_layout(params)
    pbuf = packing.pack(layout, params)
    mbuf = packing.zeros(layout)

    launches_leaf = count_launches(
        jax.jit(lambda s, g: apply_arrival(
            s, g, method="heloco", outer_lr=0.7, mu=0.9, h=H,
            use_kernel=True)), state, delta)
    launches_packed = count_launches(
        jax.jit(lambda p, m, g: apply_arrival_packed(
            p, m, g, layout, method="heloco", outer_lr=0.7, mu=0.9, h=H)),
        pbuf, mbuf, delta)

    rows = [
        {"name": "arrival_launches_per_leaf",
         "us_per_call": float(launches_leaf),
         "derived": f"pallas_calls={launches_leaf} (O(#leaves), "
                    f"{N_BLOCKS} blocks)"},
        {"name": "arrival_launches_packed",
         "us_per_call": float(launches_packed),
         "derived": f"pallas_calls={launches_packed} (O(1): stats + "
                    "fused correct+outer)"},
        {"name": "arrival_hbm_bytes",
         "us_per_call": 0.0,
         "derived": (f"per_leaf={10 * d_large * 4}B (7R+3W of d floats) "
                     f"packed={9 * d_large * 4}B (6R+3W incl. delta pack) "
                     f"at d={d_large}; fused sweep alone is 3R+2W, the "
                     "roofline minimum")},
    ]
    rows += per_method_launch_rows(d_small)
    rows += _arrival_timing_rows(d_small, reps, "launch-bound regime")
    rows += _arrival_timing_rows(d_large, max(reps // 6, 5),
                                 "bandwidth-bound regime")
    return rows


def run() -> List[Dict]:
    rows = []
    for d in (1 << 14, 1 << 17, 1 << 20, 1 << 23):
        us = time_correction(d)
        rows.append({"name": f"heloco_correct_d{d}", "us_per_call": us,
                     "derived": f"bytes={3 * 4 * d} us_per_Mparam={us / (d / 1e6):.1f}"})
    # linearity check: us/d should be ~constant for large d
    big = [r for r in rows if "d1048576" in r["name"] or "d8388608" in r["name"]]
    if len(big) == 2:
        r1 = big[0]["us_per_call"] / (1 << 20)
        r2 = big[1]["us_per_call"] / (1 << 23)
        rows.append({"name": "heloco_correct_linearity",
                     "us_per_call": 0.0,
                     "derived": f"ratio={r2 / r1:.2f} (1.0 = perfectly O(d))"})
    rows.extend(arrival_rows())
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
