"""Paper §3 overhead claim: the HeLoCo correction is one O(d) pass per
arrival. Measures wall-time per correction vs model size (jnp path on CPU)
and verifies linear scaling; reports bytes touched per arrival."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig
from repro.core.heloco import block_correct

H = HeLoCoConfig()


def time_correction(d: int, reps: int = 20) -> float:
    """us per correction of a d-parameter pseudo-gradient (8 tensor blocks)."""
    key = jax.random.PRNGKey(0)
    per = max(d // 8, 1)
    delta = {f"b{i}": jax.random.normal(jax.random.fold_in(key, i), (per,))
             for i in range(8)}
    mom = {f"b{i}": jax.random.normal(jax.random.fold_in(key, 100 + i), (per,))
           for i in range(8)}
    fn = jax.jit(lambda a, b: block_correct(a, b, H))
    out = fn(delta, mom)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(delta, mom)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> List[Dict]:
    rows = []
    for d in (1 << 14, 1 << 17, 1 << 20, 1 << 23):
        us = time_correction(d)
        rows.append({"name": f"heloco_correct_d{d}", "us_per_call": us,
                     "derived": f"bytes={3 * 4 * d} us_per_Mparam={us / (d / 1e6):.1f}"})
    # linearity check: us/d should be ~constant for large d
    big = [r for r in rows if "d1048576" in r["name"] or "d8388608" in r["name"]]
    if len(big) == 2:
        r1 = big[0]["us_per_call"] / (1 << 20)
        r2 = big[1]["us_per_call"] / (1 << 23)
        rows.append({"name": "heloco_correct_linearity",
                     "us_per_call": 0.0,
                     "derived": f"ratio={r2 / r1:.2f} (1.0 = perfectly O(d))"})
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
