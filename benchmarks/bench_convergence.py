"""Paper Fig. 2: validation loss under {IID, non-IID} x {heterogeneous,
homogeneous} worker speeds for sync-Nesterov / async-Nesterov / async-MLA /
async-HeLoCo (+ DyLU variants in the heterogeneous settings).

Paper setting: 5 workers, paces 0.74-7.5 s/step. The qualitative claims
checked here (and recorded in EXPERIMENTS.md):
  C1: het+non-IID: HeLoCo < MLA < async-Nesterov (final loss)
  C2: het+IID:     HeLoCo <= MLA  < async-Nesterov
  C3: hom+non-IID: HeLoCo <= MLA (non-IID alone justifies per-block)
  C4: DyLU does not consistently beat non-DyLU HeLoCo
"""
from __future__ import annotations

import argparse
import json
from typing import Dict

from benchmarks.common import base_run, run_cached

HET_PACES = (0.74, 1.5, 3.0, 6.0, 7.5)     # paper: 0.74-7.50 s/step
HOM_PACES = (1.0, 1.0, 1.0, 1.0, 1.0)


def run(outer_steps: int = 40, inner_steps: int = 10) -> Dict:
    results = {}
    settings = [
        ("het_noniid", HET_PACES, True),
        ("het_iid", HET_PACES, False),
        ("hom_noniid", HOM_PACES, True),
        ("hom_iid", HOM_PACES, False),
    ]
    for tag, paces, non_iid in settings:
        for method in ("async-heloco", "async-mla", "async-nesterov",
                       "sync-nesterov"):
            rc = base_run(paces, method=method, non_iid=non_iid,
                          outer_steps=outer_steps, inner_steps=inner_steps)
            results[f"{tag}/{method}"] = run_cached(
                f"fig2_{tag}_{method}", rc)
        if tag.startswith("het"):
            for method in ("async-heloco", "async-mla"):
                rc = base_run(paces, method=method, non_iid=non_iid,
                              outer_steps=outer_steps,
                              inner_steps=inner_steps, dylu=True)
                results[f"{tag}/{method}+dylu"] = run_cached(
                    f"fig2_{tag}_{method}_dylu", rc)
    return results


def summarize(results: Dict) -> str:
    lines = ["setting,method,final_loss,mean_staleness,tokens"]
    for key, r in sorted(results.items()):
        tau = (sum(r["staleness"]) / max(len(r["staleness"]), 1))
        lines.append(f"{key.replace('/', ',')},{r['final_loss']:.4f},"
                     f"{tau:.2f},{r['tokens']}")
    checks = []
    g = lambda s, m: results[f"{s}/{m}"]["final_loss"]
    checks.append(("C1 het_noniid heloco<mla<nesterov",
                   g("het_noniid", "async-heloco") <= g("het_noniid", "async-mla")
                   <= g("het_noniid", "async-nesterov") + 1e-6))
    checks.append(("C2 het_iid heloco<=mla",
                   g("het_iid", "async-heloco") <= g("het_iid", "async-mla") + 0.02))
    checks.append(("C3 hom_noniid heloco<=mla",
                   g("hom_noniid", "async-heloco") <= g("hom_noniid", "async-mla") + 0.02))
    checks.append(("C4 dylu not consistently better",
                   results["het_noniid/async-heloco"]["final_loss"]
                   <= results["het_noniid/async-heloco+dylu"]["final_loss"] + 0.05))
    for name, ok in checks:
        lines.append(f"CHECK,{name},{'PASS' if ok else 'FAIL'}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outer", type=int, default=40)
    ap.add_argument("--inner", type=int, default=10)
    args = ap.parse_args()
    results = run(args.outer, args.inner)
    print(summarize(results))


if __name__ == "__main__":
    main()
