"""Batched-arrival scale benchmark (docs/scale.md): the O(10k)-worker
claims behind the commit-buffer fast path.

Three row families, persisted to results/bench/BENCH_scale.json and
gated against ``benchmarks/baselines/BENCH_scale.json`` by ``make
bench-check-scale`` with the same per-metric discipline as the arrival
family:

  - ``scale_launches_*`` (EXACT): a flush of K coalesced arrivals must
    commit in <= 2 Pallas launches for EVERY registered outer method —
    one optional multi-Gram statistics sweep plus one K-unrolled fused
    sweep — and the count must hold with telemetry on (the (K, R, 4)
    moments ride the fused sweep as an extra output). The sequential
    path costs up to 2K launches; this contract is the TPU-relevant
    quantity the batching buys.
  - ``scale_arrival_*`` (timing, banded): amortized per-arrival engine
    bookkeeping at N in {64, 1k, 10k} workers — the NumPy worker arena +
    vectorized event queue draining same-tick batches, against a
    faithful reimplementation of the pre-arena bookkeeping (heapq +
    per-worker Python dataclass + the O(N) dict walks the per-commit
    streaming-telemetry snapshot performed). The run() harness asserts
    the N=1k amortized improvement stays >= 5x.
  - ``scale_hot_*_h2d_traffic`` (EXACT): after warm-up, a single-arrival
    commit and a K-arrival flush issue ZERO implicit host->device
    transfers (the coefficient-scalar table plus the one-device_put-per-
    flush vector discipline), proven under
    ``jax.transfer_guard_host_to_device("disallow")``.

Kernel wall-times are deliberately absent: on CPU the kernels run in
interpret mode, where a K-unrolled sweep re-interprets K applications'
worth of ops and the dispatch savings vanish — the same artifact
``bench_overhead`` documents for the per-leaf vs packed comparison. The
launch counts and transfer counts are the hardware-relevant contracts.
"""
from __future__ import annotations

import functools
import heapq
import time
from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.bench_overhead import N_BLOCKS, _blocks, count_launches
from repro.configs.base import HeLoCoConfig, OuterOptConfig
from repro.core import packing
from repro.core.heloco import apply_arrivals_packed

H = HeLoCoConfig()
K = 4                                # flush size for the launch contract
SCALE_NS = (64, 1000, 10000)
SPEEDUP_FLOOR = 5.0                  # asserted at N=1k


# ---------------------------------------------------------------------------
# EXACT family 1: <= 2 launches per K-arrival flush, every method
# ---------------------------------------------------------------------------

def multi_launch_rows(d: int = 1 << 13, k: int = K) -> List[Dict]:
    from repro.core import methods as outer_methods

    params = _blocks(d, 0)
    deltas = [_blocks(d, 2 + i) for i in range(k)]
    layout = packing.build_layout(params)
    pbuf = packing.pack(layout, params)
    mbuf = packing.zeros(layout)
    abuf = packing.zeros(layout)
    rhos = [0.9, 1.0, 0.7, 1.0][:k]
    taus = [1.0, 0.0, 3.0, 2.0][:k]
    rows = []
    for m in outer_methods.all_methods():
        def flush(p, mm, b=None, name=m.name, stats=False):
            return apply_arrivals_packed(
                p, mm, deltas, layout, method=name, outer_lr=0.7, mu=0.9,
                h=H, rhos=rhos, taus=taus, abuf=b,
                phases=list(range(k)) if b is not None else None,
                with_stats=stats)
        counts = {}
        for stats in (False, True):
            fn = jax.jit(functools.partial(flush, stats=stats))
            if m.uses_buffer:
                counts[stats] = count_launches(fn, pbuf, mbuf, abuf)
            else:
                counts[stats] = count_launches(fn, pbuf, mbuf)
        n, nt = counts[False], counts[True]
        rows.append({
            "name": f"scale_launches_multi_{m.name}",
            "us_per_call": float(n),
            "derived": (f"pallas_calls={n} for a K={k} flush (<= 2; "
                        f"sequential path is up to {2 * k})")})
        rows.append({
            "name": f"scale_launches_multi_telemetry_{m.name}",
            "us_per_call": float(nt),
            "derived": (f"pallas_calls={nt} == telemetry-off count "
                        "((K,R,4) moments ride the fused sweep)")})
        assert n <= 2 and nt == n, (m.name, n, nt)
    return rows


# ---------------------------------------------------------------------------
# Timing family: amortized engine bookkeeping per arrival at N workers
# ---------------------------------------------------------------------------

@dataclass
class _LegacyWorker:
    """The pre-arena per-worker record: one Python object per worker."""
    wid: int
    pace: float
    s_i: int = 0
    inner_step_count: int = 0
    in_flight: bool = False
    alive: bool = True
    generation: int = 0


def _legacy_us(n: int, arrivals: int) -> float:
    """Pre-arena bookkeeping reference: heapq event loop + dataclass
    field churn + the per-commit O(N) dict walks the streaming-telemetry
    snapshot (workers_alive / in_flight / min alive pace) performed."""
    workers = {w: _LegacyWorker(w, 1.0 + (w % 7)) for w in range(n)}
    heap: list = []
    seq = 0
    for w in workers.values():
        heapq.heappush(heap, (w.pace * 2, seq, "return", w.wid, 0))
        seq += 1
        w.in_flight = True
    t0 = time.perf_counter()
    done = 0
    while done < arrivals:
        tm, _, _kind, wid, gen = heapq.heappop(heap)
        w = workers[wid]
        if not (w.alive and w.generation == gen):
            continue
        w.in_flight = False
        w.s_i += 1
        w.inner_step_count += 2
        _snap = (sum(1 for x in workers.values() if x.alive),
                 sum(1 for x in workers.values() if x.in_flight),
                 min(x.pace for x in workers.values() if x.alive))
        heapq.heappush(heap, (tm + w.pace * 2, seq, "return", wid, gen))
        seq += 1
        w.in_flight = True
        done += 1
    return (time.perf_counter() - t0) / arrivals * 1e6


def _arena_us(n: int, arrivals: int, k: int = 16) -> float:
    """The batched fast path: struct-of-arrays arena + vectorized queue,
    same logical work, one snapshot per committed batch."""
    from repro.async_engine.engine import EventQueue, WorkerArena

    q = EventQueue()
    arena = WorkerArena(n)
    pace = arena.cols["pace"]
    in_flight = arena.cols["in_flight"]
    alive = arena.cols["alive"]
    s_i = arena.cols["s_i"]
    isc = arena.cols["inner_step_count"]
    gen = arena.cols["generation"]
    slots = {}
    for w in range(n):
        s = arena.alloc(w)
        pace[s] = 1.0 + (w % 7)
        in_flight[s] = True
        slots[w] = s
        q.push(pace[s] * 2, "return", w, 0)
    t0 = time.perf_counter()
    done = 0
    while done < arrivals:
        evs = q.pop_batch(k)
        for tm, _kind, wid, g in evs:
            s = slots[wid]
            if not (alive[s] and gen[s] == g):
                continue
            in_flight[s] = False
            s_i[s] += 1
            isc[s] += 2
        _snap = (arena.n_alive(), arena.n_in_flight(),
                 arena.min_alive_pace())
        for tm, _kind, wid, g in evs:
            s = slots[wid]
            q.push(tm + pace[s] * 2, "return", wid, g)
            in_flight[s] = True
        done += len(evs)
    return (time.perf_counter() - t0) / arrivals * 1e6


def bookkeeping_rows(reps: int = 3) -> List[Dict]:
    rows = []
    speedups = {}
    for n in SCALE_NS:
        arrivals = min(2 * n, 2048)
        legacy = min(_legacy_us(n, arrivals) for _ in range(reps))
        arena = min(_arena_us(n, arrivals) for _ in range(reps))
        speedups[n] = legacy / arena
        rows.append({
            "name": f"scale_arrival_us_legacy_n{n}",
            "us_per_call": legacy,
            "derived": f"heapq + dataclass + O(N) snapshot walks, N={n}"})
        rows.append({
            "name": f"scale_arrival_us_batched_n{n}",
            "us_per_call": arena,
            "derived": (f"arena + pop_batch(16), N={n}; "
                        f"{legacy / arena:.1f}x vs legacy")})
    rows.append({
        "name": "scale_arrival_speedup_n1000",
        "us_per_call": 0.0,
        "derived": (f"amortized us/arrival improved "
                    f"{speedups[1000]:.1f}x at N=1k "
                    f"(floor {SPEEDUP_FLOOR:g}x, asserted), "
                    f"{speedups[10000]:.1f}x at N=10k")})
    assert speedups[1000] >= SPEEDUP_FLOOR, speedups
    return rows


# ---------------------------------------------------------------------------
# EXACT family 2: zero implicit h2d transfers on warmed commit paths
# ---------------------------------------------------------------------------

def transfer_rows(d: int = 1 << 13) -> List[Dict]:
    from repro.async_engine.server import Synchronizer

    params = _blocks(d, 0)
    deltas = [_blocks(d, 2 + i) for i in range(8)]
    cfg = OuterOptConfig(method="heloco", delay_weighting=True)

    single = Synchronizer(params, cfg, n_workers=4, telemetry=True)
    for i in range(4):
        single.on_arrival(deltas[i], single.t, i % 4)
    with jax.transfer_guard_host_to_device("disallow"):
        single.on_arrival(deltas[4], single.t, 0)

    batched = Synchronizer(params, cfg, n_workers=4, telemetry=True)
    batched.commit_batch = 4
    for _ in range(2):
        for i in range(4):
            batched.buffer_arrival(deltas[i], batched.t, i % 4)
        batched.flush()
    with jax.transfer_guard_host_to_device("disallow"):
        for i in range(4):
            batched.buffer_arrival(deltas[4 + i % 4], batched.t, i % 4)
        batched.flush()

    return [
        {"name": "scale_hot_arrival_h2d_traffic",
         "us_per_call": 0.0,
         "derived": ("implicit h2d transfers on a warmed single-arrival "
                     "commit: 0 (coefficient-scalar table; proven under "
                     "transfer_guard_host_to_device('disallow'))")},
        {"name": "scale_hot_flush_h2d_traffic",
         "us_per_call": 0.0,
         "derived": ("implicit h2d transfers on a warmed K=4 flush: 0 "
                     "(one explicit device_put per flush for all "
                     "per-arrival scalars; moments pulled to host once)")},
    ]


def run() -> List[Dict]:
    rows = multi_launch_rows()
    rows += transfer_rows()
    rows += bookkeeping_rows()
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
