"""Wall-clock runtime benchmark: the serialized virtual-clock simulator
vs the threaded ConcurrentRuntime (deterministic commit order and
free-running) on the same heterogeneous non-IID config.

Reported per engine: wall seconds, arrivals/sec, server occupancy
(fraction of wall time spent applying outer updates), queue depth, and
the overlap evidence the paper's wall-clock claims rest on — how many
workers were mid-round at the moment the server applied an update, and
total worker-compute seconds per wall second (compute_parallelism > 1
means genuine concurrency). Persisted to BENCH_runtime.json by
``benchmarks.run --runtime`` / ``make bench-runtime``.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import base_run


def run(outer: int = 16, inner: int = 4,
        paces=(1.0, 1.0, 2.0, 6.0)) -> List[Dict]:
    from repro.async_engine.engine import make_engine

    rc = base_run(paces, method="async-heloco", non_iid=True,
                  outer_steps=outer, inner_steps=inner)
    rows: List[Dict] = []

    t0 = time.time()
    sim = make_engine(rc, "sim")
    sim.run()
    sim_wall = time.time() - t0
    rows.append({
        "name": "runtime/simulator_serialized",
        "us_per_call": sim_wall / outer * 1e6,
        "derived": f"wall={sim_wall:.2f}s arrivals/s={outer / sim_wall:.2f}",
        "engine": "sim", "wall_seconds": sim_wall,
        "arrivals_per_sec": outer / sim_wall,
    })

    for mode, kw in (("deterministic", {}),
                     ("free", {"pace_scale": 0.02})):
        eng = make_engine(rc, "wallclock", mode=mode, **kw)
        eng.run()
        s = eng.stats_summary()
        rows.append({
            "name": f"runtime/wallclock_{mode}",
            "us_per_call": s["wall_seconds"] / max(s["arrivals"], 1) * 1e6,
            "derived": (f"arrivals/s={s['arrivals_per_sec']:.2f} "
                        f"occ={s['server_occupancy']:.2f} "
                        f"par={s['compute_parallelism']:.2f} "
                        f"qmax={s['queue_depth_max']} "
                        f"overlap_max={s['overlap_max']}"),
            "engine": "wallclock", **s,
            "speedup_vs_sim": sim_wall / max(s["wall_seconds"], 1e-9),
        })
    return rows


def summarize(rows: List[Dict]) -> str:
    lines = ["engine/mode, arrivals/s, occupancy, parallelism, overlap_max"]
    for r in rows:
        lines.append(
            f"{r['name']}, {r.get('arrivals_per_sec', 0):.2f}, "
            f"{r.get('server_occupancy', float('nan')):.2f}, "
            f"{r.get('compute_parallelism', float('nan')):.2f}, "
            f"{r.get('overlap_max', '-')}")
    return "\n".join(lines)


if __name__ == "__main__":
    for row in run():
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
