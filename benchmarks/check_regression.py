"""Benchmark regression gate: compare the latest BENCH_arrival.json /
BENCH_runtime.json entries against committed baselines.

Per-metric discipline:

  - deterministic rows (kernel-launch counts, HBM-byte accounting — names
    matching EXACT_PATTERNS): ``us_per_call`` and ``derived`` must match
    the baseline exactly; these encode the packed arrival-path contract
    (2 launches per arrival, fused-sweep traffic), not machine speed.
  - timing rows: ``us_per_call`` may not exceed ``baseline *
    --timing-slack`` (default 4.0 — CI machines are slow and noisy; the
    gate catches order-of-magnitude regressions, the committed history
    catches slow creep).
  - runtime rows additionally: ``arrivals`` exact, and the qualitative
    concurrency evidence must not evaporate — if the baseline showed
    genuine overlap (compute_parallelism > 1, overlap_max >= 1), the
    fresh run must too.

``--update`` refreshes the committed baselines from the latest fresh
entries. A machine-readable report lands in results/bench/ either way
(the CI failure artifact). Wired in as ``make bench-check``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

from benchmarks.run import (
    BENCH_JSON, BENCH_RUNTIME_JSON, BENCH_SCALE_JSON, _load_history,
)

_HERE = os.path.dirname(os.path.abspath(__file__))
BASELINE_DIR = os.path.join(_HERE, "baselines")
REPORT_PATH = os.path.join(os.path.dirname(BENCH_JSON),
                           "regression_report.json")

# Rows whose numbers are deterministic contracts, not timings.
EXACT_PATTERNS = ("launches", "hbm", "traffic")
TIMING_SLACK = 4.0


def _is_exact_row(name: str) -> bool:
    return any(p in name for p in EXACT_PATTERNS)


def baseline_path(fresh_path: str) -> str:
    return os.path.join(BASELINE_DIR, os.path.basename(fresh_path))


def latest_rows(path: str) -> Optional[List[Dict]]:
    history = _load_history(path)
    return history[-1]["rows"] if history else None


def check_rows(fresh: List[Dict], base: List[Dict],
               timing_slack: float = TIMING_SLACK) -> List[str]:
    """Compare one benchmark family; returns human-readable failures."""
    fails: List[str] = []
    fresh_by = {r["name"]: r for r in fresh}
    for b in base:
        name = b["name"]
        f = fresh_by.get(name)
        if f is None:
            fails.append(f"{name}: present in baseline, missing from "
                         f"fresh run")
            continue
        if _is_exact_row(name):
            if f["us_per_call"] != b["us_per_call"]:
                fails.append(f"{name}: exact metric drifted — "
                             f"got {f['us_per_call']!r}, baseline "
                             f"{b['us_per_call']!r}")
            if f.get("derived") != b.get("derived"):
                fails.append(f"{name}: derived contract drifted — "
                             f"got {f.get('derived')!r}, baseline "
                             f"{b.get('derived')!r}")
            continue
        if b["us_per_call"] > 0 and \
                f["us_per_call"] > b["us_per_call"] * timing_slack:
            fails.append(f"{name}: {f['us_per_call']:.1f}us > "
                         f"{timing_slack:g}x baseline "
                         f"{b['us_per_call']:.1f}us")
        # runtime-bench rows carry structural/concurrency metrics too
        if "arrivals" in b and f.get("arrivals") != b["arrivals"]:
            fails.append(f"{name}: arrivals {f.get('arrivals')} != "
                         f"baseline {b['arrivals']}")
        par = f.get("compute_parallelism") or 0
        if b.get("compute_parallelism", 0) > 1.0 and par <= 1.0:
            fails.append(f"{name}: compute_parallelism {par!r} lost "
                         f"genuine concurrency (baseline "
                         f"{b['compute_parallelism']:.2f})")
        ov = f.get("overlap_max") or 0
        if b.get("overlap_max", 0) >= 1 and ov < 1:
            fails.append(f"{name}: overlap_max {ov!r} — no compute/update "
                         f"overlap (baseline {b['overlap_max']})")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.check_regression")
    ap.add_argument("--update", action="store_true",
                    help="refresh committed baselines from the latest "
                         "fresh entries")
    ap.add_argument("--timing-slack", type=float, default=TIMING_SLACK)
    ap.add_argument("--which", default="arrival,runtime,scale",
                    help="comma-set of {arrival, runtime, scale}")
    args = ap.parse_args(argv)

    which = {w.strip() for w in args.which.split(",") if w.strip()}
    paths = {"arrival": BENCH_JSON, "runtime": BENCH_RUNTIME_JSON,
             "scale": BENCH_SCALE_JSON}
    report = {"ok": True, "families": {}}
    rc = 0
    for fam, fresh_path in paths.items():
        if fam not in which:
            continue
        fresh = latest_rows(fresh_path)
        bpath = baseline_path(fresh_path)
        if fresh is None:
            print(f"[SKIP] {fam}: no fresh rows at {fresh_path} "
                  f"(run `make bench` / `make bench-runtime` first)")
            rc = max(rc, 2)
            continue
        if args.update:
            os.makedirs(BASELINE_DIR, exist_ok=True)
            with open(bpath, "w") as f:
                json.dump(fresh, f, indent=1)
            print(f"[UPDATE] {fam}: baseline <- {len(fresh)} rows "
                  f"-> {bpath}")
            continue
        if not os.path.exists(bpath):
            print(f"[FAIL] {fam}: no committed baseline {bpath} "
                  f"(record one with --update)")
            report["families"][fam] = ["missing baseline"]
            rc = 1
            continue
        with open(bpath) as f:
            base = json.load(f)
        fails = check_rows(fresh, base, args.timing_slack)
        report["families"][fam] = fails
        if fails:
            print(f"[FAIL] {fam}: {len(fails)} metric(s) drifted")
            for msg in fails:
                print(f"    - {msg}")
            rc = 1
        else:
            print(f"[PASS] {fam}: {len(base)} baseline rows within bands")
    report["ok"] = rc == 0
    if not args.update:
        os.makedirs(os.path.dirname(REPORT_PATH), exist_ok=True)
        with open(REPORT_PATH, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# report -> {REPORT_PATH}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
