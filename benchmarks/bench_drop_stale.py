"""Paper App. A.6 (Fig. 8): dropping pseudo-gradients from highly stale
workers — compares keep vs drop for MLA-family methods and async-Nesterov
in high-staleness configurations."""
from __future__ import annotations

import argparse
from typing import Dict

from benchmarks.common import base_run, run_cached

CONFIGS = [(1, 1, 6, 6, 6), (1, 6, 6, 6, 6)]


def run(outer_steps: int = 30, inner_steps: int = 8) -> Dict:
    out = {}
    for paces in CONFIGS:
        tag = "p" + "_".join(str(int(p)) for p in paces)
        for method in ("async-heloco", "async-mla", "async-nesterov"):
            for drop in (None, 3):
                rc = base_run(paces, method=method, non_iid=True,
                              outer_steps=outer_steps,
                              inner_steps=inner_steps,
                              drop_stale_after=drop)
                key = f"{tag}/{method}/{'drop' if drop else 'keep'}"
                out[key] = run_cached(
                    f"fig8_{tag}_{method}_{'drop' if drop else 'keep'}", rc)
    return out


def summarize(results: Dict) -> str:
    lines = ["paces,method,policy,final_loss,n_dropped"]
    for key, r in sorted(results.items()):
        tag, method, policy = key.split("/")
        lines.append(f"{tag},{method},{policy},{r['final_loss']:.4f},"
                     f"{r.get('n_dropped', '-')}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outer", type=int, default=30)
    ap.add_argument("--inner", type=int, default=8)
    args = ap.parse_args()
    print(summarize(run(args.outer, args.inner)))


if __name__ == "__main__":
    main()
