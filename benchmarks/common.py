"""Shared harness for the paper-reproduction benchmarks.

Each benchmark names a scenario (a ``repro.scenarios.Scenario`` — the
single source of truth the launcher and tests also build from), runs a
training engine (the event-driven simulator by default; pass
engine="wallclock" for the threaded concurrent runtime — same Engine API,
real overlap), and caches results as JSON under results/experiments/ so
EXPERIMENTS.md assembly and reruns are cheap.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import RunConfig
from repro.core import methods as outer_methods
from repro.async_engine.engine import make_engine, make_eval_fn
from repro.scenarios.spec import Scenario

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/experiments")

# paper Table 3 (Appendix A.5): benchmark-dialect names ("async-heloco")
# -> raw method + defaults, straight from the ``repro.core.methods``
# registry (the aliases live ON the method definitions; the duplicated
# alias table this module used to keep is gone).
METHODS = {alias: dict(method=raw, **outer_methods.get(raw).defaults())
           for alias, raw in outer_methods.alias_table().items()}


def scenario_for(paces: Sequence[float], *, method: str, non_iid: bool,
                 outer_steps: int, inner_steps: int, dylu: bool = False,
                 seed: int = 0, compression: str = "none",
                 drop_stale_after: Optional[int] = None,
                 shard_assignment: str = "fixed",
                 mixture_alpha: Optional[float] = None,
                 batch_size: int = 4, seq_len: int = 64,
                 name: str = "bench", **scenario_kw) -> Scenario:
    """The benchmark dialect, compiled to a Scenario: `method` accepts the
    benchmark preset names ("async-heloco", ...) or raw method names
    (``Scenario`` canonicalizes through the method registry)."""
    return Scenario(
        name=name, method=method,
        n_workers=len(paces),
        worker_paces=tuple(float(p) for p in paces),
        outer_steps=outer_steps, inner_steps=inner_steps,
        batch_size=batch_size, seq_len=seq_len,
        non_iid=non_iid, dylu=dylu, seed=seed,
        compression=compression, drop_stale_after=drop_stale_after,
        shard_assignment=shard_assignment, mixture_alpha=mixture_alpha,
        **scenario_kw)


def base_run(paces: Sequence[float], *, method: str, non_iid: bool,
             outer_steps: int, inner_steps: int, dylu: bool = False,
             seed: int = 0, compression: str = "none",
             drop_stale_after: Optional[int] = None,
             shard_assignment: str = "fixed") -> RunConfig:
    return scenario_for(
        paces, method=method, non_iid=non_iid, outer_steps=outer_steps,
        inner_steps=inner_steps, dylu=dylu, seed=seed,
        compression=compression, drop_stale_after=drop_stale_after,
        shard_assignment=shard_assignment).run_config()


def _key(rc: RunConfig, eval_every: int, engine: str = "sim",
         engine_kw: Optional[Dict] = None, eval_batch: int = 8,
         budget=None, telemetry: bool = False) -> str:
    blob = json.dumps(dataclasses.asdict(rc), sort_keys=True, default=str)
    # keep pre-engine cache keys stable for the default simulator/eval
    tag = ("" if engine == "sim"
           else engine + json.dumps(engine_kw or {}, sort_keys=True,
                                    default=str))
    if eval_batch != 8:
        tag += f"eb{eval_batch}"
    if budget is not None:
        tag += f"|budget:{budget.kind}:{budget.amount}"
    if telemetry:
        tag += "|telem"
    return hashlib.sha1((blob + str(eval_every) + tag).encode()
                        ).hexdigest()[:16]


def run_cached(name: str, rc: RunConfig, eval_every: int = 0,
               force: bool = False, engine: str = "sim",
               eval_batch: int = 8, budget=None,
               telemetry_path: Optional[str] = None, **engine_kw) -> Dict:
    """Run (or reload) one cached training run.

    budget: optional ``repro.async_engine.engine.Budget`` stopping rule —
    part of the cache key, applied via ``eng.run(budget=...)``.
    telemetry_path: when set, stream per-arrival update-quality telemetry
    (``repro.telemetry``) to this JSONL path; the cache is only reused if
    the stream file still exists alongside the result JSON.
    """
    os.makedirs(RESULTS_DIR, exist_ok=True)
    key = _key(rc, eval_every, engine, engine_kw, eval_batch, budget,
               telemetry_path is not None)
    path = os.path.join(RESULTS_DIR, f"{name}__{key}.json")
    if os.path.exists(path) and not force and (
            telemetry_path is None or os.path.exists(telemetry_path)):
        return json.load(open(path))
    rec = None
    if telemetry_path is not None:
        from repro.telemetry import RunMeta, TelemetryRecorder
        rec = TelemetryRecorder(meta=RunMeta(
            method=rc.outer.method, engine=engine,
            n_workers=rc.n_workers, outer_steps=rc.outer_steps,
            seed=rc.seed, non_iid=rc.non_iid,
            mixture_alpha=rc.mixture_alpha, scenario=name))
    eng = make_engine(rc, engine, telemetry=rec, **engine_kw)
    eval_fn = make_eval_fn(eng, batch=eval_batch, seq=rc.seq_len)
    t0 = time.time()
    hist = eng.run(eval_every=eval_every or max(rc.outer_steps // 8, 1),
                   eval_fn=eval_fn, budget=budget)
    out = {
        "name": name,
        "engine": engine,
        "config": {"paces": rc.worker_paces, "method": rc.outer.method,
                   "non_iid": rc.non_iid, "dylu": rc.dylu,
                   "outer_steps": rc.outer_steps,
                   "inner_steps": rc.inner_steps,
                   "compression": rc.outer.compression,
                   "drop_stale_after": rc.outer.drop_stale_after},
        "evals": hist.evals,
        "final_loss": hist.evals[-1]["mean"] if hist.evals else None,
        "per_lang": hist.evals[-1]["per_lang"] if hist.evals else None,
        "tokens": hist.tokens,
        "comm_bytes": hist.comm_bytes,
        "final_time": hist.final_time,
        "staleness": [a["staleness"] for a in hist.arrivals],
        "arrival_workers": [a["worker_id"] for a in hist.arrivals],
        "n_dropped": sum(1 for a in hist.arrivals if a.get("dropped")),
        "wall_seconds": time.time() - t0,
    }
    if budget is not None:
        out["budget"] = {"kind": budget.kind, "amount": budget.amount}
    if rec is not None:
        out["telemetry"] = rec.write_jsonl(telemetry_path)
        out["telemetry_summary"] = rec.summary()
    if hasattr(eng, "stats_summary"):
        out["runtime_stats"] = eng.stats_summary()
    json.dump(out, open(path, "w"), indent=1)
    return out


def run_cached_scenario(name: str, scn: Scenario, eval_every: int = 0,
                        force: bool = False, budget=None,
                        telemetry_path: Optional[str] = None) -> Dict:
    """run_cached driven entirely by a Scenario: engine choice, runtime
    options, and the eval cadence/batch all come from the spec, so the
    curve is comparable with the scenario's golden trace. ``budget`` and
    ``telemetry_path`` forward to :func:`run_cached` (the sweep harness
    entry point)."""
    m = scn.materialize()
    if m.failures or m.elastic:
        raise ValueError("run_cached_scenario does not cache runs with "
                         "failure/elastic schedules; use scn.build()")
    return run_cached(name, m.run_cfg,
                      eval_every=eval_every or scn.eval_cadence,
                      force=force, engine=m.engine,
                      eval_batch=scn.eval_batch, budget=budget,
                      telemetry_path=telemetry_path, **m.engine_kw)


def loss_at_time(result: Dict, t: float) -> Optional[float]:
    """Loss of the last eval snapshot at sim-time <= t."""
    best = None
    for e in result["evals"]:
        if e["time"] <= t + 1e-9:
            best = e["mean"]
    return best
