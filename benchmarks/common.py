"""Shared harness for the paper-reproduction benchmarks.

Each benchmark builds RunConfigs for the paper's methods, runs a training
engine (the event-driven simulator by default; pass engine="wallclock"
for the threaded concurrent runtime — same Engine API, real overlap), and
caches results as JSON under results/experiments/ so EXPERIMENTS.md
assembly and reruns are cheap.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import get_config, reduced
from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
from repro.async_engine.engine import make_engine, make_eval_fn

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results/experiments")

# paper Table 3 (Appendix A.5): outer lr / momentum / weight factor
METHODS = {
    "async-heloco": dict(method="heloco", outer_lr=0.7, momentum=0.9,
                         weight_factor="base", lookahead_init=True),
    "async-mla": dict(method="mla", outer_lr=0.7, momentum=0.9,
                      weight_factor="base", lookahead_init=True),
    "async-nesterov": dict(method="nesterov", outer_lr=0.07, momentum=0.9,
                           weight_factor="base", lookahead_init=False),
    "sync-nesterov": dict(method="sync_nesterov", outer_lr=0.7, momentum=0.9,
                          weight_factor="average", lookahead_init=False),
}


def base_run(paces: Sequence[float], *, method: str, non_iid: bool,
             outer_steps: int, inner_steps: int, dylu: bool = False,
             seed: int = 0, compression: str = "none",
             drop_stale_after: Optional[int] = None,
             shard_assignment: str = "fixed") -> RunConfig:
    model = reduced(get_config("tinygpt-15m"))
    outer = OuterOptConfig(compression=compression,
                           drop_stale_after=drop_stale_after,
                           **METHODS[method])
    total = outer_steps * inner_steps
    return RunConfig(
        model=model,
        inner=InnerOptConfig(lr=3e-3, warmup_steps=max(total // 20, 2),
                             total_steps=total),
        outer=outer,
        n_workers=len(paces), inner_steps=inner_steps,
        outer_steps=outer_steps, batch_size=4, seq_len=64,
        worker_paces=tuple(float(p) for p in paces),
        non_iid=non_iid, dylu=dylu, seed=seed,
        shard_assignment=shard_assignment)


def _key(rc: RunConfig, eval_every: int, engine: str = "sim",
         engine_kw: Optional[Dict] = None) -> str:
    blob = json.dumps(dataclasses.asdict(rc), sort_keys=True, default=str)
    # keep pre-engine cache keys stable for the default simulator
    tag = ("" if engine == "sim"
           else engine + json.dumps(engine_kw or {}, sort_keys=True,
                                    default=str))
    return hashlib.sha1((blob + str(eval_every) + tag).encode()
                        ).hexdigest()[:16]


def run_cached(name: str, rc: RunConfig, eval_every: int = 0,
               force: bool = False, engine: str = "sim",
               **engine_kw) -> Dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(
        RESULTS_DIR, f"{name}__{_key(rc, eval_every, engine, engine_kw)}.json")
    if os.path.exists(path) and not force:
        return json.load(open(path))
    eng = make_engine(rc, engine, **engine_kw)
    eval_fn = make_eval_fn(eng, batch=8, seq=rc.seq_len)
    t0 = time.time()
    hist = eng.run(eval_every=eval_every or max(rc.outer_steps // 8, 1),
                   eval_fn=eval_fn)
    out = {
        "name": name,
        "engine": engine,
        "config": {"paces": rc.worker_paces, "method": rc.outer.method,
                   "non_iid": rc.non_iid, "dylu": rc.dylu,
                   "outer_steps": rc.outer_steps,
                   "inner_steps": rc.inner_steps,
                   "compression": rc.outer.compression,
                   "drop_stale_after": rc.outer.drop_stale_after},
        "evals": hist.evals,
        "final_loss": hist.evals[-1]["mean"] if hist.evals else None,
        "per_lang": hist.evals[-1]["per_lang"] if hist.evals else None,
        "tokens": hist.tokens,
        "comm_bytes": hist.comm_bytes,
        "final_time": hist.final_time,
        "staleness": [a["staleness"] for a in hist.arrivals],
        "arrival_workers": [a["worker_id"] for a in hist.arrivals],
        "n_dropped": sum(1 for a in hist.arrivals if a.get("dropped")),
        "wall_seconds": time.time() - t0,
    }
    if hasattr(eng, "stats_summary"):
        out["runtime_stats"] = eng.stats_summary()
    json.dump(out, open(path, "w"), indent=1)
    return out


def loss_at_time(result: Dict, t: float) -> Optional[float]:
    """Loss of the last eval snapshot at sim-time <= t."""
    best = None
    for e in result["evals"]:
        if e["time"] <= t + 1e-9:
            best = e["mean"]
    return best
