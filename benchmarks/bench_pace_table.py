"""Paper Table 1: validation loss at a fixed token budget (left) and fixed
time budget (right) across worker-pace configurations, non-IID.

Reports L-HeLoCo / L-AMLA / L-AN / L-SN plus the paper's delta columns:
  dX  = relative improvement of HeLoCo over X at the full step budget
  TdX = relative improvement at matched wall-clock time T (T = HeLoCo's
        finishing time, as in the paper).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Sequence

from benchmarks.common import base_run, loss_at_time, run_cached

PACE_CONFIGS: List[Sequence[float]] = [
    (1, 1, 1, 1, 1),
    (1, 1, 1, 1, 2),
    (1, 1, 1, 1, 6),
    (1, 1, 1, 1, 15),
    (1, 2, 2, 2, 2),
    (1, 6, 6, 6, 6),
    (1, 15, 15, 15, 15),
]

ORDER = ("async-heloco", "async-mla", "async-nesterov", "sync-nesterov")


def run(outer_steps: int = 30, inner_steps: int = 8,
        configs: Sequence[Sequence[float]] = PACE_CONFIGS) -> Dict:
    out = {}
    for paces in configs:
        tag = "p" + "_".join(str(int(p)) for p in paces)
        for method in ORDER:
            rc = base_run(paces, method=method, non_iid=True,
                          outer_steps=outer_steps, inner_steps=inner_steps)
            out[f"{tag}/{method}"] = run_cached(f"table1_{tag}_{method}", rc)
    return out


def summarize(results: Dict,
              configs: Sequence[Sequence[float]] = PACE_CONFIGS) -> str:
    hdr = ("paces,L-HeLoCo,L-AMLA,L-AN,L-SN,dAMLA%,dAN%,dSN%,"
           "T,TdAMLA%,TdAN%,TdSN%")
    lines = [hdr]
    for paces in configs:
        tag = "p" + "_".join(str(int(p)) for p in paces)
        rs = {m: results[f"{tag}/{m}"] for m in ORDER}
        lh = rs["async-heloco"]["final_loss"]
        losses = [rs[m]["final_loss"] for m in ORDER]
        deltas = [100.0 * (l - lh) / l for l in losses[1:]]
        t_budget = rs["async-heloco"]["final_time"]
        tls = []
        for m in ORDER[1:]:
            lm = loss_at_time(rs[m], t_budget)
            tls.append(100.0 * (lm - lh) / lm if lm else float("nan"))
        lines.append(
            f"({'_'.join(str(int(p)) for p in paces)}),"
            + ",".join(f"{l:.3f}" for l in losses) + ","
            + ",".join(f"{d:+.2f}" for d in deltas)
            + f",{t_budget:.0f}," + ",".join(f"{d:+.2f}" for d in tls))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outer", type=int, default=30)
    ap.add_argument("--inner", type=int, default=8)
    args = ap.parse_args()
    results = run(args.outer, args.inner)
    print(summarize(results))


if __name__ == "__main__":
    main()
