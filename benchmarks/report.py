"""Bench-history trajectory report: render the accumulated
``results/bench/BENCH_*.json`` histories as markdown.

Every ``make bench`` / ``make bench-runtime`` run APPENDS an entry to the
history JSONs, so the per-PR perf trajectory is on disk — but nothing
rendered it. ``make bench-report`` turns each family into a markdown
table: one row per benchmark, one column per recorded entry (most recent
last, capped), plus the latest-vs-oldest ratio for timing rows. Exact
contract rows (launch counts / HBM bytes) are listed separately with
their current values — their history is only interesting when it
changes, which the regression gate already fails on.

    PYTHONPATH=src python -m benchmarks.report [--last N] [--out PATH]
"""
from __future__ import annotations

import argparse
import datetime
import os
import sys
from typing import Dict, List

from benchmarks.check_regression import _is_exact_row
from benchmarks.run import BENCH_JSON, BENCH_RUNTIME_JSON, _load_history

REPORT_MD = os.path.join(os.path.dirname(BENCH_JSON), "BENCH_REPORT.md")


def _stamp(entry: Dict) -> str:
    t = entry.get("unix_time")
    if not t:
        return "?"
    return datetime.datetime.fromtimestamp(t).strftime("%Y-%m-%d %H:%M")


def _trajectory(history: List[Dict], last: int) -> List[str]:
    entries = history[-last:]
    names: List[str] = []
    for e in entries:
        for r in e["rows"]:
            if r["name"] not in names:
                names.append(r["name"])
    by_entry = [{r["name"]: r for r in e["rows"]} for e in entries]
    stamps = [_stamp(e) for e in entries]

    timing = [n for n in names if not _is_exact_row(n)]
    exact = [n for n in names if _is_exact_row(n)]
    lines: List[str] = []

    lines.append(f"### Timing trajectory (us/call, {len(entries)} most "
                 "recent entries)")
    lines.append("")
    lines.append("| benchmark | " + " | ".join(stamps) + " | last/first |")
    lines.append("|---" * (len(entries) + 2) + "|")
    for n in timing:
        cells, seen = [], []
        for be in by_entry:
            r = be.get(n)
            if r is None:
                cells.append("—")
            else:
                cells.append(f"{r['us_per_call']:.1f}")
                seen.append(r["us_per_call"])
        ratio = (f"{seen[-1] / seen[0]:.2f}x"
                 if len(seen) >= 2 and seen[0] > 0 else "—")
        lines.append(f"| {n} | " + " | ".join(cells) + f" | {ratio} |")
    lines.append("")

    lines.append("### Exact contracts (current values; drift fails "
                 "`make bench-check`)")
    lines.append("")
    lines.append("| contract | value | meaning |")
    lines.append("|---|---|---|")
    latest = by_entry[-1] if by_entry else {}
    for n in exact:
        r = latest.get(n)
        if r is None:
            continue
        lines.append(f"| {n} | {r['us_per_call']:g} | {r['derived']} |")
    lines.append("")
    return lines


def render(last: int = 8) -> str:
    out = ["# Benchmark trajectory", ""]
    out.append("Rendered from the accumulated bench histories "
               "(`results/bench/BENCH_*.json`); regenerate with "
               "`make bench-report`.")
    out.append("")
    for title, path in (("Arrival path (`make bench`)", BENCH_JSON),
                        ("Runtime (`make bench-runtime`)",
                         BENCH_RUNTIME_JSON)):
        history = _load_history(path)
        out.append(f"## {title}")
        out.append("")
        if not history:
            out.append(f"(no history at {path})")
            out.append("")
            continue
        out.append(f"{len(history)} recorded entries, "
                   f"{_stamp(history[0])} -> {_stamp(history[-1])}.")
        out.append("")
        out += _trajectory(history, last)
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.report")
    ap.add_argument("--last", type=int, default=8,
                    help="columns: N most recent history entries")
    ap.add_argument("--out", default=REPORT_MD)
    args = ap.parse_args(argv)
    md = render(args.last)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(md)
    print(md)
    print(f"\n# report -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
