"""Roofline assembly: per (arch x shape) cell, combine
  - probes (results/probes/*.json): exact per-device FLOPs / HBM bytes /
    collective wire bytes, loop-corrected (see probes.py docstring), and
  - the production dry-run (results/dryrun/*__single.json): per-device
    memory proof + collective schedule inventory,
into the three roofline terms on TPU v5e constants:

    compute_s    = flops_per_device / 197e12        (bf16 MXU peak)
    memory_s     = hbm_bytes_per_device / 819e9     (HBM bandwidth)
    collective_s = wire_bytes_per_device / 50e9     (per-link ICI)

plus MODEL_FLOPS = 6*N*D (6*N_active*D for MoE) and the useful-compute
ratio MODEL_FLOPS / (HLO flops * chips).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link
CHIPS = 256                  # single-pod roofline

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def _param_counts(arch: str) -> Dict[str, float]:
    """total / active parameter counts (active: MoE experts scaled by top_k/E)."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    import jax
    from repro.configs import get_config
    from repro.launch.inputs import abstract_params
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    total = expert = 0
    for path, leaf in flat:
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        total += leaf.size
        if "moe/w_" in keys and "shared" not in keys:
            expert += leaf.size
    active = total - expert
    if cfg.is_moe and cfg.moe.n_experts:
        active += expert * cfg.moe.top_k / cfg.moe.n_experts
    out = {"total": float(total), "active": float(active)}
    _PARAM_CACHE[arch] = out
    return out


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    pc = _param_counts(arch)
    n = pc["active"] if cfg.is_moe else pc["total"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per row


def cell_roofline(arch: str, shape_name: str, probes_dir: str,
                  dryrun_dir: str) -> Optional[Dict]:
    ppath = os.path.join(probes_dir, f"{arch}__{shape_name}.json")
    dpath = os.path.join(dryrun_dir, f"{arch}__{shape_name}__single.json")
    if not os.path.exists(ppath):
        return None
    probe = json.load(open(ppath))
    if "skipped" in probe:
        return {"arch": arch, "shape": shape_name, "skipped": probe["skipped"]}
    if "error" in probe:
        return {"arch": arch, "shape": shape_name, "error": probe["error"]}
    t = probe["total_per_device"]
    compute_s = t["flops"] / PEAK_FLOPS
    memory_upper_s = t["bytes"] / HBM_BW         # pre-fusion operand bytes
    memory_s = (t["bytes_fused"] / HBM_BW        # post-fusion HBM estimate
                if t.get("bytes_fused") else memory_upper_s)
    coll_s = t["wire"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = model_flops(arch, shape_name)
    hlo_total = t["flops"] * CHIPS
    rec = {
        "arch": arch, "shape": shape_name,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_upper_s": memory_upper_s, "collective_s": coll_s,
        "dominant": dominant,
        "roofline_fraction": compute_s / step_time if step_time else 0.0,
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "mfu_bound": (mf / CHIPS / PEAK_FLOPS) / step_time if step_time else 0.0,
    }
    if os.path.exists(dpath):
        dr = json.load(open(dpath))
        if "memory" in dr:
            rec["peak_gib_per_device"] = dr["memory"]["peak_estimate_bytes"] / 2**30
            rec["fits_16g"] = rec["peak_gib_per_device"] <= 16.0
    return rec


def assemble(probes_dir: str = "results/probes",
             dryrun_dir: str = "results/dryrun"):
    from repro.configs import ASSIGNED, SHAPES
    rows = []
    for arch in ASSIGNED:
        for shape_name in SHAPES:
            r = cell_roofline(arch, shape_name, probes_dir, dryrun_dir)
            if r:
                rows.append(r)
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| roofline frac | MFU bound | useful ratio | peak GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped: {r['skipped']} | — | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"ERROR | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| {r['dominant']} | {r['roofline_fraction']:.2f} "
            f"| {r.get('mfu_bound', 0):.3f} | {r['useful_ratio']:.2f} "
            f"| {r.get('peak_gib_per_device', float('nan')):.2f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--probes", default="results/probes")
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    rows = assemble(args.probes, args.dryrun)
    os.makedirs(args.out, exist_ok=True)
    json.dump(rows, open(os.path.join(args.out, "roofline.json"), "w"),
              indent=1)
    md = to_markdown(rows)
    open(os.path.join(args.out, "roofline.md"), "w").write(md)
    print(md)


if __name__ == "__main__":
    main()
