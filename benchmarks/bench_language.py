"""Paper Fig. 3: per-language (per-shard) evaluation loss under non-IID
training with heterogeneous fixed-pace workers — shows how HeLoCo's gain
concentrates on the shards trained by stale workers."""
from __future__ import annotations

import argparse
from typing import Dict

from benchmarks.common import base_run, run_cached

HET_PACES = (0.74, 1.5, 3.0, 6.0, 7.5)


def run(outer_steps: int = 40, inner_steps: int = 10) -> Dict:
    out = {}
    for method in ("async-heloco", "async-mla", "async-nesterov",
                   "sync-nesterov"):
        rc = base_run(HET_PACES, method=method, non_iid=True,
                      outer_steps=outer_steps, inner_steps=inner_steps)
        out[method] = run_cached(f"fig3_{method}", rc)
    # DyLU row (paper: Async-DyLU)
    rc = base_run(HET_PACES, method="async-heloco", non_iid=True,
                  outer_steps=outer_steps, inner_steps=inner_steps, dylu=True)
    out["async-heloco+dylu"] = run_cached("fig3_async-heloco_dylu", rc)
    return out


def summarize(results: Dict) -> str:
    langs = sorted(next(iter(results.values()))["per_lang"].keys())
    lines = ["method," + ",".join(langs) + ",mean"]
    for m, r in results.items():
        per = r["per_lang"]
        lines.append(m + "," + ",".join(f"{per[l]:.4f}" for l in langs)
                     + f",{r['final_loss']:.4f}")
    # per-worker staleness summary (paper reports avg staleness per language)
    lines.append("")
    lines.append("method,worker,arrivals,mean_staleness")
    for m, r in results.items():
        per_w = {}
        for w, s in zip(r["arrival_workers"], r["staleness"]):
            per_w.setdefault(w, []).append(s)
        for w in sorted(per_w):
            ss = per_w[w]
            lines.append(f"{m},{w},{len(ss)},{sum(ss)/len(ss):.2f}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outer", type=int, default=40)
    ap.add_argument("--inner", type=int, default=10)
    args = ap.parse_args()
    print(summarize(run(args.outer, args.inner)))


if __name__ == "__main__":
    main()
