"""Kernel micro-bench: Pallas (interpret mode on CPU — correctness-path
timing, not TPU performance) vs the pure-jnp reference, plus HBM-traffic
accounting for the fused TPU kernels (the roofline-relevant number)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig
from repro.kernels import ops
from repro.kernels.ref import ref_heloco_correct, ref_outer_update

H = HeLoCoConfig()


def _time(fn, *args, reps=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> List[Dict]:
    n = 1 << 20
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (n,))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    g = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    rows = []
    rows.append({"name": "heloco_correct_ref_jnp",
                 "us_per_call": _time(jax.jit(
                     lambda a, b: ref_heloco_correct(a, b, H)), u, v),
                 "derived": f"d={n}"})
    rows.append({"name": "heloco_correct_pallas_interp",
                 "us_per_call": _time(
                     lambda a, b: ops.heloco_correct_block(a, b, H,
                                                           interpret=True),
                     u, v),
                 "derived": "interpret-mode (CPU correctness path)"})
    rows.append({"name": "outer_update_ref_jnp",
                 "us_per_call": _time(jax.jit(
                     lambda p, m, gg: ref_outer_update(p, m, gg, 0.7, 0.9, 1.0)),
                     u, v, g),
                 "derived": f"d={n}"})
    rows.append({"name": "outer_update_pallas_interp",
                 "us_per_call": _time(
                     lambda p, m, gg: ops.outer_update_block(
                         p, m, gg, 0.7, 0.9, 1.0, interpret=True), u, v, g),
                 "derived": "fused: 3 reads + 2 writes of d floats"})
    # HBM traffic accounting for the fused kernel vs unfused (TPU roofline)
    d_bytes = n * 4
    rows.append({"name": "outer_update_hbm_traffic",
                 "us_per_call": 0.0,
                 "derived": (f"fused={5 * d_bytes}B unfused={8 * d_bytes}B "
                             f"saving=37.5%")})
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
