"""Kernel micro-bench: Pallas (interpret mode on CPU — correctness-path
timing, not TPU performance) vs the pure-jnp reference, plus HBM-traffic
accounting for the fused TPU kernels (the roofline-relevant number)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig
from repro.kernels import ops
from repro.kernels.ref import ref_heloco_correct, ref_outer_update

H = HeLoCoConfig()


def _time(fn, *args, reps=5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> List[Dict]:
    n = 1 << 20
    key = jax.random.PRNGKey(0)
    u = jax.random.normal(key, (n,))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    g = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    rows = []
    rows.append({"name": "heloco_correct_ref_jnp",
                 "us_per_call": _time(jax.jit(
                     lambda a, b: ref_heloco_correct(a, b, H)), u, v),
                 "derived": f"d={n}"})
    rows.append({"name": "heloco_correct_pallas_interp",
                 "us_per_call": _time(
                     lambda a, b: ops.heloco_correct_block(a, b, H,
                                                           interpret=True),
                     u, v),
                 "derived": "interpret-mode (CPU correctness path)"})
    rows.append({"name": "outer_update_ref_jnp",
                 "us_per_call": _time(jax.jit(
                     lambda p, m, gg: ref_outer_update(p, m, gg, 0.7, 0.9, 1.0)),
                     u, v, g),
                 "derived": f"d={n}"})
    rows.append({"name": "outer_update_pallas_interp",
                 "us_per_call": _time(
                     lambda p, m, gg: ops.outer_update_block(
                         p, m, gg, 0.7, 0.9, 1.0, interpret=True), u, v, g),
                 "derived": "fused: 3 reads + 2 writes of d floats"})
    # HBM traffic accounting for the fused kernel vs unfused (TPU roofline)
    d_bytes = n * 4
    rows.append({"name": "outer_update_hbm_traffic",
                 "us_per_call": 0.0,
                 "derived": (f"fused={5 * d_bytes}B unfused={8 * d_bytes}B "
                             f"saving=37.5%")})
    rows.extend(packed_rows(n))
    return rows


def packed_rows(n: int) -> List[Dict]:
    """Packed-buffer kernels (one launch per sweep) vs their per-leaf
    equivalents at the same d: the packed stats sweep replaces one
    block_stats launch PER LEAF, and the fused correct+outer sweep
    replaces one correct_apply + one outer_update launch per leaf."""
    from repro.core import packing
    from repro.kernels import packed as pk

    key = jax.random.PRNGKey(3)
    tree = {f"b{i}": jax.random.normal(jax.random.fold_in(key, i),
                                       (n // 8,)) for i in range(8)}
    layout = packing.build_layout(tree)
    rb = jnp.asarray(layout.row_block)
    u2 = packing.pack(layout, tree)
    v2 = packing.pack(layout, jax.tree.map(lambda x: -x + 0.25, tree))
    g2 = packing.pack(layout, jax.tree.map(lambda x: 0.5 * x, tree))
    cu = jnp.ones((layout.n_rows, 1))
    cv = 0.5 * jnp.ones((layout.n_rows, 1))

    rows = [
        {"name": "packed_stats_pallas_interp",
         "us_per_call": _time(jax.jit(
             lambda a, b: pk.packed_stats(
                 a, b, rb, layout.n_blocks, interpret=True,
                 ranges=layout.block_row_ranges)), u2, v2),
         "derived": f"d={n} 8 blocks, ONE launch (was one per leaf)"},
        {"name": "packed_correct_outer_pallas_interp",
         "us_per_call": _time(jax.jit(
             lambda p, m, g: pk.packed_correct_outer(
                 p, m, g, cu, cv, 0.7, 0.9, 1.0, interpret=True)),
             u2, v2, g2),
         "derived": "fused Alg.2 + Eqs.17-19: 3 reads + 2 writes of d "
                    "floats, ONE launch"},
        {"name": "packed_hbm_traffic",
         "us_per_call": 0.0,
         "derived": (f"packed_arrival={9 * n * 4}B (pack 1R+1W, stats 2R, "
                     f"fused 3R+2W) per_leaf={10 * n * 4}B (stats 2R, "
                     "apply 2R+1W, outer 3R+2W)")},
    ]
    return rows


def main():
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")


if __name__ == "__main__":
    main()
