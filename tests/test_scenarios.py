"""Scenario layer: registry completeness, materialization as the single
source of truth, Dirichlet mixtures, golden-trace record/verify (incl.
tamper detection and cross-engine equality), and the benchmark
regression gate's tolerance bands."""
import copy
import json

import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.data.synthetic import ShardSampler, make_language_specs, \
    mixture_weights
from repro.scenarios import registry, trace
from repro.scenarios.spec import METHOD_TABLE, Scenario, load_pace_trace

TINY = Scenario(name="tiny_roundtrip", n_workers=3,
                worker_paces=(1.0, 2.0, 6.0), outer_steps=3, inner_steps=1,
                eval_batch=2)


# ---------------------------------------------------------------------------
# Registry completeness
# ---------------------------------------------------------------------------

def test_registry_names_and_axes():
    names = registry.names()
    assert len(names) >= 6
    assert len(set(names)) == len(names)
    for expected in ("paper_hetero_severe", "noniid_dirichlet",
                     "crash_rejoin", "elastic_membership", "int8_dylu",
                     "drop_stale", "wallclock_free"):
        assert expected in names, expected
    # at least one golden per comparison discipline
    assert any(s.engine == "sim" for s in registry.all_scenarios())
    assert any(s.engine == "wallclock" and s.exact
               for s in registry.all_scenarios())
    assert any(not s.exact for s in registry.all_scenarios())


def test_every_scenario_materializes():
    for s in registry.all_scenarios():
        m = s.materialize()
        assert isinstance(m.run_cfg, RunConfig)
        assert m.engine in ("sim", "wallclock")
        if m.engine == "sim":
            assert m.engine_kw == {}
        # trace-paced scenarios append the trace file's churn events
        tr = load_pace_trace(s.pace_trace) if s.pace_trace else {}
        assert len(m.failures) == len(s.failures) + len(tr.get("failures", []))
        assert len(m.elastic) == len(s.elastic) + len(tr.get("elastic", []))
        # description + paces cycle to n_workers
        assert s.description
        assert len(m.run_cfg.worker_paces) == s.n_workers


def test_scenario_method_presets_single_source():
    from benchmarks.common import METHODS, base_run, scenario_for
    assert METHODS["async-nesterov"]["outer_lr"] == \
        METHOD_TABLE["nesterov"]["outer_lr"] == 0.07
    assert METHODS["sync-nesterov"]["weight_factor"] == "average"
    # the benchmark dialect and the scenario path build the same RunConfig
    rc = base_run((1.0, 2.0), method="async-heloco", non_iid=True,
                  outer_steps=4, inner_steps=2)
    rc2 = scenario_for((1.0, 2.0), method="async-heloco", non_iid=True,
                       outer_steps=4, inner_steps=2).run_config()
    assert rc == rc2
    assert rc.outer.lookahead_init and rc.outer.outer_lr == 0.7
    assert rc.inner.total_steps == 8


def test_launcher_flags_compile_to_same_scenario():
    import argparse
    from repro.launch.train import scenario_from_args
    ns = argparse.Namespace(
        arch="tinygpt-15m", smoke=True, engine="sim", free=False,
        pace_scale=0.0, workers=2, paces="1,2", inner=2, outer=4, batch=4,
        seq=64, iid=False, mixture_alpha=None, shard_assignment="fixed",
        dylu=False, method="heloco", outer_lr=None, momentum=0.9,
        compression="none", drop_stale_after=None, inner_lr=3e-3, seed=0)
    from benchmarks.common import base_run
    rc = scenario_from_args(ns).run_config()
    assert rc == base_run((1.0, 2.0), method="async-heloco", non_iid=True,
                          outer_steps=4, inner_steps=2)


# ---------------------------------------------------------------------------
# Dirichlet language mixtures
# ---------------------------------------------------------------------------

def test_mixture_weights_deterministic_and_heterogeneous():
    w1 = mixture_weights(5, 0.3, wid=0, seed=0)
    w2 = mixture_weights(5, 0.3, wid=0, seed=0)
    w3 = mixture_weights(5, 0.3, wid=1, seed=0)
    np.testing.assert_array_equal(w1, w2)
    assert not np.array_equal(w1, w3)
    assert w1.shape == (5,) and abs(w1.sum() - 1.0) < 1e-12
    # small alpha concentrates mass (the severe non-IID end of the axis)
    assert mixture_weights(5, 0.05, wid=3, seed=0).max() > 0.8


def test_shard_sampler_mixture_path():
    specs = make_language_specs(128, n_langs=4, seed=0)
    mix = np.array([0.97, 0.01, 0.01, 0.01])
    s = ShardSampler(specs, lang_index=0, batch=16, seq=8, seed=7,
                     mixture=mix)
    b1, b2 = s.sample(0), s.sample(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # determinism
    # dominant language's private token range should dominate the batch
    spec0 = specs[0]
    frac0 = np.mean((b1["tokens"] >= spec0.lo) & (b1["tokens"] < spec0.hi))
    assert frac0 > 0.4, frac0


def test_engine_assigns_mixtures():
    scn = registry.get_scenario("noniid_dirichlet")
    m = scn.materialize()
    assert m.run_cfg.mixture_alpha == 0.3
    eng = scn.build()
    mixes = [w.mixture for w in eng.workers.values()]
    assert all(mx is not None for mx in mixes)
    assert len({tuple(mx) for mx in mixes}) == len(mixes)  # per-worker
    for w in eng.workers.values():
        assert w.lang == int(np.argmax(w.mixture))


# ---------------------------------------------------------------------------
# Golden traces: digests, round-trip, tamper detection
# ---------------------------------------------------------------------------

def test_param_digest_sensitivity():
    import jax.numpy as jnp
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "b": {"c": jnp.ones((4,), jnp.float32)}}
    d1 = trace.param_digest(params)
    bumped = {"a": params["a"].at[0, 0].add(1e-6), "b": params["b"]}
    assert d1 == trace.param_digest(
        {"a": params["a"] + 0, "b": {"c": params["b"]["c"] + 0}})
    assert d1 != trace.param_digest(bumped)
    fp = trace.param_fingerprint(params)
    assert set(map(len, fp.values())) == {2}


def test_record_verify_roundtrip_and_tamper(tmp_path, monkeypatch):
    # exact-mode semantics regardless of the environment (CI scopes
    # REPRO_GOLDEN_RTOL to the golden-verification steps, but be safe:
    # _RTOL is read once at module import)
    monkeypatch.setattr(trace, "_RTOL", 0.0)
    d = str(tmp_path)
    path = trace.record(TINY, d)
    doc = json.load(open(path))
    assert len(doc["arrivals"]) == TINY.outer_steps
    assert doc["exact"]
    # round-trip: the freshly recorded trace verifies against itself
    res = trace.verify(TINY, d, fresh=copy.deepcopy(doc))
    assert res.ok, res.failures

    # tamper 1: flip a staleness value in the golden file
    bad = copy.deepcopy(doc)
    bad["arrivals"][1][3] += 1
    json.dump(bad, open(path, "w"))
    res = trace.verify(TINY, d, fresh=copy.deepcopy(doc))
    assert not res.ok and any("staleness" in f for f in res.failures)

    # tamper 2: corrupt the final-param digest
    bad = copy.deepcopy(doc)
    bad["param_digest"] = "0" * 64
    json.dump(bad, open(path, "w"))
    res = trace.verify(TINY, d, fresh=copy.deepcopy(doc))
    assert not res.ok and any("param_digest" in f for f in res.failures)

    # tamper 3: drift an eval loss
    bad = copy.deepcopy(doc)
    bad["evals"][-1]["mean"] += 1e-4
    json.dump(bad, open(path, "w"))
    res = trace.verify(TINY, d, fresh=copy.deepcopy(doc))
    assert not res.ok and any("eval" in f for f in res.failures)

    # tamper 3b: per-language drift with the mean left untouched
    bad = copy.deepcopy(doc)
    lang = next(iter(bad["evals"][-1]["per_lang"]))
    bad["evals"][-1]["per_lang"][lang] += 1e-4
    json.dump(bad, open(path, "w"))
    res = trace.verify(TINY, d, fresh=copy.deepcopy(doc))
    assert not res.ok and any("per_lang" in f for f in res.failures)

    # tamper 4: the registered spec changed since recording
    json.dump(doc, open(path, "w"))
    changed = TINY.overridden(seed=123)
    res = trace.verify(changed, d, fresh=copy.deepcopy(doc))
    assert not res.ok and any("re-record" in f for f in res.failures)

    # diff artifact is written for CI upload
    diff = trace.write_diff(res, str(tmp_path / "diffs"))
    assert json.load(open(diff))["ok"] is False


@pytest.mark.wallclock
def test_free_mode_banded_verify(tmp_path):
    d = str(tmp_path)
    free = Scenario(name="tiny_free", engine="wallclock", mode="free",
                    pace_scale=0.0, n_workers=2, worker_paces=(1.0, 1.0),
                    outer_steps=2, inner_steps=1, eval_batch=2)
    path = trace.record(free, d)
    doc = json.load(open(path))
    assert not doc["exact"]
    ok = trace.verify(free, d, fresh=copy.deepcopy(doc))
    assert ok.ok, ok.failures
    # out-of-band drift is caught even without exactness
    drifted = copy.deepcopy(doc)
    drifted["tokens"] = doc["tokens"] * 3
    res = trace.verify(free, d, fresh=drifted)
    assert not res.ok and any("tokens" in f for f in res.failures)
    drifted = copy.deepcopy(doc)
    drifted["evals"][-1]["mean"] += 10.0
    res = trace.verify(free, d, fresh=drifted)
    assert not res.ok and any("drifted" in f for f in res.failures)


# ---------------------------------------------------------------------------
# Heavier lanes: real smoke-runs + cross-engine equality
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_every_registered_scenario_smoke_runs():
    """Registry completeness at the run level: every scenario builds an
    engine from its spec alone and completes a shrunken run."""
    for s in registry.all_scenarios():
        shrunk = s.overridden(outer_steps=2,
                              inner_steps=min(s.inner_steps, 2))
        eng = shrunk.build()
        hist = eng.run()
        assert len(hist.arrivals) == 2, s.name
        assert hist.tokens > 0, s.name


@pytest.mark.wallclock
def test_cross_engine_trace_equality_vs_sim_golden(tmp_path):
    """The determinism contract as a golden-trace artifact: replaying a
    sim-recorded golden on the deterministic wall-clock engine yields the
    identical arrival trace and fp32-close numerics."""
    d = str(tmp_path)
    trace.record(TINY, d)
    res = trace.verify(TINY, d, cross_engine=True)
    assert res.ok, res.failures
    # and the cross check actually bites: a tampered arrival is caught
    path = trace.golden_path(TINY.name, d)
    doc = json.load(open(path))
    doc["arrivals"][0][1] = 99
    json.dump(doc, open(path, "w"))
    res = trace.verify(TINY, d, cross_engine=True)
    assert not res.ok and any("wid" in f for f in res.failures)


# ---------------------------------------------------------------------------
# Benchmark regression gate
# ---------------------------------------------------------------------------

def test_check_regression_bands():
    from benchmarks.check_regression import check_rows
    base = [
        {"name": "arrival_packed_d8192", "us_per_call": 100.0,
         "derived": "2 launches"},
        {"name": "arrival_launches_packed", "us_per_call": 2.0,
         "derived": "pallas_calls=2"},
        {"name": "runtime/wallclock_free", "us_per_call": 1000.0,
         "derived": "x", "arrivals": 12, "compute_parallelism": 2.5,
         "overlap_max": 2},
    ]
    fresh_ok = copy.deepcopy(base)
    fresh_ok[0]["us_per_call"] = 250.0          # within 4x band
    assert check_rows(fresh_ok, base) == []

    slow = copy.deepcopy(base)
    slow[0]["us_per_call"] = 500.0              # > 4x: drift
    assert any("4x baseline" in f for f in check_rows(slow, base))

    mutated = copy.deepcopy(base)
    mutated[1]["us_per_call"] = 16.0            # launch-count contract
    assert any("exact metric" in f for f in check_rows(mutated, base))

    lost = copy.deepcopy(base)
    lost[2]["compute_parallelism"] = 0.9        # concurrency evaporated
    assert any("concurrency" in f for f in check_rows(lost, base))

    wrong_count = copy.deepcopy(base)
    wrong_count[2]["arrivals"] = 11
    assert any("arrivals" in f for f in check_rows(wrong_count, base))

    missing = [base[0]]
    assert any("missing" in f for f in check_rows(missing, base))


def test_bench_persist_routes_to_results(tmp_path, monkeypatch):
    import benchmarks.run as bench_run
    new = str(tmp_path / "results" / "bench" / "BENCH_arrival.json")
    legacy = str(tmp_path / "BENCH_arrival.json")
    json.dump([{"unix_time": 1.0, "rows": [{"name": "old"}]}],
              open(legacy, "w"))
    monkeypatch.setitem(bench_run._LEGACY, new, legacy)
    # legacy history is carried forward into the results/ location
    bench_run._persist([{"name": "fresh"}], path=new)
    hist = json.load(open(new))
    assert [e["rows"][0]["name"] for e in hist] == ["old", "fresh"]
    # subsequent writes read the new location, not legacy
    bench_run._persist([{"name": "fresh2"}], path=new)
    assert len(json.load(open(new))) == 3
