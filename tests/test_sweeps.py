"""Sweep subsystem: grid enumeration, budget specs, report math, and a
tiny end-to-end budgeted sweep through the cached runner."""
import json
import os

import pytest

from repro.sweeps import (
    BudgetSpec, SweepAxis, SweepSpec, comparison_tables, get_sweep,
    names, run_sweep,
)
from repro.telemetry import TelemetryRecorder


# ---------------------------------------------------------------------------
# Spec / grid enumeration
# ---------------------------------------------------------------------------

def test_registered_sweeps_enumerate():
    assert {"smoke", "paper_table2", "staleness_analysis"} <= set(names())
    for name in names():
        cells = get_sweep(name).cells()
        assert cells
        ids = [c.cell_id for c in cells]
        assert len(set(ids)) == len(ids)


def test_smoke_grid_shape_and_method_defaults():
    spec = get_sweep("smoke")
    cells = spec.cells()
    assert len(cells) == (len(spec.methods) * len(spec.scenarios)
                          * len(spec.budgets))
    for c in cells:
        # method swapped in with Table-3 defaults, budget binding
        assert c.scenario.method == c.method
        assert c.scenario.outer_lr is None
        assert c.scenario.outer_steps >= spec.outer_cap
        assert c.scenario.name == c.cell_id
    assert spec.baseline_method == "nesterov"


def test_axes_expand_the_grid_and_validate():
    spec = SweepSpec(name="t", methods=("heloco",),
                     scenarios=("paper_hetero_severe",),
                     budgets=(BudgetSpec("outer_steps", 4),),
                     axes=(SweepAxis("drop_stale_after", (None, 2)),
                           SweepAxis("inner_steps", (1, 2, 3))))
    cells = spec.cells()
    assert len(cells) == 6
    assert {c.scenario.inner_steps for c in cells} == {1, 2, 3}
    assert any(c.scenario.drop_stale_after == 2 for c in cells)
    # outer_steps budget -> exact step count, no Budget object
    assert all(c.scenario.outer_steps == 4 for c in cells)
    assert all(c.budget.to_budget() is None for c in cells)
    with pytest.raises(AssertionError):
        SweepAxis("not_a_scenario_field", (1,))


def test_budget_spec_labels_and_conversion():
    assert BudgetSpec("fixed_tokens", 512).label == "tok512"
    assert BudgetSpec("fixed_wallclock", 12.0).label == "sec12"
    assert BudgetSpec("outer_steps", 24).label == "steps24"
    b = BudgetSpec("fixed_tokens", 512).to_budget()
    assert b is not None and b.kind == "fixed_tokens"
    with pytest.raises(AssertionError):
        BudgetSpec("wat", 1)


def test_failure_scenarios_rejected():
    spec = SweepSpec(name="t", methods=("heloco",),
                     scenarios=("crash_rejoin",),
                     budgets=(BudgetSpec("fixed_tokens", 128),))
    with pytest.raises(ValueError):
        spec.cells()


# ---------------------------------------------------------------------------
# Report math (synthetic results: no training)
# ---------------------------------------------------------------------------

def _fake_doc():
    b = {"kind": "fixed_tokens", "amount": 256}
    def cell(method, loss):
        return {"cell_id": f"x__{method}", "base": "paper_hetero_severe",
                "method": method, "budget": b, "overrides": {},
                "final_loss": loss, "per_lang": {"de": loss},
                "tokens": 256, "final_time": 10.0, "arrivals": 4,
                "n_dropped": 0, "telemetry": None}
    return {"sweep": "x", "baseline": "nesterov",
            "methods": ["heloco", "nesterov"],
            "scenarios": ["paper_hetero_severe"],
            "budgets": [b],
            "cells": [cell("heloco", 3.8), cell("nesterov", 4.0)],
            "n_cells": 2, "wall_seconds": 1.0}


def test_comparison_table_percentages():
    tables = comparison_tables(_fake_doc())
    assert len(tables) == 1
    rows = tables[0]["rows"]
    col = "paper_hetero_severe"
    assert rows["nesterov"][col]["delta_pct"] is None      # baseline
    assert abs(rows["heloco"][col]["delta_pct"] - (-5.0)) < 1e-9


# ---------------------------------------------------------------------------
# End-to-end: a tiny budgeted sweep through the cached runner
# ---------------------------------------------------------------------------

def test_run_sweep_end_to_end(tmp_path, monkeypatch):
    from benchmarks import common
    monkeypatch.setattr(common, "RESULTS_DIR",
                        str(tmp_path / "experiments"))
    spec = SweepSpec(
        name="tiny",
        methods=("heloco", "nesterov"),
        scenarios=("paper_hetero_severe",),
        budgets=(BudgetSpec("fixed_tokens", 192),),
        outer_cap=12, baseline="nesterov")
    doc = run_sweep(spec, out_dir=str(tmp_path), verbose=False)
    assert doc["n_cells"] == 2
    for row in doc["cells"]:
        # the budget actually stopped the run (192 tokens = 3 rounds)
        assert 192 <= row["tokens"] < 192 + 64
        assert row["final_loss"] is not None
        # telemetry stream exists and parses through the typed schema
        rec = TelemetryRecorder.read_jsonl(row["telemetry"])
        assert len(rec.arrivals()) == row["arrivals"]
        assert rec.meta.method == row["method"]
    sweep_dir = tmp_path / "tiny"
    report = (sweep_dir / "report.md").read_text()
    assert "fixed token budget" in report
    assert "baseline" in report and "`heloco`" in report
    curves = json.loads((sweep_dir / "staleness_alignment.json"
                         ).read_text())["curves"]
    assert curves.get("heloco"), "no alignment curve from telemetry"
    assert all(
        set(pt) >= {"staleness", "n", "mean_cos_align"}
        for pts in curves.values() for pt in pts)
    # second invocation reuses the cache (no recompute)
    doc2 = run_sweep(spec, out_dir=str(tmp_path), verbose=False)
    assert [r["final_loss"] for r in doc2["cells"]] == \
        [r["final_loss"] for r in doc["cells"]]
    assert doc2["wall_seconds"] < doc["wall_seconds"] / 2


def test_run_sweep_by_name_resolves_registry():
    with pytest.raises(KeyError):
        run_sweep("not_a_sweep")
