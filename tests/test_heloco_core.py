"""Unit + property tests for the HeLoCo core math (paper Eqs. 5-19 and the
Appendix A.2 lemma invariants)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.utils.hypcompat import given, settings, st

from repro.configs.base import HeLoCoConfig
from repro.core.heloco import (
    OuterState, apply_arrival, block_correct, correct_block, init_outer_state,
    lookahead_init, outer_update,
)

H = HeLoCoConfig()  # paper defaults: c_ok=0.2, k_s=0.5, k_d=1.0, kappa=3, beta_max=0.5


def _vec(xs):
    return jnp.asarray(xs, jnp.float32)


# ---------------------------------------------------------------------------
# Branch behaviour (Alg. 2)
# ---------------------------------------------------------------------------

def test_aligned_block_unchanged():
    u = _vec([1.0, 2.0, 3.0])
    v = 0.5 * u  # cosine = 1 >= c_ok
    out = correct_block(u, v, H)
    np.testing.assert_allclose(np.asarray(out), np.asarray(u), rtol=1e-6)


def test_degenerate_blocks_pass_through():
    u = _vec([1.0, -1.0, 2.0])
    z = jnp.zeros(3)
    np.testing.assert_allclose(np.asarray(correct_block(u, z, H)),
                               np.asarray(u))
    np.testing.assert_allclose(np.asarray(correct_block(z, u, H)),
                               np.asarray(z))


def test_anti_aligned_matches_eq10():
    u = _vec([1.0, 0.0])
    v = _vec([-2.0, 0.0])          # cosine = -1
    nu, nv = 1.0, 2.0
    c = -1.0
    conf = nu / (nu + H.kappa * nv + H.eps)
    beta = min(H.k_s * (-c) * conf, H.beta_max)
    expected = np.array([1.0, 0.0]) - beta * c * nu * np.array([-1.0, 0.0])
    out = np.asarray(correct_block(u, v, H))
    np.testing.assert_allclose(out, expected, rtol=1e-6)
    # anti-momentum component shrank (less negative along v_hat)
    assert out @ np.array([-1.0, 0.0]) > float(u @ _vec([-1.0, 0.0]))


def test_weak_aligned_preserves_norm_and_rotates():
    u = _vec([1.0, 0.0])
    v = _vec([0.1, 1.0])           # small positive cosine < c_ok
    out = np.asarray(correct_block(u, v, H))
    np.testing.assert_allclose(np.linalg.norm(out), 1.0, rtol=1e-5)
    v_hat = np.asarray(v) / np.linalg.norm(v)
    c_before = float(u @ v_hat)
    c_after = float(out @ v_hat)
    assert 0 <= c_before < H.c_ok
    assert c_after >= c_before  # rotated toward momentum


# ---------------------------------------------------------------------------
# A.2 lemma invariants (property-based)
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=2, max_size=16),
       st.lists(st.floats(-10, 10, allow_nan=False), min_size=2, max_size=16),
       st.floats(0.0, 1.0), st.floats(0.01, 5.0), st.floats(0.01, 5.0))
def test_lemma_invariants(us, vs, c_ok, k_s, k_d):
    n = min(len(us), len(vs))
    u = _vec(us[:n])
    v = _vec(vs[:n])
    h = HeLoCoConfig(c_ok=c_ok, k_s=k_s, k_d=k_d, beta_max=1.0)
    out = correct_block(u, v, h)
    nu = float(jnp.linalg.norm(u))
    nv = float(jnp.linalg.norm(v))
    if nu < h.eps or nv < h.eps:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(u))
        return
    v_hat = np.asarray(v) / nv
    # (i) signed component along momentum never decreases
    assert float(np.asarray(out) @ v_hat) >= float(np.asarray(u) @ v_hat) - 1e-4 * max(nu, 1)
    # (ii) norm never amplified
    assert float(jnp.linalg.norm(out)) <= nu * (1 + 1e-5) + 1e-6


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 64))
def test_correction_invariants_gaussian(seed, dim):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    u = jax.random.normal(k1, (dim,))
    v = jax.random.normal(k2, (dim,))
    out = correct_block(u, v, H)
    v_hat = v / jnp.linalg.norm(v)
    assert float(out @ v_hat) >= float(u @ v_hat) - 1e-4
    assert float(jnp.linalg.norm(out)) <= float(jnp.linalg.norm(u)) * (1 + 1e-5)


# ---------------------------------------------------------------------------
# Pytree-level correction
# ---------------------------------------------------------------------------

def test_block_correct_treats_each_tensor_separately():
    delta = {"a": _vec([1.0, 0.0]), "b": _vec([0.0, 1.0])}
    mom = {"a": _vec([1.0, 0.0]), "b": _vec([0.0, -1.0])}
    out = block_correct(delta, mom, H)
    np.testing.assert_allclose(np.asarray(out["a"]), [1.0, 0.0])  # aligned: kept
    # b is anti-aligned: corrected, not equal to input
    assert not np.allclose(np.asarray(out["b"]), [0.0, 1.0])


def test_block_correct_stacked_axes_matches_per_layer():
    key = jax.random.PRNGKey(0)
    d = jax.random.normal(key, (3, 4, 5))      # 3 stacked layers
    m = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 5))
    stacked = block_correct({"w": d}, {"w": m}, H, stacked_axes={"w": 1})["w"]
    per = jnp.stack([correct_block(d[i], m[i], H) for i in range(3)])
    np.testing.assert_allclose(np.asarray(stacked), np.asarray(per), rtol=1e-6)
    # and WITHOUT stacked_axes the result differs (flattened as one block)
    flat = block_correct({"w": d}, {"w": m}, H)["w"]
    assert not np.allclose(np.asarray(flat), np.asarray(per), atol=1e-6)


# ---------------------------------------------------------------------------
# Outer update + look-ahead (Eqs. 5, 17-19)
# ---------------------------------------------------------------------------

def test_outer_update_matches_equations():
    params = {"w": _vec([1.0, 2.0])}
    state = init_outer_state(params)
    state = state._replace(momentum={"w": _vec([0.5, -0.5])})
    g = {"w": _vec([0.1, 0.2])}
    mu, eta, rho = 0.9, 0.7, 1.0
    new = outer_update(state, g, eta, mu, rho)
    m_exp = mu * np.array([0.5, -0.5]) + (1 - mu) * np.array([0.1, 0.2])
    p_exp = np.array([1.0, 2.0]) - eta * (np.array([0.1, 0.2]) + mu * m_exp)
    np.testing.assert_allclose(np.asarray(new.momentum["w"]), m_exp, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(new.params["w"]), p_exp, rtol=1e-6)
    assert int(new.step) == 1


def test_lookahead_init_eq5():
    params = {"w": _vec([1.0, 2.0])}
    state = init_outer_state(params)._replace(momentum={"w": _vec([1.0, -1.0])})
    bar = lookahead_init(state, outer_lr=0.7, mu=0.9)
    np.testing.assert_allclose(np.asarray(bar["w"]),
                               np.array([1.0, 2.0]) - 0.7 * 0.9 * np.array([1.0, -1.0]),
                               rtol=1e-6)


@pytest.mark.parametrize("method", ["heloco", "mla", "nesterov"])
def test_apply_arrival_runs(method):
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 4))}
    state = init_outer_state(params)
    delta = {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 4))}
    new = apply_arrival(state, delta, method=method, outer_lr=0.7, mu=0.9,
                        h=H, tau=3.0)
    assert int(new.step) == 1
    assert np.all(np.isfinite(np.asarray(new.params["w"])))
    assert not np.allclose(np.asarray(new.params["w"]),
                           np.asarray(params["w"]))


def test_heloco_equals_nesterov_when_aligned():
    """If every block is perfectly aligned with momentum, HeLoCo reduces to
    plain async Nesterov (blocks kept unchanged)."""
    params = {"w": _vec([1.0, 2.0, 3.0])}
    mom = {"w": _vec([0.2, 0.4, 0.6])}
    delta = {"w": _vec([0.1, 0.2, 0.3])}   # parallel to momentum
    state = init_outer_state(params)._replace(momentum=mom)
    a = apply_arrival(state, delta, method="heloco", outer_lr=0.7, mu=0.9, h=H)
    b = apply_arrival(state, delta, method="nesterov", outer_lr=0.7, mu=0.9, h=H)
    np.testing.assert_allclose(np.asarray(a.params["w"]),
                               np.asarray(b.params["w"]), rtol=1e-6)
