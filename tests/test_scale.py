"""Batched-arrival fast path (docs/scale.md): the K-stacked multi-apply
property-tested against K sequential applications for EVERY registered
outer method (random K / shapes / stacked axes / int8-quantized deltas,
telemetry moments against the per-leaf reference), the commit-buffer
semantics (K=1 byte-identity, idempotent redelivery, drop interleaving),
the event-queue compaction guarantee under a crash/rejoin storm at
N=1k, the history ring, and the hogwild batch-ramp-up accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils.hypcompat import given, settings, st

from repro.configs.base import HeLoCoConfig, OuterOptConfig
from repro.core import compression, methods as M, packing
from repro.core.heloco import (
    apply_arrival, apply_arrivals_packed, init_outer_state,
)
from repro.async_engine.engine import (
    HISTORY_WINDOW, EventQueue, History, WorkerArena,
)
from repro.async_engine.server import Synchronizer
from repro.telemetry.stats import reference_moments_multi

H = HeLoCoConfig()


def _tree(seed: int, stacked: bool):
    """Small mixed-shape param tree; optionally one scan-stacked leaf
    (stacked_axes=1) so the layout's per-slice blocks are exercised."""
    key = jax.random.PRNGKey(seed)
    shapes = {"w": (19, 7), "b": (133,), "s": (3, 5, 9)}
    tree = {k: jax.random.normal(jax.random.fold_in(key, i), s)
            for i, (k, s) in enumerate(shapes.items())}
    axes = {"w": 0, "b": 0, "s": 1 if stacked else 0}
    return tree, axes


def _deltas(seed: int, k: int, stacked: bool, int8: bool):
    out = []
    for j in range(k):
        d, _ = _tree(1000 + seed * 31 + j, stacked)
        d = jax.tree.map(lambda x: 0.05 * x, d)
        if int8:
            # what the server sees after the engine decodes the wire form
            d = compression.decompress(compression.compress(d, "int8"), d)
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# Property: batched K-apply == K sequential applies, every method
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=2, max_value=5),
       st.integers(min_value=0, max_value=3),
       st.integers(min_value=0, max_value=1),
       st.integers(min_value=0, max_value=1))
def test_multi_apply_matches_sequential_every_method(k, seed, stacked_i,
                                                     int8_i):
    stacked, int8 = bool(stacked_i), bool(int8_i)
    params, axes = _tree(seed, stacked)
    deltas = _deltas(seed, k, stacked, int8)
    layout = packing.build_layout(params, axes)
    rhos = [1.0 / np.sqrt(1.0 + (j % 3)) for j in range(k)]
    taus = [float(j % 3) for j in range(k)]
    for m in M.all_methods():
        phases = list(range(2, 2 + k)) if m.uses_buffer else [None] * k
        # per-leaf sequential reference (the paper-exact path)
        state = init_outer_state(params, with_aux=m.uses_buffer)
        for j in range(k):
            state = apply_arrival(state, deltas[j], method=m,
                                  outer_lr=0.7, mu=0.9, h=H, rho=rhos[j],
                                  tau=taus[j], stacked_axes=axes,
                                  phase=phases[j])
        ref_mom = reference_moments_multi(
            init_outer_state(params, with_aux=m.uses_buffer), deltas,
            method=m, outer_lr=0.7, mu=0.9, h=H, rhos=rhos, taus=taus,
            phases=phases if m.uses_buffer else None, stacked_axes=axes)
        # one fused multi-apply on the packed buffers
        pbuf = packing.pack(layout, params)
        mbuf = packing.zeros(layout)
        out = apply_arrivals_packed(
            pbuf, mbuf, deltas, layout, method=m, outer_lr=0.7, mu=0.9,
            h=H, rhos=rhos, taus=taus,
            abuf=packing.zeros(layout) if m.uses_buffer else None,
            phases=phases if m.uses_buffer else None, with_stats=True)
        if m.uses_buffer:
            p2, m2, a2, stats = out
            ref_aux = packing.pack(layout, state.aux)
            np.testing.assert_allclose(np.asarray(a2), np.asarray(ref_aux),
                                       atol=5e-6, rtol=1e-5,
                                       err_msg=f"{m.name} aux K={k}")
        else:
            p2, m2, stats = out
        got_p = packing.unpack(layout, p2)
        got_m = packing.unpack(layout, m2)
        for a, b in zip(jax.tree.leaves(got_p),
                        jax.tree.leaves(state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-6, rtol=1e-5,
                                       err_msg=f"{m.name} params K={k}")
        for a, b in zip(jax.tree.leaves(got_m),
                        jax.tree.leaves(state.momentum)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-6, rtol=1e-5,
                                       err_msg=f"{m.name} momentum K={k}")
        # (K, R, 4) kernel moments reduce to the (K, 4) per-leaf reference
        assert stats.shape[0] == k and stats.shape[-1] == 4
        np.testing.assert_allclose(np.asarray(jnp.sum(stats, axis=1)),
                                   np.asarray(ref_mom),
                                   atol=1e-3, rtol=1e-3,
                                   err_msg=f"{m.name} moments K={k}")


# ---------------------------------------------------------------------------
# Commit buffer semantics on the Synchronizer
# ---------------------------------------------------------------------------

def _params(d: int = 1024, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    return {f"b{i}": jax.random.normal(jax.random.fold_in(key, i), (d // 4,))
            for i in range(4)}


def _delta_list(n: int, d: int = 1024):
    key = jax.random.PRNGKey(7)
    return [jax.tree.map(
        lambda x: 0.01 * x,
        {f"b{i}": jax.random.normal(jax.random.fold_in(key, 10 * j + i),
                                    (d // 4,))
         for i in range(4)}) for j in range(n)]


def test_commit_batch_one_is_byte_identical():
    cfg = OuterOptConfig(method="heloco", delay_weighting=True)
    deltas = _delta_list(5)
    a = Synchronizer(_params(), cfg, n_workers=4, telemetry=True)
    b = Synchronizer(_params(), cfg, n_workers=4, telemetry=True,
                     commit_batch=1)
    recs_a, recs_b = [], []
    for i, d in enumerate(deltas):
        recs_a.append(a.on_arrival(d, max(0, a.t - 2), i % 4))
        out = b.buffer_arrival(d, max(0, b.t - 2), i % 4)
        assert out is not None and len(out) == 1   # K=1 flushes eagerly
        recs_b.extend(out)
    for x, y in zip(jax.tree.leaves(a.state.params),
                    jax.tree.leaves(b.state.params)):
        assert bool(jnp.all(x == y))               # bitwise, not approx
    assert [r.outer_step for r in recs_a] == [r.outer_step for r in recs_b]


def test_buffered_flush_matches_sequential_with_drops():
    deltas = _delta_list(7)
    for method in ("heloco", "delayed_nesterov", "dcasgd"):
        cfg = OuterOptConfig(method=method, delay_weighting=True,
                             drop_stale_after=1)
        a = Synchronizer(_params(), cfg, n_workers=4, telemetry=True)
        b = Synchronizer(_params(), cfg, n_workers=4, telemetry=True,
                         commit_batch=3)
        recs_a, recs_b = [], []
        for i, d in enumerate(deltas):
            s_i = max(0, i - (i % 3))              # staleness 0..2 -> drops
            recs_a.append(a.on_arrival(d, s_i, i % 4, commit_key=("k", i)))
            out = b.buffer_arrival(d, s_i, i % 4, commit_key=("k", i))
            if out:
                recs_b.extend(out)
        recs_b.extend(b.flush())
        assert a.t == b.t
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=5e-6, rtol=1e-5,
                                       err_msg=method)
        for x, y in zip(recs_a, recs_b):
            assert (x.outer_step, x.worker_id, x.staleness, x.dropped,
                    x.lang) == (y.outer_step, y.worker_id, y.staleness,
                                y.dropped, y.lang)
            assert x.rho == pytest.approx(y.rho)


def test_idempotent_redelivery_while_buffered():
    cfg = OuterOptConfig(method="heloco")
    s = Synchronizer(_params(), cfg, n_workers=4, commit_batch=8)
    d = _delta_list(1)[0]
    s.buffer_arrival(d, 0, 0, commit_key=("a", 0))
    s.buffer_arrival(d, 0, 0, commit_key=("a", 0))   # dup while pending
    assert s.pending == 1
    assert len(s.flush()) == 1 and s.t == 1
    # dup after commit: ledger short-circuits, nothing re-buffers
    assert s.buffer_arrival(d, 0, 0, commit_key=("a", 0)) is None
    assert s.pending == 0 and s.flush() == [] and s.t == 1


# ---------------------------------------------------------------------------
# Event queue: order, batching, compaction under a storm
# ---------------------------------------------------------------------------

def test_pop_batch_preserves_global_event_order():
    q = EventQueue()
    q.push(1.0, "return", 0, 0)
    q.push(1.0, "return", 1, 0)
    q.push(1.0, "restart", 2, 1)     # same tick, seq-interleaved
    q.push(1.0, "return", 3, 0)
    batch = q.pop_batch(8)           # stops BEFORE the restart
    assert [(w, k) for _, k, w, _ in batch] == [(0, "return"),
                                                (1, "return")]
    assert [k for _, k, _, _ in q.pop_batch(8)] == ["restart"]
    assert [w for _, _, w, _ in q.pop_batch(8)] == [3]


def test_queue_compacts_under_crash_rejoin_storm_n1000():
    """N=1k storm: orphaned in-flight returns must be compacted away
    (never quadratically re-popped) once they outnumber live entries."""
    n = 1000
    q = EventQueue()
    alive_gen = {w: 0 for w in range(n)}
    for w in range(n):
        q.push(1.0 + (w % 5), "return", w, 0)

    def live(kind, wid, gen):
        return kind == "restart" or alive_gen[wid] == gen

    # storm: 900 workers crash; the engine reports each orphaned round
    for w in range(900):
        alive_gen[w] = 1
        q.note_stale()
        q.maybe_compact(live)
    assert q.compactions >= 1        # dead entries never pile up past n/2
    for w in range(900):             # ...and they all rejoin
        q.push(7.0 + (w % 3), "restart", w, 1)
    # drain: at most a bounded remnant of dead returns can reach a pop
    popped_dead = 0
    while len(q):
        for _, kind, wid, gen in q.pop_batch(64):
            if kind == "return" and alive_gen[wid] != gen:
                popped_dead += 1
                q.note_skip()
    assert popped_dead <= 64          # bounded, not O(storm size)
    assert q.stale_skipped == popped_dead


def test_engine_crash_storm_compacts_and_completes():
    """End-to-end: a two-wave crash/rejoin storm over 40 slow workers
    (their orphaned returns pile up BEHIND the fast survivors' events)
    drives the engine's own compaction, and the run still completes its
    outer-step budget on the 8 survivors."""
    from repro.scenarios.spec import FailureSpec, Scenario
    waves = tuple(FailureSpec(time=t, wid=w, restart_delay=0.25)
                  for t in (0.3, 0.7) for w in range(40))
    scn = Scenario(name="_storm", n_workers=48,
                   worker_paces=(2.0,) * 40 + (0.2,) * 8,
                   outer_steps=30, inner_steps=1, batch_size=1, seq_len=16,
                   commit_batch=8, failures=waves)
    eng = scn.build()
    eng.run(eval_fn=None)
    assert eng.server.t == 30
    assert eng._events.compactions >= 1
    assert eng._events.stale_skipped <= 2 * 48    # bounded by membership


# ---------------------------------------------------------------------------
# Worker arena + history ring
# ---------------------------------------------------------------------------

def test_worker_arena_grows_and_recycles_slots():
    arena = WorkerArena(2)
    slots = [arena.alloc(w) for w in range(5)]     # forces growth
    assert len(set(slots)) == 5 and arena.n_alive() == 5
    arena.cols["pace"][slots[3]] = 9.0
    assert arena.min_alive_pace() == 1.0
    arena.release(slots[0])
    assert arena.n_alive() == 4
    s = arena.alloc(17)                            # recycled slot, defaults
    assert arena.cols["wid"][s] == 17
    assert arena.cols["pace"][s] == 1.0 and arena.cols["alive"][s]


def test_history_ring_bounds_memory_but_counts_everything():
    h = History(window=10)
    for i in range(25):
        h.append_arrival({"outer_step": i + 1})
    assert len(h.arrivals) == 10
    assert h.arrivals[0]["outer_step"] == 16       # oldest kept
    assert h.total_arrivals == 25
    assert h.summary()["outer_steps"] == 25
    assert History().window == HISTORY_WINDOW


# ---------------------------------------------------------------------------
# Hogwild ramp-up + committed pace traces
# ---------------------------------------------------------------------------

def test_batch_rampup_token_accounting():
    from repro.scenarios.registry import get_scenario
    scn = get_scenario("hogwild_rampup")
    base = scn.overridden(name="_flat", batch_rampup=None)
    eng_r, eng_b = scn.build(), base.build()
    eng_r.run(eval_every=scn.outer_steps, eval_fn=None)
    eng_b.run(eval_every=scn.outer_steps, eval_fn=None)
    flat = (eng_b.history.total_arrivals * scn.inner_steps
            * scn.batch_size * scn.seq_len)
    assert eng_b.history.tokens == flat
    # the ramp trains strictly more tokens on the same arrival count,
    # bounded by the target batch
    assert eng_r.history.total_arrivals == eng_b.history.total_arrivals
    cap = (eng_r.history.total_arrivals * scn.inner_steps
           * scn.batch_rampup * scn.seq_len)
    assert flat < eng_r.history.tokens <= cap


def test_pace_trace_drives_paces_and_churn():
    from repro.scenarios.registry import get_scenario
    from repro.scenarios.spec import load_pace_trace
    scn = get_scenario("trace_paced")
    tr = load_pace_trace(scn.pace_trace)
    assert scn.paces == tuple(tr["paces"][i % len(tr["paces"])]
                              for i in range(scn.n_workers))
    m = scn.materialize()
    assert any(f.wid == 4 for f in m.failures)     # from the trace file
    acts = {(e.action, e.wid) for e in m.elastic}
    assert ("join", 11) in acts and ("leave", 6) in acts
    assert m.run_cfg.commit_batch == 4
