"""Per-kernel validation: sweep shapes/dtypes and assert_allclose against
the pure-jnp oracles in repro/kernels/ref.py. Kernels run in interpret
mode on CPU with a single-step grid (see kernels.tiling.row_tile); the
multi-step TPU index maps are exercised via the explicit ``rows=``
override (test_packed.py::test_multi_step_grid_matches_single_step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.utils.hypcompat import given, settings, st

from repro.configs.base import HeLoCoConfig
from repro.kernels import ops
from repro.kernels.ref import (
    ref_dequantize, ref_heloco_correct, ref_outer_update, ref_quantize,
)

H = HeLoCoConfig()

SHAPES = [(7,), (128,), (129,), (4, 33), (256, 128), (3, 5, 64), (1000, 130)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
           dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_heloco_correct_kernel(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31))
    u = jax.random.normal(k1, shape, jnp.float32).astype(dtype)
    v = jax.random.normal(k2, shape, jnp.float32).astype(dtype)
    got = ops.heloco_correct_block(u, v, H, interpret=True)
    want = ref_heloco_correct(u, v, H)
    assert got.shape == shape and got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("case", ["aligned", "anti", "weak", "zero_u", "zero_v"])
def test_heloco_correct_kernel_branches(case):
    base = jnp.arange(1.0, 513.0)
    u, v = {
        "aligned": (base, 2 * base),
        "anti": (base, -base),
        "weak": (base, jnp.roll(base, 256) - base.mean()),
        "zero_u": (jnp.zeros_like(base), base),
        "zero_v": (base, jnp.zeros_like(base)),
    }[case]
    got = ops.heloco_correct_block(u, v, H, interpret=True)
    want = ref_heloco_correct(u, v, H)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 5000), st.integers(0, 2**31 - 1))
def test_heloco_correct_kernel_property(n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    u = jax.random.normal(k1, (n,))
    v = jax.random.normal(k2, (n,))
    got = ops.heloco_correct_block(u, v, H, interpret=True)
    want = ref_heloco_correct(u, v, H)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_outer_update_kernel(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    p = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    m = jax.random.normal(ks[1], shape, jnp.float32)
    g = jax.random.normal(ks[2], shape, jnp.float32)
    got_p, got_m = ops.outer_update_block(p, m, g, 0.7, 0.9, 0.447,
                                          interpret=True)
    want_p, want_m = ref_outer_update(p, m, g, 0.7, 0.9, 0.447)
    assert got_p.dtype == p.dtype and got_m.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got_p, np.float32),
                               np.asarray(want_p, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(got_m), np.asarray(want_m),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
def test_quantize_roundtrip_kernel(shape):
    x = jax.random.normal(jax.random.PRNGKey(3), shape) * 5.0
    q2d, scale, _ = ops.quantize_block(x, interpret=True)
    assert q2d.dtype == jnp.int8
    got = ops.dequantize_block(q2d, scale, shape, interpret=True)
    want = ref_dequantize(*ref_quantize(x)).reshape(shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # quantization error bounded by scale/2 per element
    err = np.abs(np.asarray(got) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_kernel_path_equals_core_in_block_correct():
    """core.block_correct(use_kernel=True) must match the jnp path."""
    from repro.core.heloco import block_correct
    key = jax.random.PRNGKey(0)
    delta = {"a": jax.random.normal(key, (40, 30)),
             "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (17,))}}
    mom = jax.tree.map(lambda x: -x + 0.3, delta)
    a = block_correct(delta, mom, H, use_kernel=False)
    b = block_correct(delta, mom, H, use_kernel=True)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Pallas flash-attention forward kernel vs naive softmax oracle
# ---------------------------------------------------------------------------

def _naive_attn(q, k, v, causal):
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * q.shape[-1] ** -0.5
    if causal:
        qi = jnp.arange(q.shape[1])[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        s = jnp.where(ki <= qi, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(q.dtype), v)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 128, 64), (1, 256, 128), (3, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_kernel(causal, shape, dtype):
    from repro.kernels.flash_attention import flash_attention_fwd
    bh, s, d = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], shape, jnp.float32).astype(dtype)
    got = flash_attention_fwd(q, k, v, causal=causal, q_chunk=64,
                              kv_chunk=128, interpret=True)
    want = _naive_attn(q.astype(jnp.float32), k.astype(jnp.float32),
                       v.astype(jnp.float32), causal)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_flash_fwd_kernel_rectangular():
    """Sq != Skv (prefill-continuation shape) + uneven chunking."""
    from repro.kernels.flash_attention import flash_attention_fwd
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 128, 64))
    k = jax.random.normal(ks[1], (2, 384, 64))
    v = jax.random.normal(ks[2], (2, 384, 64))
    got = flash_attention_fwd(q, k, v, causal=False, q_chunk=32,
                              kv_chunk=128, interpret=True)
    want = _naive_attn(q, k, v, False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
