"""Observability subsystem: tail/follow reader robustness, the
forward-compatible StreamDecoder (version check + skipped-unknown
accounting), the recorder's live-sink/bounded-ring memory contract,
Chrome trace-event export, the operator console's headless render over
the committed chaos_partition golden stream, and the byte-identity
contract: a golden scenario run with telemetry + tracing + runtime
records enabled must still verify against its committed golden."""
import json
import os
import threading
import time

import pytest

from repro.obs.console import ConsoleState, render, sparkline
from repro.obs.spans import (
    NULL_TRACER, SpanTracer, validate_chrome_trace,
)
from repro.obs.tail import TailReader, read_complete_lines
from repro.telemetry import (
    DEFAULT_WINDOW, RunMeta, RuntimeMetrics, StreamDecoder,
    TelemetryRecorder, schema,
)

GOLDEN_STREAM = os.path.join(os.path.dirname(__file__), os.pardir,
                             "results", "golden", "streams",
                             "chaos_partition.jsonl")


# ---------------------------------------------------------------------------
# Tail / follow reader
# ---------------------------------------------------------------------------

def test_tail_holds_back_partial_trailing_line(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text('{"a": 1}\n{"b": 2')          # second record still mid-write
    r = TailReader(str(p))
    assert r.read_available() == ['{"a": 1}']
    assert r.read_available() == []             # partial line stays buffered
    with open(p, "a") as f:
        f.write('}\n{"c": 3}\n')
    assert r.read_available() == ['{"b": 2}', '{"c": 3}']
    r.close()


def test_tail_restarts_on_truncation(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text("one\ntwo\nthree\n")
    r = TailReader(str(p))
    assert r.read_available() == ["one", "two", "three"]
    p.write_text("fresh\n")                     # rerun over the same path
    assert r.read_available() == ["fresh"]
    r.close()


def test_tail_follows_rotation_to_new_inode(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text("old\n")
    r = TailReader(str(p))
    assert r.read_available() == ["old"]
    os.rename(p, tmp_path / "s.jsonl.1")        # rotate
    (tmp_path / "s.jsonl").write_text("new\n")
    # allow same-inode reuse on exotic filesystems: poll a couple times
    got = r.read_available() or r.read_available()
    assert got == ["new"]
    r.close()


def test_tail_waits_for_missing_file(tmp_path):
    p = tmp_path / "later.jsonl"
    r = TailReader(str(p))
    assert r.read_available() == []             # not an error
    p.write_text("here\n")
    assert r.read_available() == ["here"]
    r.close()


def test_follow_drains_after_stop_and_survives_concurrent_writer(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text("")
    stop = threading.Event()
    got = []

    def writer():
        with open(p, "a") as f:
            for i in range(20):
                f.write(f"line-{i}\n")
                f.flush()
                time.sleep(0.002)
        stop.set()

    t = threading.Thread(target=writer)
    t.start()
    r = TailReader(str(p), poll=0.005)
    for ln in r.follow(stop=stop.is_set):
        got.append(ln)
    t.join()
    r.close()
    # final drain after stop => nothing written before stop is lost
    assert got == [f"line-{i}" for i in range(20)]


def test_read_complete_lines_drops_partial_tail(tmp_path):
    p = tmp_path / "s.jsonl"
    p.write_text("a\nb\ncut-off-no-newline")
    assert read_complete_lines(str(p)) == ["a", "b"]


# ---------------------------------------------------------------------------
# StreamDecoder: forward-compat version check + drift accounting
# ---------------------------------------------------------------------------

def _meta_line(version: int) -> str:
    d = json.loads(schema.to_json_line(RunMeta(
        method="heloco", engine="sim", n_workers=2, outer_steps=4, seed=0)))
    d["schema_version"] = version
    return json.dumps(d)


def test_decoder_counts_unknown_kinds_and_keys_from_newer_stream():
    dec = StreamDecoder()
    assert dec.decode(_meta_line(schema.SCHEMA_VERSION + 1)) is not None
    assert dec.newer_stream
    # a record kind this reader has never heard of
    assert dec.decode('{"kind": "gpu_power", "watts": 412.0}') is None
    # a known kind with a field from the future
    line = json.dumps({"kind": "eval", "outer_step": 4, "sim_time": 1.0,
                       "wall_time": 2.0, "mean_loss": 3.5,
                       "per_lang": {}, "perplexity_v4": 33.1})
    rec = dec.decode(line)
    assert rec is not None and rec.mean_loss == 3.5
    assert dec.unknown_kinds == {"gpu_power": 1}
    assert dec.unknown_keys == {"eval.perplexity_v4": 1}
    report = "\n".join(dec.drift_report())
    assert f"v{schema.SCHEMA_VERSION + 1} > reader" in report
    assert "gpu_power" in report and "eval.perplexity_v4" in report


def test_decoder_strict_raises_on_same_version_drift_only():
    strict = StreamDecoder(strict=True)
    strict.decode(_meta_line(schema.SCHEMA_VERSION))
    with pytest.raises(ValueError, match="unknown"):
        strict.decode('{"kind": "gpu_power", "watts": 1.0}')
    # ... but a declared-NEWER stream is tolerated-and-counted even strict
    newer = StreamDecoder(strict=True)
    newer.decode(_meta_line(schema.SCHEMA_VERSION + 2))
    assert newer.decode('{"kind": "gpu_power", "watts": 1.0}') is None
    assert newer.unknown_kinds["gpu_power"] == 1


def test_decoder_tolerates_bad_lines_and_missing_required_fields():
    dec = StreamDecoder()
    assert dec.decode("") is None
    assert dec.decode('{"kind": "arrival"') is None          # torn JSON
    assert dec.decode('{"kind": "eval", "outer_step": 1}') is None  # missing
    assert dec.bad_lines == 2
    assert any("undecodable" in s for s in dec.drift_report())


# ---------------------------------------------------------------------------
# Recorder: live sink + bounded ring (the memory contract)
# ---------------------------------------------------------------------------

def _fake_arrival(i):
    class A:
        outer_step = i
        worker_id = i % 2
        staleness = 0
        rho = 1.0
        sim_time = float(i)
        lang = "en"
        dropped = False
    return A()


def test_recorder_sink_streams_full_stream_but_bounds_memory(tmp_path):
    sink = str(tmp_path / "live.jsonl")
    rec = TelemetryRecorder(sink=sink, window=8)
    rec.ensure_meta(method="heloco", engine="sim", n_workers=2,
                    outer_steps=64, seed=0)
    for i in range(64):
        rec.record_arrival(_fake_arrival(i))
    assert len(rec.records) == 8                 # bounded ring
    # ... but the on-disk stream is complete and live (no close needed)
    lines = read_complete_lines(sink)
    assert len(lines) == 65                      # meta + 64 arrivals
    # write_jsonl copies the FULL stream, not the ring
    out = str(tmp_path / "copy.jsonl")
    rec.write_jsonl(out)
    assert len(read_complete_lines(out)) == 65
    rec.close()
    rec.close()                                  # idempotent
    dec = StreamDecoder(strict=True)
    for ln in lines:
        assert dec.decode(ln) is not None
    assert dec.meta is not None and not dec.drift_report()


def test_recorder_without_sink_keeps_unbounded_list():
    rec = TelemetryRecorder()
    for i in range(DEFAULT_WINDOW + 10):
        rec.record_arrival(_fake_arrival(i))
    assert isinstance(rec.records, list)
    assert len(rec.records) == DEFAULT_WINDOW + 10


def test_runtime_record_roundtrip():
    rec = TelemetryRecorder()
    rec.record_runtime(outer_step=7, sim_time=3.0, workers_alive=3,
                       workers_total=4, queue_depth=2,
                       liveness={"dead": 1},
                       delivery={"retries": 5.0})
    (rt,) = rec.runtime_records()
    line = schema.to_json_line(rt)
    back = schema.from_json_line(line)
    assert isinstance(back, RuntimeMetrics)
    assert back.workers_alive == 3 and back.delivery == {"retries": 5.0}


# ---------------------------------------------------------------------------
# Span tracer + Chrome trace export
# ---------------------------------------------------------------------------

def test_span_tracer_exports_valid_chrome_trace_with_thread_names():
    tr = SpanTracer()
    with tr.span("outer", cat="engine", step=1):
        with tr.span("inner", cat="compute"):
            pass
    tr.instant("retry", cat="transport", wid=3)

    def worker():
        with tr.span("worker_round", cat="compute", wid=0):
            pass

    t = threading.Thread(target=worker, name="heloco-worker-0")
    t.start()
    t.join()
    assert len(tr) == 4
    doc = tr.to_chrome()
    assert validate_chrome_trace(doc) == []
    names = [e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M" and e["name"] == "thread_name"]
    assert "heloco-worker-0" in names
    spans = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert {e["name"] for e in spans} == {"outer", "inner", "worker_round"}
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in spans)
    # nesting: inner ends no later than outer
    by = {e["name"]: e for e in spans}
    assert (by["inner"]["ts"] + by["inner"]["dur"]
            <= by["outer"]["ts"] + by["outer"]["dur"] + 1e-3)


def test_span_tracer_write_roundtrip(tmp_path):
    tr = SpanTracer()
    with tr.span("s"):
        pass
    path = tr.write(str(tmp_path / "t.trace.json"))
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == []


def test_null_tracer_is_inert():
    with NULL_TRACER.span("anything", wid=1):
        pass
    NULL_TRACER.instant("x")
    assert len(NULL_TRACER) == 0
    with pytest.raises(RuntimeError):
        NULL_TRACER.write("/nonexistent/nope.json")


def test_validate_chrome_trace_rejects_malformed():
    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": []}) != []
    no_dur = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0,
                               "pid": 0, "tid": 0}]}
    assert any("dur" in p for p in validate_chrome_trace(no_dur))
    meta_only = {"traceEvents": [{"name": "process_name", "ph": "M",
                                  "pid": 0, "args": {"name": "p"}}]}
    assert any("no complete" in p for p in validate_chrome_trace(meta_only))


# ---------------------------------------------------------------------------
# Operator console (headless) over the committed golden stream
# ---------------------------------------------------------------------------

def _console_over(lines):
    state = ConsoleState()
    for ln in lines:
        state.add_line(ln)
    return state, render(state, color=False)


def test_console_once_renders_committed_chaos_partition_stream():
    lines = read_complete_lines(GOLDEN_STREAM)
    assert lines, f"missing committed stream {GOLDEN_STREAM}"
    state, out = _console_over(lines)
    assert state.meta is not None and state.meta.scenario == "chaos_partition"
    # every panel the chaos scenario exercises is present — including the
    # cross-process transport + commit-buffer panels the socket-recorded
    # reference stream carries
    for needle in ("HeLoCo operator console", "chaos_partition",
                   "staleness histogram", "cos(D,m)", "per-language loss",
                   "workers", "runtime health", "delivery / chaos",
                   "transport (per worker process)",
                   "commit-buffer flushes"):
        assert needle in out, f"panel {needle!r} missing:\n{out}"
    # the partitioned worker (wid 3, black-holed from t=2.0) shows dead
    assert state.workers[3]["state"] == "dead"
    assert "dead" in out
    # delivery counters from the runtime records made it to the panel
    # (child-side injection: liveness recovery + dedup, not parent drops)
    assert "liveness_deaths" in out and "redelivered_deduped" in out
    # transport records from every worker process — including the
    # partitioned one: obs frames ride the raw control channel, not the
    # fault-injected data path
    assert len(state.transport) >= 2
    assert any(wid == 3 for wid, _pid in state.transport)
    assert state.n_flushes >= 1 and "batch-full" in out
    # a clean committed stream renders no drift footer
    assert "schema drift" not in out
    assert state.decoder.stream_version == schema.SCHEMA_VERSION


def test_console_surfaces_unknown_kind_instead_of_crashing():
    lines = [_meta_line(schema.SCHEMA_VERSION + 1),
             '{"kind": "quantum_flux", "q": 1}']
    state, out = _console_over(lines)
    assert "schema drift" in out and "quantum_flux" in out


def test_console_cli_once_smoke(capsys):
    from repro.obs.console import main as console_main
    assert console_main([GOLDEN_STREAM, "--once"]) == 0
    out = capsys.readouterr().out
    assert "HeLoCo operator console" in out and "chaos_partition" in out


def test_trace_cli_validate(tmp_path, capsys):
    from repro.obs.__main__ import main as obs_main
    tr = SpanTracer()
    with tr.span("s"):
        pass
    p = tr.write(str(tmp_path / "t.json"))
    assert obs_main(["trace", p, "--validate"]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text('{"traceEvents": [{"ph": "X"}]}')
    capsys.readouterr()
    assert obs_main(["trace", str(bad), "--validate"]) == 1


def test_sparkline_shape():
    assert sparkline([]) == ""
    s = sparkline([0, 1, 2, 3], width=4)
    assert len(s) == 4 and s[0] == "▁" and s[-1] == "█"
    assert sparkline([5.0] * 3) == "▁▁▁"        # constant series: no crash


# ---------------------------------------------------------------------------
# Schema v4 forward compatibility: a v3-era reader over a v4 stream
# ---------------------------------------------------------------------------

def test_v3_reader_skips_but_counts_v4_transport_and_flush_records(
        monkeypatch):
    """A PR-7-era (schema v3) StreamDecoder over today's committed v4
    reference stream — which carries `transport` and `flush` records —
    must skip-but-COUNT the new kinds, keep decoding every kind it
    knows, and surface the version gap in the drift report instead of
    silently thinning the stream."""
    monkeypatch.setattr(schema, "SCHEMA_VERSION", 3)
    monkeypatch.setattr(schema, "KINDS", {
        k: v for k, v in schema.KINDS.items()
        if k not in ("transport", "flush")})
    lines = read_complete_lines(GOLDEN_STREAM)
    dec = StreamDecoder()
    decoded = [dec.decode(ln) for ln in lines]
    assert dec.stream_version == 4 and dec.newer_stream
    assert dec.unknown_kinds["transport"] >= 2     # >= 2 worker procs
    assert dec.unknown_kinds["flush"] >= 1
    kinds = {type(r).__name__ for r in decoded if r is not None}
    assert {"RunMeta", "ArrivalMetrics", "EvalMetrics"} <= kinds
    report = "\n".join(dec.drift_report())
    assert "v4 > reader v3" in report
    assert "transport" in report and "flush" in report
    # even a STRICT v3 reader tolerates-and-counts the declared-newer
    # stream (the loud path is reserved for same-version drift)
    strict = StreamDecoder(strict=True)
    for ln in lines:
        strict.decode(ln)
    assert strict.unknown_kinds["transport"] >= 2


# ---------------------------------------------------------------------------
# Aggregation layer + web dashboard
# ---------------------------------------------------------------------------

def test_web_snapshot_contains_acceptance_panels():
    """Acceptance: `python -m repro.obs web --snapshot` over the
    committed reference stream aggregates non-empty arrival-rate,
    staleness, transport, and flush panels."""
    from repro.obs.web import snapshot_panels
    p = snapshot_panels(GOLDEN_STREAM)
    assert p["meta"]["scenario"] == "chaos_partition"
    assert p["meta"]["schema_version"] == schema.SCHEMA_VERSION
    assert p["arrivals"]["commits"] > 0
    assert p["arrivals"]["rate_per_sec"] > 0
    assert p["staleness"]
    assert (sum(p["staleness"].values())
            == p["arrivals"]["commits"])
    # cross-process transport panel: per-(wid/pid) rows + summed totals
    assert len(p["transport"]["workers"]) >= 2
    assert p["transport"]["totals"]["frames_sent"] > 0
    assert p["transport"]["totals"]["compute_s"] > 0
    # commit-buffer flush panel
    assert p["flush"]["flushes"] >= 1
    assert "batch-full" in p["flush"]["reasons"]
    assert p["flush"]["fused"] + p["flush"]["sequential"] >= 2
    # a clean committed stream aggregates drift-free
    assert p["drift"] == []


def test_web_snapshot_cli(capsys):
    from repro.obs.__main__ import main as obs_main
    assert obs_main(["web", GOLDEN_STREAM, "--snapshot"]) == 0
    p = json.loads(capsys.readouterr().out)
    for panel in ("arrivals", "staleness", "transport", "flush"):
        assert p[panel], f"panel {panel!r} empty in --snapshot output"


def test_console_and_web_share_one_aggregation_code_path():
    """The satellite contract: console, web, and snapshot all read ONE
    rollup (repro.obs.metrics.MetricsAggregator) — same stream in,
    identical panels out."""
    from repro.obs.web import snapshot_panels
    state = ConsoleState()
    for ln in read_complete_lines(GOLDEN_STREAM):
        state.add_line(ln)
    assert state.panels() == snapshot_panels(GOLDEN_STREAM)


def test_web_server_routes_live(tmp_path):
    """The dashboard server end-to-end on an ephemeral port: / serves
    the self-contained page, /snapshot.json tracks a growing stream
    through the tail hub, /events pushes SSE frames, unknown paths 404."""
    import urllib.error
    import urllib.request

    from repro.obs import web

    lines = read_complete_lines(GOLDEN_STREAM)
    stream = tmp_path / "live.jsonl"
    stream.write_text("\n".join(lines[:3]) + "\n")
    hub = web._Hub(str(stream), poll=0.02)
    hub.start()
    handler = type("H", (web._Handler,),
                   {"hub": hub, "sse_interval": 0.05})
    httpd = web.ThreadingHTTPServer(("127.0.0.1", 0), handler)
    httpd.daemon_threads = True
    t = threading.Thread(target=httpd.serve_forever,
                         kwargs={"poll_interval": 0.05}, daemon=True)
    t.start()
    base = f"http://127.0.0.1:{httpd.server_address[1]}"
    try:
        page = urllib.request.urlopen(base + "/", timeout=10).read()
        assert b"HeLoCo dashboard" in page and b"EventSource" in page
        # grow the stream; the hub tails the rest into the aggregate
        with open(stream, "a") as f:
            f.write("\n".join(lines[3:]) + "\n")
        snap = {}
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            snap = json.loads(urllib.request.urlopen(
                base + "/snapshot.json", timeout=10).read())
            if snap.get("transport") and snap.get("flush"):
                break
            time.sleep(0.05)
        assert snap["arrivals"]["commits"] > 0
        assert snap["transport"] and snap["flush"]
        # one SSE data frame arrives (skipping keepalive comments)
        resp = urllib.request.urlopen(base + "/events", timeout=10)
        payload = None
        for _ in range(100):
            ln = resp.readline()
            if ln.startswith(b"data: "):
                payload = json.loads(ln[6:])
                break
        resp.close()
        assert payload is not None and payload["arrivals"]["commits"] > 0
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(base + "/nope", timeout=10)
        assert exc.value.code == 404
    finally:
        httpd.shutdown()
        httpd.server_close()
        hub.stop()


# ---------------------------------------------------------------------------
# Commit-buffer flush telemetry (schema v4 "flush" records)
# ---------------------------------------------------------------------------

def _tiny_cfg(commit_batch=2, outer_steps=6):
    import dataclasses

    from repro.configs import get_config, reduced
    from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
    cfg = reduced(get_config("tinygpt-15m"))
    return dataclasses.replace(RunConfig(
        model=cfg, n_workers=2, inner_steps=1, outer_steps=outer_steps,
        batch_size=2, seq_len=16, worker_paces=(1.0, 2.0), non_iid=True,
        inner=InnerOptConfig(lr=3e-3, warmup_steps=2, total_steps=100),
        outer=OuterOptConfig(method="heloco")),
        commit_batch=commit_batch)


def test_flush_records_emitted_from_commit_buffer():
    """PR 9's batching is no longer a black box: every multi-arrival
    flush of the server commit buffer lands in the stream as one "flush"
    record carrying depth, reason, and the fused-vs-sequential split."""
    from repro.async_engine.engine import make_engine
    rec = TelemetryRecorder()
    eng = make_engine(_tiny_cfg(commit_batch=2, outer_steps=6),
                      telemetry=rec)
    eng.run(eval_every=3)
    fl = rec.flush_records()
    assert fl, "commit_batch=2 run produced no flush records"
    assert all(f.depth >= 2 for f in fl)          # singles skip the buffer
    assert {f.reason for f in fl} <= {"batch-full", "eval", "ckpt", "close"}
    assert "batch-full" in {f.reason for f in fl}
    # fused + sequential always account for the whole buffered depth
    assert all(f.fused + f.sequential == f.depth for f in fl)
    # ... and the server's cumulative totals agree (the stats_summary /
    # console "commit-buffer flushes" panel reads these)
    assert eng.server.flush_totals["flushes"] == len(fl)
    assert eng.server.flush_totals["depth_max"] == max(f.depth for f in fl)


@pytest.mark.wallclock
def test_free_mode_coalesces_commits_without_losing_arrivals():
    """The free-running loop's opportunistic batch drain (commit_batch>1)
    must conserve arrivals exactly: every commit is recorded once,
    batched or not, and the run still reaches the outer-step target."""
    from repro.async_engine.engine import make_engine, make_eval_fn
    from repro.scenarios import get_scenario
    scn = get_scenario("wallclock_free").overridden(commit_batch=3)
    rec = TelemetryRecorder()
    eng = make_engine(scn, telemetry=rec)
    hist = eng.run(eval_every=scn.eval_cadence,
                   eval_fn=make_eval_fn(eng, batch=scn.eval_batch))
    assert len(hist.arrivals) == scn.outer_steps
    assert eng.stats["arrivals"] == len(hist.arrivals)
    assert len(rec.arrivals()) == len(hist.arrivals)
    for f in rec.flush_records():                 # coalescing opportunistic
        assert 2 <= f.depth <= 3
        assert f.reason in {"batch-full", "eval", "ckpt", "close"}


# ---------------------------------------------------------------------------
# Single-writer sink enforcement (TailReader multi-writer satellite)
# ---------------------------------------------------------------------------

def test_second_recorder_on_same_sink_rejected_loudly(tmp_path):
    """Interleaved flushes from two writers can tear JSONL lines in ways
    no tail reader can repair — the recorder enforces one live writer
    per sink via an exclusive flock held for its lifetime."""
    sink = str(tmp_path / "s.jsonl")
    rec = TelemetryRecorder(sink=sink)
    rec.record_arrival(_fake_arrival(0))
    with pytest.raises(RuntimeError, match="live writer"):
        TelemetryRecorder(sink=sink)
    # the rejected opener never clobbered the live writer's bytes
    assert read_complete_lines(sink)
    rec.close()
    # close releases the lock: the sink is reusable afterwards
    rec2 = TelemetryRecorder(sink=sink)
    rec2.close()


# ---------------------------------------------------------------------------
# The byte-identity contract: observability on == golden off
# ---------------------------------------------------------------------------

def test_golden_identical_with_telemetry_tracing_and_runtime_records(
        tmp_path):
    """Running a golden scenario with the FULL observability stack on —
    live-sink telemetry, span tracing, periodic runtime records — must
    reproduce the committed golden trace byte-for-byte (observation
    never perturbs the run), while actually producing a live stream,
    runtime records, and a valid Chrome trace."""
    from repro.async_engine.engine import make_engine, make_eval_fn
    from repro.scenarios import get_scenario, trace

    scn = get_scenario("paper_hetero_severe")
    sink = str(tmp_path / "live.jsonl")
    rec = TelemetryRecorder(sink=sink)
    tr = SpanTracer()
    eng = make_engine(scn, telemetry=rec, tracer=tr, runtime_record_every=2)
    hist = eng.run(eval_every=scn.eval_cadence,
                   eval_fn=make_eval_fn(eng, batch=scn.eval_batch))
    rec.close()

    arrivals = [[a["outer_step"], a["worker_id"],
                 a["outer_step"] - 1 - a["staleness"], a["staleness"],
                 a["lang"], a["rho"], a["sim_time"], bool(a["dropped"])]
                for a in hist.arrivals]
    doc = {
        "schema": trace.SCHEMA_VERSION,
        "scenario": scn.to_dict(),
        "engine": scn.engine, "mode": scn.mode, "exact": scn.exact,
        "arrivals": arrivals, "evals": hist.evals,
        "tokens": int(hist.tokens), "comm_bytes": int(hist.comm_bytes),
        "final_time": float(hist.final_time),
        "param_digest": trace.param_digest(eng.server.state.params),
        "param_fingerprint": trace.param_fingerprint(
            eng.server.state.params),
    }
    res = trace.verify(scn, fresh=doc)
    assert res.ok, res.report()

    # the observability artifacts actually materialized
    assert rec.runtime_records(), "no runtime records at cadence 2"
    rt = rec.runtime_records()[-1]
    assert rt.workers_total == scn.n_workers
    assert len(tr) > 0 and validate_chrome_trace(tr.to_chrome()) == []
    dec = StreamDecoder(strict=True)
    for ln in read_complete_lines(sink):
        dec.decode(ln)
    assert dec.meta is not None and not dec.drift_report()
    kinds = {type(r).__name__ for r in map(dec.decode,
                                           read_complete_lines(sink))
             if r is not None}
    assert {"ArrivalMetrics", "EvalMetrics", "RuntimeMetrics"} <= kinds
