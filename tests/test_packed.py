"""Packed arrival fast path: layout round-trips, numerical equivalence to
the per-leaf reference (block_correct + outer_update), O(1)-launch
accounting, dropped-arrival fast path, and packed int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import HeLoCoConfig, OuterOptConfig
from repro.core import packing
from repro.core.compression import roundtrip_with_error_feedback
from repro.core.heloco import (
    apply_arrival, apply_arrival_packed, init_outer_state,
    momentum_decay_update,
)
from repro.async_engine.server import Synchronizer
from repro.kernels import ops
from repro.kernels.tiling import LANES, ROW_ALIGN, ROWS, padded_rows, row_tile

H = HeLoCoConfig()

# awkward sizes around every padding boundary (satellite: _to_2d property)
AWKWARD_SIZES = [1, 127, 128, 129, LANES * ROWS - 1, LANES * ROWS,
                 LANES * ROWS + 1, LANES * (ROWS + ROW_ALIGN)]


def _tree(key, bf16=False):
    """Multi-leaf transformer-ish pytree incl. a stacked layer axis."""
    ks = jax.random.split(key, 5)
    dt = jnp.bfloat16 if bf16 else jnp.float32
    return {
        "emb": jax.random.normal(ks[0], (40, 30)).astype(dt),
        "layers": {"w": jax.random.normal(ks[1], (3, 4, 5)).astype(dt),
                   "b": jax.random.normal(ks[2], (3, 5)).astype(dt)},
        "norm": jax.random.normal(ks[3], (129,)).astype(dt),
        "head": jax.random.normal(ks[4], (17,)).astype(dt),
    }


STACKED = {"emb": 0, "layers": {"w": 1, "b": 1}, "norm": 0, "head": 0}


def _allclose_tree(a, b, **tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), **tol)


# ---------------------------------------------------------------------------
# _to_2d / tiling (satellite: simplified padding, bounded over-pad)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", AWKWARD_SIZES)
def test_to_2d_roundtrip_and_padding_bound(n):
    x = jnp.arange(1.0, n + 1.0)
    x2d, n_out = ops._to_2d(x)
    assert n_out == n
    r = x2d.shape[0]
    assert x2d.shape[1] == LANES
    assert r % row_tile(r) == 0          # kernel grid always divides
    # over-padding bounded by one sublane tile of rows (old rule hit ~2x)
    assert r * LANES - n < LANES * ROW_ALIGN + LANES
    back = ops._from_2d(x2d, n, x.shape, x.dtype)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    # padding must be zeros (stats kernels rely on it)
    assert not np.any(np.asarray(x2d.reshape(-1)[n:]))


@pytest.mark.parametrize("n", [1, 127, 129, LANES * ROWS - 1,
                               LANES * ROWS + 1])
def test_per_leaf_kernels_at_awkward_sizes(n):
    """The gcd row-tile path must stay exact at non-divisible sizes."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(n))
    u = jax.random.normal(k1, (n,))
    v = jax.random.normal(k2, (n,))
    got = ops.heloco_correct_block(u, v, H, interpret=True)
    from repro.kernels.ref import ref_heloco_correct
    want = ref_heloco_correct(u, v, H)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("n", AWKWARD_SIZES)
def test_packed_layout_roundtrip_awkward(n):
    tree = {"x": jnp.arange(1.0, n + 1.0), "y": jnp.ones((3, 5))}
    layout = packing.build_layout(tree)
    buf = packing.pack(layout, tree)
    assert buf.shape == (layout.n_rows, LANES)
    assert layout.n_rows % row_tile(layout.n_rows) == 0
    back = packing.unpack(layout, buf)
    _allclose_tree(tree, back, rtol=0, atol=0)


def test_packed_layout_stacked_blocks_and_ids():
    layout = packing.build_layout(_tree(jax.random.PRNGKey(0)), STACKED)
    # 1 (emb) + 3 (layers.b) + 3 (layers.w) + 1 (head) + 1 (norm) blocks
    # (pytree flatten order is sorted dict keys)
    assert layout.n_blocks == 9
    rb = layout.row_block
    assert rb.shape == (layout.n_rows,)
    # block ids are sorted and every non-filler block owns >= 1 row
    assert sorted(set(rb.tolist())) == list(range(layout.n_blocks))
    sizes = layout.block_sizes
    assert int(sizes.sum()) == layout.total_elems


def test_pack_unpack_preserves_bf16_leaf_dtypes():
    tree = _tree(jax.random.PRNGKey(1), bf16=True)
    layout = packing.build_layout(tree, STACKED)
    back = packing.unpack(layout, packing.pack(layout, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# Numerical equivalence: packed pipeline vs per-leaf reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["heloco", "mla", "nesterov", "dcasgd"])
def test_packed_arrival_equals_per_leaf(method):
    key = jax.random.PRNGKey(0)
    params = _tree(key)
    delta = _tree(jax.random.PRNGKey(7))
    mom = jax.tree.map(lambda x: -0.3 * x + 0.1, delta)
    state = init_outer_state(params)._replace(momentum=mom)
    layout = packing.build_layout(params, STACKED)
    pbuf = packing.pack(layout, state.params)
    mbuf = packing.pack(layout, state.momentum)

    ref = apply_arrival(state, delta, method=method, outer_lr=0.7, mu=0.9,
                        h=H, rho=0.447, tau=3.0, stacked_axes=STACKED)
    p2, m2 = apply_arrival_packed(pbuf, mbuf, delta, layout, method=method,
                                  outer_lr=0.7, mu=0.9, h=H, rho=0.447,
                                  tau=3.0)
    _allclose_tree(ref.params, packing.unpack(layout, p2),
                   rtol=3e-5, atol=3e-5)
    _allclose_tree(ref.momentum, packing.unpack(layout, m2, jnp.float32),
                   rtol=3e-5, atol=3e-5)


def test_packed_synchronizer_trajectory_matches_per_leaf():
    """Multi-arrival trajectory incl. a dropped stale update."""
    params = _tree(jax.random.PRNGKey(2))
    cfg = OuterOptConfig(method="heloco", drop_stale_after=2)
    svA = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3,
                       stacked_axes=STACKED, packed=True)
    svB = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3,
                       stacked_axes=STACKED, packed=False)
    assert svA.packed and not svB.packed
    for i in range(6):
        delta = jax.tree.map(
            lambda x: 0.01 * jax.random.normal(jax.random.PRNGKey(i),
                                               x.shape), params)
        ra = svA.on_arrival(jax.tree.map(jnp.copy, delta),
                            s_i=max(0, svA.t - 3), worker_id=0)
        rb = svB.on_arrival(jax.tree.map(jnp.copy, delta),
                            s_i=max(0, svB.t - 3), worker_id=0)
        assert ra.dropped == rb.dropped
    assert any(r.dropped for r in svA.records)
    assert svA.t == svB.t == 6
    _allclose_tree(svA.state.params, svB.state.params, rtol=3e-5, atol=3e-5)
    _allclose_tree(svA.state.momentum, svB.state.momentum,
                   rtol=3e-5, atol=3e-5)
    # worker_init (packed look-ahead) agrees too
    _allclose_tree(svA.worker_init(), svB.worker_init(),
                   rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("method", ["heloco", "mla", "nesterov", "dcasgd",
                                    "delayed_nesterov"])
def test_momentum_decay_equals_zero_gradient_arrival(method):
    """Dropped-arrival fast path == the method applied to a ZERO
    pseudo-gradient (the pre-fast-path semantics) — including MLA, whose
    momentum extrapolation of a zero delta is a nonzero G."""
    params = _tree(jax.random.PRNGKey(3))
    mom = jax.tree.map(lambda x: 0.1 * x, params)
    state = init_outer_state(params)._replace(momentum=mom)
    zeros = jax.tree.map(jnp.zeros_like, params)
    want = apply_arrival(state, zeros, method=method, outer_lr=0.7, mu=0.9,
                         h=H, rho=0.447, tau=4.0, stacked_axes=STACKED)
    got = momentum_decay_update(state, 0.7, 0.9, method=method, rho=0.447,
                                tau=4.0)
    _allclose_tree(want.params, got.params, rtol=1e-6, atol=1e-6)
    _allclose_tree(want.momentum, got.momentum, rtol=1e-6, atol=1e-6)
    assert int(got.step) == 1


def test_packed_state_checkpoint_roundtrip():
    """state property/setter round-trips bit-exactly (ckpt semantics)."""
    params = _tree(jax.random.PRNGKey(4))
    sv = Synchronizer(params, OuterOptConfig(), 3, stacked_axes=STACKED)
    delta = jax.tree.map(lambda x: 0.01 * x, params)
    sv.on_arrival(delta, s_i=0, worker_id=0)
    snap = sv.state
    sv2 = Synchronizer(params, OuterOptConfig(), 3, stacked_axes=STACKED)
    sv2.state = snap
    assert sv2.t == sv.t == 1
    for a, b in zip(jax.tree.leaves(sv.state.params),
                    jax.tree.leaves(sv2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multi_step_grid_matches_single_step():
    """The TPU path walks multi-step grids; interpret mode defaults to one
    step. The explicit rows= override must give identical results, which
    exercises every kernel's index maps."""
    from repro.kernels import heloco_correct as hk
    from repro.kernels import outer_update as ok
    from repro.kernels import packed as pk
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    r = 64
    u = jax.random.normal(ks[0], (r, LANES))
    v = jax.random.normal(ks[1], (r, LANES))
    g = jax.random.normal(ks[2], (r, LANES))
    np.testing.assert_allclose(
        np.asarray(hk.block_stats(u, v, interpret=True).sum(0)),
        np.asarray(hk.block_stats(u, v, interpret=True, rows=8).sum(0)),
        rtol=1e-5, atol=1e-5)
    cu = jnp.asarray(0.7)
    cv = jnp.asarray(-0.2)
    np.testing.assert_allclose(
        np.asarray(hk.correct_apply(u, v, cu, cv, interpret=True)),
        np.asarray(hk.correct_apply(u, v, cu, cv, interpret=True, rows=8)),
        rtol=1e-5, atol=1e-6)
    a1, b1 = ok.outer_update_2d(u, v, g, 0.7, 0.9, 1.0, interpret=True)
    a2, b2 = ok.outer_update_2d(u, v, g, 0.7, 0.9, 1.0, interpret=True,
                                rows=16)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(pk.packed_row_stats(u, v, interpret=True)),
        np.asarray(pk.packed_row_stats(u, v, interpret=True, rows=8)),
        rtol=1e-5, atol=1e-5)
    cur = jnp.ones((r, 1))
    cvr = 0.5 * jnp.ones((r, 1))
    p1, m1 = pk.packed_correct_outer(u, v, g, cur, cvr, 0.7, 0.9, 1.0,
                                     interpret=True)
    p2, m2 = pk.packed_correct_outer(u, v, g, cur, cvr, 0.7, 0.9, 1.0,
                                     interpret=True, rows=16)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# O(1) kernel launches per arrival
# ---------------------------------------------------------------------------

def _count_launches(fn, *args):
    """pallas_call equation instances in the traced program (= dispatches
    per execution; robust to jit caching across same-shape blocks)."""
    def walk(jx):
        n = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                n += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (tuple, list)) else (v,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        n += walk(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        n += walk(sub)
        return n
    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def test_packed_arrival_is_two_launches():
    params = _tree(jax.random.PRNGKey(5))
    delta = _tree(jax.random.PRNGKey(6))
    layout = packing.build_layout(params, STACKED)
    pbuf = packing.pack(layout, params)
    mbuf = packing.zeros(layout)

    n_packed = _count_launches(
        lambda: apply_arrival_packed(pbuf, mbuf, delta, layout,
                                     method="heloco", outer_lr=0.7, mu=0.9,
                                     h=H))
    assert n_packed == 2, n_packed   # stats sweep + fused correct+outer

    # per-leaf kernel path: 2 launches per block, independent of d
    state = init_outer_state(params)
    n_leaf = _count_launches(
        lambda: apply_arrival(state, delta, method="heloco", outer_lr=0.7,
                              mu=0.9, h=H, stacked_axes=STACKED,
                              use_kernel=True))
    assert n_leaf >= 2 * len(jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Packed int8 compression
# ---------------------------------------------------------------------------

def test_packed_int8_matches_per_leaf_roundtrip():
    params = {"a": jax.random.normal(jax.random.PRNGKey(0), (40, 30)),
              "b": {"c": jax.random.normal(jax.random.PRNGKey(1), (17,))}}
    layout = packing.build_layout(params)
    delta = jax.tree.map(lambda x: 0.5 * x, params)
    dec_p, ef_p, nb_p = roundtrip_with_error_feedback(delta, None, "int8",
                                                      layout=layout)
    dec_l, ef_l, nb_l = roundtrip_with_error_feedback(delta, None, "int8")
    assert nb_p == nb_l              # same wire-byte accounting
    # decoded arrives as an already-packed buffer (no unpack/re-pack on
    # the arrival hot path); pack() must unwrap it for free
    assert isinstance(dec_p, packing.Packed)
    assert packing.pack(layout, dec_p) is dec_p.buf
    _allclose_tree(packing.unpack(layout, dec_p.buf), dec_l,
                   rtol=1e-6, atol=1e-6)
    # error feedback accumulates in the packed buffer and stays unbiased:
    # decoded(delta + ef) + new_ef == delta + ef
    assert ef_p.shape == (layout.n_rows, 128)
    dbuf = packing.pack(layout, delta)
    np.testing.assert_allclose(np.asarray(dec_p.buf + ef_p),
                               np.asarray(dbuf), rtol=1e-6, atol=1e-6)


def test_packed_int8_stacked_scales_per_block():
    """Stacked leaves quantize per LAYER block: a huge layer-0 magnitude
    must not destroy layer-2's resolution (per-leaf scale would)."""
    w = jnp.stack([1000.0 * jnp.ones((4, 5)), jnp.ones((4, 5)),
                   0.001 * jnp.ones((4, 5))])
    tree = {"w": w}
    layout = packing.build_layout(tree, {"w": 1})
    dec_buf, _, _ = roundtrip_with_error_feedback(tree, None, "int8",
                                                  layout=layout)
    dec = packing.unpack(layout, dec_buf.buf)
    # layer 2 survives with its own scale (per-leaf scale 1000/127 would
    # round 0.001 to zero)
    np.testing.assert_allclose(np.asarray(dec["w"][2]), 0.001, rtol=0.01)
