"""Multi-process socket backend: rendezvous defenses, orphan cleanup,
golden-trace replay across real process boundaries, mid-run process-kill
recovery, and the CI hang guard itself.

Whole module runs in CI's scenarios-proc lane (pytest.ini `proc` marker,
default-deselected); every test here spawns or supervises real worker
processes, so the per-test timeout guard (conftest.py) applies."""
import json
import os
import shutil
import signal
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

pytestmark = pytest.mark.proc

from repro.configs import get_config, reduced
from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
from repro.async_engine.engine import make_engine, make_eval_fn
from repro.async_engine.proc import (
    RendezvousRejected, SocketClient, WorkerProcessPool,
)
from repro.scenarios import get_scenario, trace

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def tiny_cfg(n_workers=2):
    cfg = reduced(get_config("tinygpt-15m"))
    return RunConfig(
        model=cfg, n_workers=n_workers, inner_steps=1, outer_steps=4,
        batch_size=2, seq_len=16,
        worker_paces=(1.0, 2.0)[:n_workers], non_iid=True,
        inner=InnerOptConfig(lr=3e-3, warmup_steps=2, total_steps=100),
        outer=OuterOptConfig(method="heloco"))


# ---------------------------------------------------------------------------
# Rendezvous
# ---------------------------------------------------------------------------

def test_rendezvous_rejects_duplicate_and_unknown_join():
    pool = WorkerProcessPool(tiny_cfg(), capacity=4)
    try:
        assert pool.ensure(0) == 1 and pool.alive(0)
        # the nonce was consumed by the real worker's join: replaying it
        # is a duplicate join and must be rejected, not re-assigned
        with pytest.raises(RendezvousRejected):
            SocketClient.connect(pool.transport.address,
                                 {"nonce": f"w0-i1-p{os.getpid()}"},
                                 timeout=10.0)
        with pytest.raises(RendezvousRejected):
            SocketClient.connect(pool.transport.address,
                                 {"nonce": "never-issued"}, timeout=10.0)
        # the legitimate worker is unaffected by the rejected impostors
        assert pool.alive(0)
        assert pool.ensure(0) is None    # already live: no respawn
    finally:
        pool.close()


class _StillbornProc:
    """Duck-typed spawn-context Process that dies before connecting."""
    exitcode = 7
    pid = -1

    def start(self):
        pass

    def is_alive(self):
        return False

    def terminate(self):
        pass

    def join(self, timeout=None):
        pass


class _StillbornCtx:
    def Process(self, *args, **kw):
        return _StillbornProc()


def test_worker_death_before_rendezvous_fails_ensure():
    pool = WorkerProcessPool(tiny_cfg(), capacity=4)
    pool._ctx = _StillbornCtx()
    try:
        with pytest.raises(RuntimeError,
                           match="died before the rendezvous"):
            pool.ensure(0)
        assert not pool._pending         # the nonce slot was reclaimed
        assert not pool.alive(0)
    finally:
        pool.close()


def test_close_leaves_no_orphan_processes():
    pool = WorkerProcessPool(tiny_cfg(), capacity=4)
    pool.ensure(0)
    pool.ensure(1)
    procs = [pool._procs[w] for w in (0, 1)]
    assert all(p.is_alive() for p in procs)
    family, target = pool.transport.address
    pool.close()
    for p in procs:
        assert not p.is_alive(), f"orphan worker pid {p.pid}"
    if family == "unix":
        assert not os.path.exists(target)   # rendezvous endpoint removed


# ---------------------------------------------------------------------------
# Determinism across the process boundary
# ---------------------------------------------------------------------------

def test_socket_transport_replays_committed_golden():
    # the acceptance anchor: the threaded golden, re-run over real worker
    # processes via the verify-time transport override, must reproduce
    # the UNMODIFIED committed trace
    res = trace.verify(get_scenario("wallclock_hetero"), trace.GOLDEN_DIR,
                       cross_engine=False, transport="socket")
    assert res.ok, res.report()


def test_process_kill_mid_run_recovers_trace_identically():
    scn = get_scenario("wallclock_hetero").overridden(transport="socket")
    eng = make_engine(scn)
    killed = {"ok": False}

    def killer():
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            pool = getattr(eng, "_pool", None)
            if pool is not None and len(eng.history.arrivals) >= 3:
                proc = pool._procs.get(0)
                if proc is not None and proc.is_alive():
                    os.kill(proc.pid, signal.SIGKILL)
                    killed["ok"] = True
                    return
            time.sleep(0.05)

    t = threading.Thread(target=killer, daemon=True)
    t.start()
    hist = eng.run(eval_every=scn.eval_cadence,
                   eval_fn=make_eval_fn(eng, batch=scn.eval_batch))
    t.join(timeout=5.0)
    assert killed["ok"], "killer never saw a live worker-0 process"
    assert eng.stats_summary()["proc_restarts"] >= 1

    with open(trace.golden_path("wallclock_hetero")) as f:
        want = json.load(f)
    got = [[a["outer_step"], a["worker_id"],
            a["outer_step"] - 1 - a["staleness"], a["staleness"],
            a["lang"], a["rho"], a["sim_time"], bool(a["dropped"])]
           for a in hist.arrivals]
    assert got == want["arrivals"]       # commit order exactly preserved
    # params: fp32-level agreement with the committed fingerprint (exact
    # locally; CI hosts may vectorize fp32 differently, see ci.yml)
    fp = trace.param_fingerprint(eng.server.state.params)
    assert fp.keys() == want["param_fingerprint"].keys()
    for k, vals in want["param_fingerprint"].items():
        np.testing.assert_allclose(fp[k], vals, rtol=1e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# The hang guard guards
# ---------------------------------------------------------------------------

def test_hang_guard_fails_hung_test_within_timeout():
    # a deliberately wedged proc test must fail within REPRO_TEST_TIMEOUT
    # — via pytest-timeout when installed, else the conftest.py fallback
    # watchdog — instead of stalling the lane to CI's job limit. The demo
    # file lives under the repo root so conftest.py applies to it.
    demo_dir = os.path.join(_REPO, "tests", ".hang_demo")
    os.makedirs(demo_dir, exist_ok=True)
    path = os.path.join(demo_dir, "test_hang_demo.py")
    with open(path, "w") as f:
        f.write(textwrap.dedent("""\
            import time

            import pytest

            pytestmark = pytest.mark.proc


            def test_deliberately_hangs():
                time.sleep(300)
        """))
    env = dict(os.environ, REPRO_TEST_TIMEOUT="3", JAX_PLATFORMS="cpu")
    t0 = time.monotonic()
    try:
        # -s: capture off, so the fallback watchdog's stderr survives its
        # hard process exit (pytest's capture buffer would be discarded)
        out = subprocess.run(
            [sys.executable, "-m", "pytest", path, "-q", "-s",
             "-o", "addopts=", "-p", "no:cacheprovider"],
            cwd=_REPO, env=env, capture_output=True, text=True,
            timeout=120)
    finally:
        shutil.rmtree(demo_dir, ignore_errors=True)
    elapsed = time.monotonic() - t0
    text = out.stdout + out.stderr
    assert out.returncode != 0, text
    assert elapsed < 60.0, (elapsed, text)
    assert "hang guard" in text or "imeout" in text, text


# ---------------------------------------------------------------------------
# Cross-process observability (docs/observability.md)
# ---------------------------------------------------------------------------

def test_socket_golden_with_full_obs_stack(tmp_path):
    """The acceptance anchor for cross-process collection: a
    deterministic socket run of socket_hetero with the FULL
    observability stack on — child spans, transport metrics, a live v4
    stream, and the merged Chrome trace — replays its committed golden
    byte-identically, and the merged trace validates with span rows
    from >= 2 distinct worker pids plus transport send/ack spans."""
    from repro.obs.spans import SpanTracer, validate_chrome_trace
    from repro.obs.tail import read_complete_lines
    from repro.telemetry import StreamDecoder, TelemetryRecorder, schema

    scn = get_scenario("socket_hetero")
    sink = str(tmp_path / "live.jsonl")
    rec = TelemetryRecorder(sink=sink)
    tr = SpanTracer()
    eng = make_engine(scn, telemetry=rec, tracer=tr,
                      runtime_record_every=2)
    hist = eng.run(eval_every=scn.eval_cadence,
                   eval_fn=make_eval_fn(eng, batch=scn.eval_batch))
    eng.assert_child_reports()           # every child process reported in
    rec.close()

    # (1) observation never perturbs the run: byte-identity vs golden
    arrivals = [[a["outer_step"], a["worker_id"],
                 a["outer_step"] - 1 - a["staleness"], a["staleness"],
                 a["lang"], a["rho"], a["sim_time"], bool(a["dropped"])]
                for a in hist.arrivals]
    doc = {
        "schema": trace.SCHEMA_VERSION,
        "scenario": scn.to_dict(),
        "engine": scn.engine, "mode": scn.mode, "exact": scn.exact,
        "arrivals": arrivals, "evals": hist.evals,
        "tokens": int(hist.tokens), "comm_bytes": int(hist.comm_bytes),
        "final_time": float(hist.final_time),
        "param_digest": trace.param_digest(eng.server.state.params),
        "param_fingerprint": trace.param_fingerprint(
            eng.server.state.params),
    }
    res = trace.verify(scn, fresh=doc)
    assert res.ok, res.report()

    # (2) the merged Chrome trace: well-formed, with per-process rows
    # from >= 2 distinct worker pids and the wire spans
    chrome = tr.to_chrome()
    assert validate_chrome_trace(chrome) == []
    spans = [e for e in chrome["traceEvents"] if e.get("ph") == "X"]
    worker_pids = {e["pid"] for e in spans} - {0}
    assert len(worker_pids) >= 2, sorted(worker_pids)
    child_names = {e["name"] for e in spans if e["pid"] != 0}
    assert {"worker_round", "transport.send",
            "transport.ack_wait"} <= child_names
    # clock-offset correction: re-based child rows never go negative
    assert all(e["ts"] >= 0 for e in spans)
    # the parent's own rows (server commits) share the same timeline
    assert any(e["name"] == "server_commit" for e in spans
               if e["pid"] == 0)

    # (3) the v4 stream carries a cumulative transport record per child
    # pid, with a final report from each
    dec = StreamDecoder(strict=True)
    recs = [dec.decode(ln) for ln in read_complete_lines(sink)]
    tps = [r for r in recs if isinstance(r, schema.TransportMetrics)]
    assert {t.pid for t in tps} >= worker_pids
    final_wids = {t.wid for t in tps if t.final}
    assert final_wids == set(range(scn.n_workers))
    assert all(t.frames_sent > 0 for t in tps if t.final)
    assert not dec.drift_report()

    # (4) stats_summary surfaces the collection counters
    s = eng.stats_summary()
    assert s["child_obs"]["reports"] and s["child_obs"]["final"]
    assert s["child_obs"]["wire"]["frames_sent"] > 0

    # (5) a silent child is LOUD, not a quiet parent-only artifact
    eng._pool.obs_reports.clear()
    with pytest.raises(RuntimeError, match="never reported"):
        eng.assert_child_reports()


def test_two_processes_writing_same_sink_rejected(tmp_path):
    """TailReader multi-writer satellite: the single-writer sink
    contract holds across REAL process boundaries — a second process
    opening the same live sink fails loudly while the first holds it."""
    sink = str(tmp_path / "s.jsonl")
    from repro.telemetry import TelemetryRecorder
    rec = TelemetryRecorder(sink=sink)
    try:
        probe = textwrap.dedent("""\
            import sys
            from repro.telemetry import TelemetryRecorder
            try:
                TelemetryRecorder(sink=sys.argv[1])
            except RuntimeError as e:
                print("REJECTED:", e)
                raise SystemExit(0)
            raise SystemExit(1)          # silently acquired: contract broken
        """)
        env = dict(os.environ, JAX_PLATFORMS="cpu",
                   PYTHONPATH=os.path.join(_REPO, "src"))
        out = subprocess.run(
            [sys.executable, "-c", probe, sink],
            env=env, capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, (out.stdout, out.stderr)
        assert "REJECTED" in out.stdout and "live writer" in out.stdout
    finally:
        rec.close()
