"""Multi-process socket backend: rendezvous defenses, orphan cleanup,
golden-trace replay across real process boundaries, mid-run process-kill
recovery, and the CI hang guard itself.

Whole module runs in CI's scenarios-proc lane (pytest.ini `proc` marker,
default-deselected); every test here spawns or supervises real worker
processes, so the per-test timeout guard (conftest.py) applies."""
import json
import os
import shutil
import signal
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

pytestmark = pytest.mark.proc

from repro.configs import get_config, reduced
from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
from repro.async_engine.engine import make_engine, make_eval_fn
from repro.async_engine.proc import (
    RendezvousRejected, SocketClient, WorkerProcessPool,
)
from repro.scenarios import get_scenario, trace

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def tiny_cfg(n_workers=2):
    cfg = reduced(get_config("tinygpt-15m"))
    return RunConfig(
        model=cfg, n_workers=n_workers, inner_steps=1, outer_steps=4,
        batch_size=2, seq_len=16,
        worker_paces=(1.0, 2.0)[:n_workers], non_iid=True,
        inner=InnerOptConfig(lr=3e-3, warmup_steps=2, total_steps=100),
        outer=OuterOptConfig(method="heloco"))


# ---------------------------------------------------------------------------
# Rendezvous
# ---------------------------------------------------------------------------

def test_rendezvous_rejects_duplicate_and_unknown_join():
    pool = WorkerProcessPool(tiny_cfg(), capacity=4)
    try:
        assert pool.ensure(0) == 1 and pool.alive(0)
        # the nonce was consumed by the real worker's join: replaying it
        # is a duplicate join and must be rejected, not re-assigned
        with pytest.raises(RendezvousRejected):
            SocketClient.connect(pool.transport.address,
                                 {"nonce": f"w0-i1-p{os.getpid()}"},
                                 timeout=10.0)
        with pytest.raises(RendezvousRejected):
            SocketClient.connect(pool.transport.address,
                                 {"nonce": "never-issued"}, timeout=10.0)
        # the legitimate worker is unaffected by the rejected impostors
        assert pool.alive(0)
        assert pool.ensure(0) is None    # already live: no respawn
    finally:
        pool.close()


class _StillbornProc:
    """Duck-typed spawn-context Process that dies before connecting."""
    exitcode = 7
    pid = -1

    def start(self):
        pass

    def is_alive(self):
        return False

    def terminate(self):
        pass

    def join(self, timeout=None):
        pass


class _StillbornCtx:
    def Process(self, *args, **kw):
        return _StillbornProc()


def test_worker_death_before_rendezvous_fails_ensure():
    pool = WorkerProcessPool(tiny_cfg(), capacity=4)
    pool._ctx = _StillbornCtx()
    try:
        with pytest.raises(RuntimeError,
                           match="died before the rendezvous"):
            pool.ensure(0)
        assert not pool._pending         # the nonce slot was reclaimed
        assert not pool.alive(0)
    finally:
        pool.close()


def test_close_leaves_no_orphan_processes():
    pool = WorkerProcessPool(tiny_cfg(), capacity=4)
    pool.ensure(0)
    pool.ensure(1)
    procs = [pool._procs[w] for w in (0, 1)]
    assert all(p.is_alive() for p in procs)
    family, target = pool.transport.address
    pool.close()
    for p in procs:
        assert not p.is_alive(), f"orphan worker pid {p.pid}"
    if family == "unix":
        assert not os.path.exists(target)   # rendezvous endpoint removed


# ---------------------------------------------------------------------------
# Determinism across the process boundary
# ---------------------------------------------------------------------------

def test_socket_transport_replays_committed_golden():
    # the acceptance anchor: the threaded golden, re-run over real worker
    # processes via the verify-time transport override, must reproduce
    # the UNMODIFIED committed trace
    res = trace.verify(get_scenario("wallclock_hetero"), trace.GOLDEN_DIR,
                       cross_engine=False, transport="socket")
    assert res.ok, res.report()


def test_process_kill_mid_run_recovers_trace_identically():
    scn = get_scenario("wallclock_hetero").overridden(transport="socket")
    eng = make_engine(scn)
    killed = {"ok": False}

    def killer():
        deadline = time.monotonic() + 120.0
        while time.monotonic() < deadline:
            pool = getattr(eng, "_pool", None)
            if pool is not None and len(eng.history.arrivals) >= 3:
                proc = pool._procs.get(0)
                if proc is not None and proc.is_alive():
                    os.kill(proc.pid, signal.SIGKILL)
                    killed["ok"] = True
                    return
            time.sleep(0.05)

    t = threading.Thread(target=killer, daemon=True)
    t.start()
    hist = eng.run(eval_every=scn.eval_cadence,
                   eval_fn=make_eval_fn(eng, batch=scn.eval_batch))
    t.join(timeout=5.0)
    assert killed["ok"], "killer never saw a live worker-0 process"
    assert eng.stats_summary()["proc_restarts"] >= 1

    with open(trace.golden_path("wallclock_hetero")) as f:
        want = json.load(f)
    got = [[a["outer_step"], a["worker_id"],
            a["outer_step"] - 1 - a["staleness"], a["staleness"],
            a["lang"], a["rho"], a["sim_time"], bool(a["dropped"])]
           for a in hist.arrivals]
    assert got == want["arrivals"]       # commit order exactly preserved
    # params: fp32-level agreement with the committed fingerprint (exact
    # locally; CI hosts may vectorize fp32 differently, see ci.yml)
    fp = trace.param_fingerprint(eng.server.state.params)
    assert fp.keys() == want["param_fingerprint"].keys()
    for k, vals in want["param_fingerprint"].items():
        np.testing.assert_allclose(fp[k], vals, rtol=1e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# The hang guard guards
# ---------------------------------------------------------------------------

def test_hang_guard_fails_hung_test_within_timeout():
    # a deliberately wedged proc test must fail within REPRO_TEST_TIMEOUT
    # — via pytest-timeout when installed, else the conftest.py fallback
    # watchdog — instead of stalling the lane to CI's job limit. The demo
    # file lives under the repo root so conftest.py applies to it.
    demo_dir = os.path.join(_REPO, "tests", ".hang_demo")
    os.makedirs(demo_dir, exist_ok=True)
    path = os.path.join(demo_dir, "test_hang_demo.py")
    with open(path, "w") as f:
        f.write(textwrap.dedent("""\
            import time

            import pytest

            pytestmark = pytest.mark.proc


            def test_deliberately_hangs():
                time.sleep(300)
        """))
    env = dict(os.environ, REPRO_TEST_TIMEOUT="3", JAX_PLATFORMS="cpu")
    t0 = time.monotonic()
    try:
        # -s: capture off, so the fallback watchdog's stderr survives its
        # hard process exit (pytest's capture buffer would be discarded)
        out = subprocess.run(
            [sys.executable, "-m", "pytest", path, "-q", "-s",
             "-o", "addopts=", "-p", "no:cacheprovider"],
            cwd=_REPO, env=env, capture_output=True, text=True,
            timeout=120)
    finally:
        shutil.rmtree(demo_dir, ignore_errors=True)
    elapsed = time.monotonic() - t0
    text = out.stdout + out.stderr
    assert out.returncode != 0, text
    assert elapsed < 60.0, (elapsed, text)
    assert "hang guard" in text or "imeout" in text, text
