"""Integration tests for the asynchronous runtime: scheduling semantics,
DyLU, sync mode, fault injection + recovery, elastic membership,
checkpoint/restore, compression accounting."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
from repro.async_engine.simulator import (
    AsyncSimulator, ElasticEvent, FailureEvent, make_eval_fn,
)


def tiny_run(method="heloco", **kw):
    cfg = reduced(get_config("tinygpt-15m"))
    defaults = dict(
        model=cfg, n_workers=3, inner_steps=3, outer_steps=9,
        batch_size=2, seq_len=16,
        worker_paces=(1.0, 2.0, 6.0), non_iid=True,
        inner=InnerOptConfig(lr=3e-3, warmup_steps=2, total_steps=100),
        outer=OuterOptConfig(method=method))
    defaults.update(kw)
    return RunConfig(**defaults)


def test_async_staleness_asymmetry():
    """Fast workers must contribute more arrivals with lower staleness."""
    sim = AsyncSimulator(tiny_run(outer_steps=15))
    hist = sim.run()
    per_worker = {}
    for a in hist.arrivals:
        per_worker.setdefault(a["worker_id"], []).append(a["staleness"])
    counts = {w: len(v) for w, v in per_worker.items()}
    assert counts[0] > counts[2], counts          # fast contributes more
    assert np.mean(per_worker[2]) > np.mean(per_worker[0])  # slow is staler


def test_dylu_equalizes_contributions():
    sim = AsyncSimulator(tiny_run(outer_steps=18, inner_steps=6, dylu=True))
    hist = sim.run()
    counts = {}
    for a in hist.arrivals:
        counts[a["worker_id"]] = counts.get(a["worker_id"], 0) + 1
    vals = list(counts.values())
    assert max(vals) - min(vals) <= 2, counts     # near-equal participation


def test_sync_mode_barrier_time():
    rc = tiny_run(method="sync_nesterov", outer_steps=4)
    sim = AsyncSimulator(rc)
    hist = sim.run()
    # each round's wall time = slowest worker = 3 steps * 6 s
    assert hist.final_time == pytest.approx(4 * 3 * 6.0)
    assert all(a["staleness"] == 0 for a in hist.arrivals)


def test_failure_recovery_continues_training():
    rc = tiny_run(outer_steps=12)
    failures = [FailureEvent(time=5.0, wid=0, restart_delay=10.0)]
    sim = AsyncSimulator(rc, failures=failures)
    hist = sim.run(eval_every=12, eval_fn=make_eval_fn(sim, batch=2, seq=16))
    assert len(hist.arrivals) == 12
    # worker 0 eventually contributes again after restart
    post = [a for a in hist.arrivals if a["worker_id"] == 0
            and a["sim_time"] > 15.0]
    assert post, "restarted worker never contributed"
    assert np.isfinite(hist.evals[-1]["mean"])


def test_elastic_join_and_leave():
    rc = tiny_run(outer_steps=12)
    elastic = [ElasticEvent(time=4.0, action="join", wid=7, pace=1.0, lang=1),
               ElasticEvent(time=20.0, action="leave", wid=2)]
    sim = AsyncSimulator(rc, elastic=elastic)
    hist = sim.run()
    wids = {a["worker_id"] for a in hist.arrivals}
    assert 7 in wids                              # joined worker contributes
    late = [a for a in hist.arrivals if a["sim_time"] > 21.0]
    assert all(a["worker_id"] != 2 for a in late)  # departed worker silent


def test_checkpoint_restore_bitexact(tmp_path):
    rc = tiny_run(outer_steps=6)
    sim = AsyncSimulator(rc)
    sim.run(ckpt_every=3, ckpt_dir=str(tmp_path))
    path = os.path.join(str(tmp_path), "step_6.npz")
    assert os.path.exists(path)

    sim2 = AsyncSimulator(rc)                     # fresh process semantics
    sim2.restore(path)
    a = jax.tree.leaves(sim.server.state.params)
    b = jax.tree.leaves(sim2.server.state.params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert sim2.server.t == 6
    # training continues after restore
    sim2.cfg = rc.__class__(**{**rc.__dict__, "outer_steps": 9})
    hist = sim2.run()
    assert sim2.server.t == 9


def test_checkpoint_detects_corruption(tmp_path):
    rc = tiny_run(outer_steps=3)
    sim = AsyncSimulator(rc)
    sim.run()
    path = sim.checkpoint(str(tmp_path))
    with open(path, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    sim2 = AsyncSimulator(rc)
    with pytest.raises(Exception):
        sim2.restore(path)


@pytest.mark.parametrize("kind,max_ratio", [("int8", 0.30), ("topk", 0.35)])
def test_compression_reduces_bytes(kind, max_ratio):
    base = AsyncSimulator(tiny_run(outer_steps=6))
    base_hist = base.run()
    comp = AsyncSimulator(tiny_run(
        outer_steps=6,
        outer=OuterOptConfig(method="heloco", compression=kind,
                             topk_ratio=0.1)))
    comp_hist = comp.run()
    assert comp_hist.comm_bytes < base_hist.comm_bytes * max_ratio
    # still trains
    assert np.isfinite(float(jax.tree.leaves(comp.server.state.params)[0].sum()))


def test_drop_stale_after():
    rc = tiny_run(outer_steps=12,
                  outer=OuterOptConfig(method="heloco", drop_stale_after=1),
                  worker_paces=(1.0, 12.0, 12.0))
    sim = AsyncSimulator(rc)
    hist = sim.run()
    dropped = [a for a in hist.arrivals if a["dropped"]]
    assert dropped, "no stale update was dropped"
    assert all(a["staleness"] > 1 for a in dropped)


def test_flexible_assignment_balances_langs():
    rc = tiny_run(outer_steps=12, shard_assignment="flexible",
                  worker_paces=(1.0, 1.0, 8.0))
    sim = AsyncSimulator(rc)
    sim.run()
    toks = sim.lang_tokens[sim.lang_tokens > 0]
    assert toks.max() <= toks.min() * 4  # far tighter than fixed w/ 8x pace gap
