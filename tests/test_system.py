"""End-to-end behaviour tests for the HeLoCo system: the paper's headline
qualitative claims on a tiny model, plus config registry integrity."""
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, SHAPES, cells, get_config, reduced


def test_registry_has_all_assigned_archs():
    expected = {
        "zamba2-2.7b", "qwen2-7b", "granite-3-8b", "command-r-35b",
        "starcoder2-15b", "granite-moe-1b-a400m", "llama4-scout-17b-a16e",
        "hubert-xlarge", "xlstm-125m", "paligemma-3b",
    }
    assert expected == set(ASSIGNED)
    assert "tinygpt-15m" in ARCHS


def test_exact_assigned_configs():
    q = get_config("qwen2-7b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab_size) == (28, 3584, 28, 4, 18944, 152064)
    assert q.qkv_bias
    c = get_config("command-r-35b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 8192, 64, 8, 22528, 256000)
    m = get_config("granite-moe-1b-a400m")
    assert (m.moe.n_experts, m.moe.top_k) == (32, 8)
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.moe.n_experts, l4.moe.top_k) == (16, 1)
    z = get_config("zamba2-2.7b")
    assert (z.n_layers, z.ssm.d_state) == (54, 64)
    x = get_config("xlstm-125m")
    assert (x.n_layers, x.d_ff) == (12, 0)
    h = get_config("hubert-xlarge")
    assert h.encoder_only and h.vocab_size == 504
    p = get_config("paligemma-3b")
    assert p.n_kv_heads == 1 and p.frontend.kind == "vision"


def test_cells_inventory():
    rows = list(cells())
    assert len(rows) == 40
    runnable = [r for r in rows if r[2]]
    skipped = [r for r in rows if not r[2]]
    assert len(runnable) == 31
    # skips: 8 full-attention long_500k + hubert decode_32k
    assert len(skipped) == 9
    for m, s, ok, why in skipped:
        assert why, (m.name, s.name)


def test_reduced_configs_are_small():
    for arch in ASSIGNED:
        r = reduced(get_config(arch))
        assert r.d_model <= 64 and r.n_layers <= 4 and r.vocab_size <= 128


@pytest.mark.slow
def test_heloco_beats_async_nesterov_under_staleness():
    """Paper's central claim, minimal form: with heterogeneous paces and
    non-IID data, async HeLoCo reaches lower validation loss than plain
    async Nesterov at the same outer-step (token) budget."""
    from benchmarks.common import base_run, run_cached
    paces = (1.0, 2.0, 6.0, 6.0)
    rh = run_cached("sys_heloco", base_run(
        paces, method="async-heloco", non_iid=True, outer_steps=20,
        inner_steps=6, seed=3))
    rn = run_cached("sys_nesterov", base_run(
        paces, method="async-nesterov", non_iid=True, outer_steps=20,
        inner_steps=6, seed=3))
    assert rh["final_loss"] < rn["final_loss"], (rh["final_loss"],
                                                 rn["final_loss"])
    # and training actually learned something
    assert rh["final_loss"] < rh["evals"][0]["mean"]


@pytest.mark.slow
def test_lookahead_init_helps_or_neutral():
    """Eq. 5 look-ahead init should not hurt under staleness (sanity)."""
    import dataclasses
    from benchmarks.common import base_run, run_cached
    paces = (1.0, 1.0, 6.0, 6.0)
    rc_on = base_run(paces, method="async-heloco", non_iid=True,
                     outer_steps=16, inner_steps=6, seed=5)
    rc_off = dataclasses.replace(
        rc_on, outer=dataclasses.replace(rc_on.outer, lookahead_init=False))
    on = run_cached("sys_lookahead_on", rc_on)
    off = run_cached("sys_lookahead_off", rc_off)
    assert on["final_loss"] <= off["final_loss"] + 0.15
