"""Wall-clock concurrent runtime: transport backpressure, determinism
contract (sim <-> wallclock arrival-sequence + final-params equivalence),
fault tolerance / elastic membership on the threaded path, and genuine
compute/update overlap in free-running mode.

Whole module runs in CI's scenarios-wallclock lane (see pytest.ini)."""
import threading
import time

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.wallclock

from repro.configs import get_config, reduced
from repro.configs.base import InnerOptConfig, OuterOptConfig, RunConfig
from repro.async_engine.engine import (
    ElasticEvent, FailureEvent, make_engine,
)
from repro.async_engine.faults import FaultSpec, PartitionSpec
from repro.async_engine.runtime import ConcurrentRuntime
from repro.async_engine.simulator import AsyncSimulator
from repro.async_engine.proc import SocketTransport
from repro.async_engine.transport import (
    InProcTransport, TransportClosed, TransportTimeout,
)
from repro.checkpoint import ckpt as _ckpt


def tiny_run(method="heloco", **kw):
    cfg = reduced(get_config("tinygpt-15m"))
    defaults = dict(
        model=cfg, n_workers=3, inner_steps=3, outer_steps=9,
        batch_size=2, seq_len=16,
        worker_paces=(1.0, 2.0, 6.0), non_iid=True,
        inner=InnerOptConfig(lr=3e-3, warmup_steps=2, total_steps=100),
        outer=OuterOptConfig(method=method))
    defaults.update(kw)
    return RunConfig(**defaults)


def arrival_keys(hist):
    """The determinism contract: per-arrival (t, wid, staleness, lang,
    dropped, rho) — and in deterministic mode the virtual clock too."""
    return [(a["outer_step"], a["worker_id"], a["staleness"], a["lang"],
             a["dropped"], a["rho"], round(a["sim_time"], 9))
            for a in hist.arrivals]


def assert_params_close(eng_a, eng_b, rtol=1e-5, atol=1e-6):
    # fp32 tolerance: both engines run the identical jitted programs on
    # identical inputs, so CPU results are bitwise-equal in practice; the
    # tolerance only allows for nondeterministic intra-op scheduling.
    for x, y in zip(jax.tree.leaves(eng_a.server.state.params),
                    jax.tree.leaves(eng_b.server.state.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Transport semantics — one contract, both backends: the in-process
# bounded queue and the socket backend's loopback channel (a real wire in
# the same process: frames, credits, reader threads — no spawned workers,
# so these stay cheap enough for this lane).
# ---------------------------------------------------------------------------

@pytest.fixture(params=["inproc", "socket"])
def make_transport(request):
    made = []

    def make(capacity):
        tr = (InProcTransport(capacity=capacity)
              if request.param == "inproc"
              else SocketTransport(capacity=capacity))
        made.append(tr)
        return tr

    yield make
    for tr in made:
        tr.close()


def _wait_depth(tr, n, timeout=5.0):
    """Socket frames land in the receive queue asynchronously; spin until
    the expected depth (no-op for the in-process queue)."""
    deadline = time.monotonic() + timeout
    while tr.depth() < n and time.monotonic() < deadline:
        time.sleep(0.01)
    return tr.depth()


def test_transport_backpressure_blocks_and_loses_nothing(make_transport):
    tr = make_transport(2)
    n = 25
    high_water = []

    def producer():
        for i in range(n):
            tr.send(i)
            high_water.append(tr.depth())

    t = threading.Thread(target=producer, daemon=True)
    t.start()
    time.sleep(0.3)                      # let the producer hit the wall
    assert _wait_depth(tr, 2) == 2       # bounded: never above capacity
    assert t.is_alive()                  # producer parked in send()
    got = [tr.recv(timeout=5.0) for _ in range(n)]
    t.join(timeout=5.0)
    assert got == list(range(n))         # FIFO, nothing dropped
    assert max(high_water) <= 2


def test_transport_close_wakes_blocked_sender_and_receiver(make_transport):
    tr = make_transport(1)
    tr.send(0)
    assert _wait_depth(tr, 1) == 1       # the frame is queued before close
    errs = []

    def blocked_send():
        try:
            tr.send(1)
        except TransportClosed as e:
            errs.append(e)

    t = threading.Thread(target=blocked_send, daemon=True)
    t.start()
    time.sleep(0.1)
    tr.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and len(errs) == 1
    assert tr.recv(timeout=1.0) == 0     # close still drains queued msgs
    with pytest.raises(TransportClosed):
        tr.recv(timeout=1.0)
    with pytest.raises(TransportTimeout):
        make_transport(1).recv(timeout=0.05)


def test_transport_send_timeout_when_full_exact_deadline(make_transport):
    tr = make_transport(1)
    tr.send(0)
    t0 = time.monotonic()
    with pytest.raises(TransportTimeout):
        tr.send(1, timeout=0.2)
    waited = time.monotonic() - t0
    # Condition-based deadlines are exact, not quantized to a poll tick
    assert 0.18 <= waited < 0.6, waited
    assert _wait_depth(tr, 1) == 1       # the timed-out msg was not queued
    assert tr.recv(timeout=0.5) == 0


def test_transport_recv_timeout_when_idle_exact_deadline(make_transport):
    tr = make_transport(4)
    t0 = time.monotonic()
    with pytest.raises(TransportTimeout):
        tr.recv(timeout=0.2)
    waited = time.monotonic() - t0
    assert 0.18 <= waited < 0.6, waited
    tr.send("late")
    assert tr.recv(timeout=0.5) == "late"


def test_transport_close_wakes_blocked_receiver(make_transport):
    tr = make_transport(1)
    errs = []

    def blocked_recv():
        try:
            tr.recv(timeout=10.0)
        except TransportClosed as e:
            errs.append(e)

    t = threading.Thread(target=blocked_recv, daemon=True)
    t.start()
    time.sleep(0.1)
    assert t.is_alive()                  # parked in recv(), no message yet
    tr.close()
    t.join(timeout=5.0)
    assert not t.is_alive() and len(errs) == 1
    with pytest.raises(TransportClosed):
        tr.send(1)


# ---------------------------------------------------------------------------
# Determinism contract (the acceptance anchor)
# ---------------------------------------------------------------------------

def test_wallclock_reproduces_sim_20_outer_noniid_hetero():
    """FIFO-forced (deterministic) wall-clock runtime must reproduce the
    simulator's arrival sequence (wid, s_i via staleness, lang) EXACTLY
    and the final params to fp32 tolerance — >= 20 outer steps, non-IID,
    paper-style (1, 2, 6, 15) pace heterogeneity."""
    rc = tiny_run(n_workers=4, outer_steps=20, inner_steps=2,
                  worker_paces=(1.0, 2.0, 6.0, 15.0))
    sim = AsyncSimulator(rc)
    h_sim = sim.run()
    rt = make_engine(rc, "wallclock")
    assert isinstance(rt, ConcurrentRuntime)
    h_rt = rt.run()
    assert arrival_keys(h_sim) == arrival_keys(h_rt)
    assert h_sim.tokens == h_rt.tokens
    assert h_sim.comm_bytes == h_rt.comm_bytes
    assert_params_close(sim, rt)
    # compute really overlapped even though commits were virtual-ordered
    s = rt.stats_summary()
    assert s["arrivals"] == 20
    assert s["overlap_max"] >= 1


def test_wallclock_matches_sim_with_dylu_and_int8():
    """Error-feedback buffers and DyLU step counts ride the threaded path
    unchanged."""
    rc = tiny_run(outer_steps=8, inner_steps=4, dylu=True,
                  outer=OuterOptConfig(method="heloco", compression="int8"))
    sim = AsyncSimulator(rc)
    h_sim = sim.run()
    rt = ConcurrentRuntime(rc)
    h_rt = rt.run()
    assert arrival_keys(h_sim) == arrival_keys(h_rt)
    assert h_sim.comm_bytes == h_rt.comm_bytes
    assert_params_close(sim, rt)


def test_wallclock_sync_mode_parallel_barrier():
    rc = tiny_run(method="sync_nesterov", outer_steps=3)
    sim = AsyncSimulator(rc)
    h_sim = sim.run()
    rt = ConcurrentRuntime(rc)
    h_rt = rt.run()
    assert h_rt.final_time == pytest.approx(3 * 3 * 6.0)
    assert arrival_keys(h_sim) == arrival_keys(h_rt)
    assert_params_close(sim, rt)


# ---------------------------------------------------------------------------
# Fault tolerance + elastic membership on the threaded path
# ---------------------------------------------------------------------------

def test_wallclock_crash_and_rejoin_matches_sim():
    rc = tiny_run(outer_steps=12)
    mk = lambda: [FailureEvent(time=5.0, wid=0, restart_delay=10.0)]
    sim = AsyncSimulator(rc, failures=mk())
    h_sim = sim.run()
    rt = ConcurrentRuntime(rc, failures=mk())
    h_rt = rt.run()
    assert arrival_keys(h_sim) == arrival_keys(h_rt)
    assert_params_close(sim, rt)
    # the restarted worker contributes again on the threaded path
    post = [a for a in h_rt.arrivals if a["worker_id"] == 0
            and a["sim_time"] > 15.0]
    assert post, "restarted worker never contributed"


def test_wallclock_elastic_join_and_leave_matches_sim():
    rc = tiny_run(outer_steps=12)
    mk = lambda: [ElasticEvent(time=4.0, action="join", wid=7, pace=1.0,
                               lang=1),
                  ElasticEvent(time=20.0, action="leave", wid=2)]
    sim = AsyncSimulator(rc, elastic=mk())
    h_sim = sim.run()
    rt = ConcurrentRuntime(rc, elastic=mk())
    h_rt = rt.run()
    assert arrival_keys(h_sim) == arrival_keys(h_rt)
    assert_params_close(sim, rt)
    wids = {a["worker_id"] for a in h_rt.arrivals}
    assert 7 in wids                              # joined worker contributes
    late = [a for a in h_rt.arrivals if a["sim_time"] > 21.0]
    assert all(a["worker_id"] != 2 for a in late)  # departed worker silent
    # departed worker's thread was reaped
    assert 2 not in rt._threads


def test_wallclock_leave_then_rejoin_same_wid_drops_orphan_round():
    """A departed worker's in-flight round must never be committed as the
    rejoined (same-wid) incarnation's result: task ids are engine-unique,
    so the orphan arrival is discarded — matching the simulator."""
    rc = tiny_run(outer_steps=10)
    mk = lambda: [ElasticEvent(time=2.0, action="leave", wid=2),
                  ElasticEvent(time=8.0, action="join", wid=2, pace=1.0,
                               lang=2)]
    sim = AsyncSimulator(rc, elastic=mk())
    h_sim = sim.run()
    rt = ConcurrentRuntime(rc, elastic=mk())
    h_rt = rt.run()
    assert arrival_keys(h_sim) == arrival_keys(h_rt)
    assert_params_close(sim, rt)
    assert any(a["worker_id"] == 2 and a["sim_time"] > 8.0
               for a in h_rt.arrivals)


def test_wallclock_checkpoint_restore_continues(tmp_path):
    rc = tiny_run(outer_steps=6)
    rt = ConcurrentRuntime(rc)
    rt.run(ckpt_every=3, ckpt_dir=str(tmp_path))
    rt2 = ConcurrentRuntime(rc)
    rt2.restore(str(tmp_path / "step_6.npz"))
    assert rt2.server.t == 6
    assert_params_close(rt, rt2, rtol=0, atol=0)
    rc9 = RunConfig(**{**rc.__dict__, "outer_steps": 9})
    rt2.cfg = rc9
    rt2.run()
    assert rt2.server.t == 9


# ---------------------------------------------------------------------------
# Free-running mode: genuine overlap on the wall clock
# ---------------------------------------------------------------------------

def test_free_running_overlap_and_heterogeneous_throttle():
    rc = tiny_run(n_workers=4, outer_steps=12, inner_steps=1,
                  worker_paces=(1.0, 1.0, 2.0, 6.0))
    rt = ConcurrentRuntime(rc, mode="free", pace_scale=0.05)
    hist = rt.run()
    assert len(hist.arrivals) == 12
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(rt.server.state.params))
    s = rt.stats_summary()
    # the paper's wall-clock premise: while the server applies an update,
    # other workers are genuinely mid-round
    assert s["overlap_max"] >= 2, s
    assert s["overlap_commits"] >= 1
    # throttled paces show up as staleness asymmetry, like the simulator
    per_worker = {}
    for a in hist.arrivals:
        per_worker.setdefault(a["worker_id"], []).append(a["staleness"])
    assert len(per_worker[0]) >= len(per_worker.get(3, []))


def test_free_running_crash_rejoin_and_elastic():
    rc = tiny_run(n_workers=3, outer_steps=10, inner_steps=1,
                  worker_paces=(1.0, 1.0, 2.0))
    failures = [FailureEvent(time=0.5, wid=0, restart_delay=1.0)]
    elastic = [ElasticEvent(time=1.0, action="join", wid=5, pace=1.0, lang=1)]
    rt = ConcurrentRuntime(rc, mode="free", pace_scale=0.05,
                           failures=failures, elastic=elastic)
    hist = rt.run()
    assert len(hist.arrivals) == 10
    wids = {a["worker_id"] for a in hist.arrivals}
    assert 5 in wids, "elastically-joined worker never contributed"
    # crashed worker's generation advanced: its lost round never committed
    w0 = [a for a in hist.arrivals if a["worker_id"] == 0]
    assert all(not a["dropped"] for a in w0)


# ---------------------------------------------------------------------------
# Unreliable delivery: at-least-once retry, idempotent commit, liveness
# ---------------------------------------------------------------------------

def chaos_run(rc, faults, **kw):
    rt = ConcurrentRuntime(rc, faults=faults, **kw)
    hist = rt.run()
    return rt, hist


def test_chaos_deterministic_identical_to_fault_free_twin():
    """The dedup+retry correctness claim: drop/dup/reorder/delay/ack-loss
    change latency and delivery counters, never the committed history or
    the final parameters (bitwise)."""
    rc = tiny_run(n_workers=4, outer_steps=10, inner_steps=2,
                  worker_paces=(1.0, 2.0, 6.0, 15.0))
    clean = ConcurrentRuntime(rc)
    h_clean = clean.run()
    faults = FaultSpec(drop_p=0.2, dup_p=0.1, reorder_p=0.2,
                       delay_p=0.1, delay_s=0.005, ack_drop_p=0.05, seed=7)
    rt, hist = chaos_run(rc, faults)
    assert arrival_keys(hist) == arrival_keys(h_clean)
    assert hist.tokens == h_clean.tokens
    assert hist.comm_bytes == h_clean.comm_bytes
    assert_params_close(clean, rt, rtol=0, atol=0)        # bitwise
    d = rt.stats_summary()["delivery"]
    assert d["injected_drops"] + d["injected_dups"] \
        + d["injected_reorders"] > 0, d
    assert d["retries"] > 0 and d["redelivered_deduped"] > 0, d
    clean_d = clean.stats_summary()["delivery"]
    assert all(v == 0 for v in clean_d.values()), clean_d  # fault-free: quiet


def test_chaos_corruption_rejected_then_redelivered_clean():
    rc = tiny_run(outer_steps=8)
    clean = ConcurrentRuntime(rc)
    h_clean = clean.run()
    rt, hist = chaos_run(rc, FaultSpec(corrupt_p=0.3, ack_drop_p=0.1,
                                       seed=11))
    assert arrival_keys(hist) == arrival_keys(h_clean)
    assert_params_close(clean, rt, rtol=0, atol=0)
    d = rt.stats_summary()["delivery"]
    assert d["checksum_rejects"] > 0, d     # corrupt frames never committed


def test_chaos_quarantine_degrades_gracefully_in_free_mode():
    rc = tiny_run(n_workers=3, outer_steps=8, inner_steps=1,
                  worker_paces=(1.0, 1.0, 2.0))
    faults = FaultSpec(corrupt_p=1.0, corrupt_wids=(1,), quarantine_after=3,
                       seed=5)
    rt, hist = chaos_run(rc, faults, mode="free", pace_scale=0.02)
    assert len(hist.arrivals) == 8          # survivors finish the run
    assert all(a["worker_id"] != 1 for a in hist.arrivals)
    d = rt.stats_summary()["delivery"]
    assert d["quarantines"] == 1 and d["checksum_rejects"] >= 3, d


def test_partition_liveness_death_and_revival():
    """A partitioned worker's heartbeats stop -> liveness declares it dead
    (generation bump: its in-flight round is lost); when the partition
    heals, the returning beacon revives it through the rejoin machinery
    and it contributes again."""
    rc = tiny_run(n_workers=3, outer_steps=14, inner_steps=1,
                  worker_paces=(1.0, 1.0, 1.0))
    faults = FaultSpec(
        seed=13, partitions=(PartitionSpec(start=0.5, end=4.0, wids=(2,)),),
        heartbeat_interval=0.05, liveness_misses=2,
        ack_timeout=0.1, max_backoff=0.2)
    rt, hist = chaos_run(rc, faults, mode="free", pace_scale=0.2)
    assert len(hist.arrivals) == 14
    d = rt.stats_summary()["delivery"]
    assert d["liveness_deaths"] >= 1, d
    assert d["heartbeat_misses"] >= 2, d
    assert d["liveness_revivals"] >= 1, d
    # the revived worker contributed after the partition healed
    late = [a for a in hist.arrivals if a["worker_id"] == 2
            and a["sim_time"] > 4.0]
    assert late, [a for a in hist.arrivals if a["worker_id"] == 2]


def test_partitions_rejected_in_deterministic_mode():
    rc = tiny_run(outer_steps=4)
    faults = FaultSpec(partitions=(PartitionSpec(0.0, 1.0),))
    with pytest.raises(ValueError):
        ConcurrentRuntime(rc, faults=faults)


def test_kill_server_and_resume_same_arrival_accounting(tmp_path):
    """Kill-and-resume recovery: request_stop mid-run, checkpoint-restore
    in a fresh runtime, and the combined arrival accounting matches an
    uninterrupted run — under a lossy channel."""
    rc = tiny_run(outer_steps=8)
    faults = FaultSpec(drop_p=0.2, dup_p=0.1, reorder_p=0.2, seed=7)
    rt = ConcurrentRuntime(rc, faults=faults)

    def kill_after_two_commits():
        while rt.server.t < 2:
            time.sleep(0.02)
        rt.request_stop()

    killer = threading.Thread(target=kill_after_two_commits, daemon=True)
    killer.start()
    h1 = rt.run(ckpt_every=1, ckpt_dir=str(tmp_path))
    killer.join(timeout=5.0)
    assert 2 <= rt.server.t <= 8
    assert rt.server.t == len(h1.arrivals)
    rt2 = ConcurrentRuntime(rc, faults=faults)
    rt2.restore(_ckpt.latest(str(tmp_path)))
    assert rt2.restored_arrivals == rt2.server.t
    h2 = rt2.run()
    assert rt2.server.t == 8
    assert rt2.restored_arrivals + len(h2.arrivals) == 8


def test_synchronizer_commit_is_idempotent():
    """Defense-in-depth below the delivery layer: a replayed commit key
    can never double-step outer state."""
    rc = tiny_run(outer_steps=2)
    rt = ConcurrentRuntime(rc)
    rt.run()
    srv = rt.server
    t_before = srv.t
    delta = jax.tree.map(np.zeros_like, jax.tree.map(np.asarray,
                                                     srv.state.params))
    rec1 = srv.on_arrival(delta, s_i=t_before, worker_id=0,
                          commit_key=(0, 0, 99))
    rec2 = srv.on_arrival(delta, s_i=t_before, worker_id=0,
                          commit_key=(0, 0, 99))
    assert rec2 is rec1                     # replay returns the original
    assert srv.t == t_before + 1            # exactly one outer step


def test_heartbeats_do_not_perturb_free_run_stats():
    """Liveness enabled on a healthy channel: beacons flow, nobody dies."""
    rc = tiny_run(n_workers=3, outer_steps=6, inner_steps=1,
                  worker_paces=(1.0, 1.0, 2.0))
    faults = FaultSpec(seed=1, heartbeat_interval=0.05, liveness_misses=50)
    rt, hist = chaos_run(rc, faults, mode="free", pace_scale=0.02)
    assert len(hist.arrivals) == 6
    d = rt.stats_summary()["delivery"]
    assert d["liveness_deaths"] == 0, d
