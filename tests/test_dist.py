"""Distribution-layer tests on a small fake-device mesh (8 devices):
sharding rule sanity, multipod train-step pod independence, and the
HeLoCo outer exchange (sync/async + int8) vs the single-host reference."""
import os
import subprocess
import sys

import pytest

# These tests need multiple fake devices; run the real checks in a
# subprocess so the main pytest process keeps its single-device view.

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced
from repro.configs.base import HeLoCoConfig, InnerOptConfig
from repro.dist import sharding as shd
from repro.dist.steps import (init_train_state, make_multipod_train_step,
                              make_outer_exchange, make_train_step)
from repro.launch.mesh import make_test_mesh, mesh_context
from repro.core.heloco import OuterState, block_correct, outer_update, lookahead_init
from repro.models import build_model

cfg = dataclasses.replace(reduced(get_config("qwen2-7b")),
                          act_batch_axes=("data",))
mesh = make_test_mesh(multi_pod=True)   # (pod=2, data=2, model=2)
axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
pspecs = shd.param_specs(params, axis_sizes=axis_sizes)

# ---- multipod train step: pods with identical params+batch stay identical,
# different batches diverge (proves per-pod independence = no cross-pod psum)
inner = InnerOptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
step = make_multipod_train_step(cfg, inner, mesh, grad_accum=1, q_chunk=16,
                                param_pspecs=pspecs)
state = init_train_state(params)
stack = lambda t: jax.tree.map(lambda x: jnp.stack([x, x]), t)
state2 = stack(state)
tok = jax.random.randint(jax.random.PRNGKey(1), (2, 4, 16), 0, cfg.vocab_size)
batch_same = {"tokens": tok[:1].repeat(2, 0), "labels": tok[:1].repeat(2, 0)}
batch_diff = {"tokens": tok, "labels": tok}
with mesh_context(mesh):
    ns, loss = jax.jit(step)(state2, batch_same)
    leaf = jax.tree.leaves(ns.params)[0]
    np.testing.assert_array_equal(np.asarray(leaf[0]), np.asarray(leaf[1]))
    nd, loss2 = jax.jit(step)(stack(state), batch_diff)
    leafd = jax.tree.leaves(nd.params)[-1]
    assert not np.allclose(np.asarray(leafd[0]), np.asarray(leafd[1])), \
        "pods with different data must diverge"
print("MULTIPOD_OK")

# ---- outer exchange vs single-host reference
h = HeLoCoConfig()
stacked = shd.stacked_axes_tree(params)
mom = jax.tree.map(lambda x: 0.01 * jnp.ones_like(x, jnp.float32), params)
wp = jax.tree.map(lambda x: jnp.stack([x - 0.05, x + 0.02]), params)
fn = make_outer_exchange(cfg, mesh, h=h, outer_lr=0.7, mu=0.9,
                         method="heloco", arriving_pod=1,
                         stacked_axes=stacked)
with mesh_context(mesh):
    new_p, new_m, bar = jax.jit(fn)(params, mom, wp)
# reference: delta from pod 1 only
delta_ref = jax.tree.map(
    lambda a, b: a.astype(jnp.float32) - b[1].astype(jnp.float32), params, wp)
g_ref = block_correct(delta_ref, mom, h, stacked_axes=stacked)
st_ref = outer_update(OuterState(params, mom, jnp.zeros((), jnp.int32)),
                      g_ref, 0.7, 0.9)
for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(st_ref.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
bar_ref = lookahead_init(st_ref, 0.7, 0.9)
for a, b in zip(jax.tree.leaves(bar), jax.tree.leaves(bar_ref)):
    np.testing.assert_allclose(np.asarray(a)[0], np.asarray(b), rtol=2e-5,
                               atol=2e-5)
print("EXCHANGE_OK")

# ---- int8-compressed exchange: close to uncompressed, not exact
fn8 = make_outer_exchange(cfg, mesh, h=h, outer_lr=0.7, mu=0.9,
                          method="heloco", arriving_pod=1,
                          stacked_axes=stacked, compress_int8=True)
with mesh_context(mesh):
    p8, m8, _ = jax.jit(fn8)(params, mom, wp)
num = den = 0.0
for a, b in zip(jax.tree.leaves(p8), jax.tree.leaves(new_p)):
    num += float(jnp.sum((a.astype(jnp.float32) - b.astype(jnp.float32))**2))
    den += float(jnp.sum(b.astype(jnp.float32)**2))
rel = (num / max(den, 1e-12)) ** 0.5
assert rel < 0.02, f"int8 exchange too lossy: {rel}"
print("INT8_OK", rel)
"""


def test_dist_semantics_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert "MULTIPOD_OK" in out.stdout, out.stdout + out.stderr
    assert "EXCHANGE_OK" in out.stdout, out.stdout + out.stderr
    assert "INT8_OK" in out.stdout, out.stdout + out.stderr


def test_sharding_rules_unit():
    """Pure-python rule checks (no devices needed)."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import spec_for
    sizes = {"data": 16, "model": 16}
    # divisible heads -> head TP
    assert spec_for("blocks/attn/wq", (28, 4096, 32, 128), data_axis="data",
                    model_axis="model", axis_sizes=sizes) == \
        P(None, "data", "model", None)
    # non-divisible heads -> head_dim TP fallback
    assert spec_for("blocks/attn/wq", (28, 3584, 28, 128), data_axis="data",
                    model_axis="model", axis_sizes=sizes) == \
        P(None, "data", None, "model")
    # vocab not divisible -> replicate vocab dim
    assert spec_for("embed/tok", (49155, 4096), data_axis="data",
                    model_axis="model", axis_sizes=sizes) == P(None, "data")
    # norm scale -> fully replicated
    assert spec_for("blocks/norm1/scale", (28, 4096), data_axis="data",
                    model_axis="model", axis_sizes=sizes) == P(None, None)
    # MoE experts over model axis
    assert spec_for("blocks/moe/w_gate", (24, 32, 1024, 512),
                    data_axis="data", model_axis="model",
                    axis_sizes=sizes) == P(None, "model", "data", None)


def test_cache_specs_unit():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import cache_specs
    sizes = {"data": 16, "model": 16}
    caches = {"k": jax.ShapeDtypeStruct((28, 128, 32768, 4, 128), jnp.bfloat16),
              "v": jax.ShapeDtypeStruct((28, 128, 32768, 4, 128), jnp.bfloat16)}
    # batch-sharded decode: B over data; kv=4 < 16 -> head_dim over model
    specs = cache_specs(caches, batch_sharded=True, axis_sizes=sizes)
    assert specs["k"] == P(None, "data", None, None, "model")
    # context-parallel long decode: S over data
    specs = cache_specs(caches, batch_sharded=False, axis_sizes=sizes)
    assert specs["k"] == P(None, None, "data", None, "model")
