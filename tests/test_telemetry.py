"""Telemetry subsystem: kernel-fused update-quality stats vs the
per-leaf reference (property-based, every registered method, stacked
axes, int8 path), schema round-trips, the byte-identity contract of the
telemetry-on arrival path, and budget accounting in the sim engine."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.utils.hypcompat import given, settings, st

from repro.configs.base import HeLoCoConfig, OuterOptConfig
from repro.core import methods as M
from repro.core import packing
from repro.core.compression import roundtrip_with_error_feedback
from repro.core.heloco import apply_arrival_packed
from repro.async_engine.engine import Budget, make_engine
from repro.async_engine.server import Synchronizer
from repro.scenarios import registry, trace
from repro.telemetry import (
    ArrivalMetrics, TelemetryRecorder, from_json_line, reference_moments,
    staleness_alignment, stats_from_moments, to_json_line,
)

H = HeLoCoConfig()


def _rand_tree(seed: int):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 4))
    shapes = {
        "stack": (k, int(rng.integers(1, 5)), int(rng.integers(1, 7))),
        "mat": (int(rng.integers(1, 9)), int(rng.integers(1, 9))),
        "vec": (int(rng.integers(1, 150)),),
    }
    stacked = {"stack": 1, "mat": 0, "vec": 0}
    key = jax.random.PRNGKey(seed)
    tree = {n: jax.random.normal(jax.random.fold_in(key, i), s)
            for i, (n, s) in enumerate(sorted(shapes.items()))}
    return tree, stacked


def _moments_close(got, want, rtol=1e-3, atol=1e-3):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Kernel-side stats == per-leaf reference (the core telemetry contract)
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 12.0, allow_nan=False))
def test_fused_stats_match_reference_every_method(seed, tau):
    """The (R, 4) moments the fused sweep emits reduce to exactly the
    per-leaf reference moments — for EVERY registered method, over
    random shapes and stacked layer axes."""
    params, stacked = _rand_tree(seed % 10_000)
    delta = {k: -0.4 * v + 0.05 for k, v in params.items()}
    mom = {k: 0.3 * v - 0.02 for k, v in params.items()}
    layout = packing.build_layout(params, stacked)
    pbuf = packing.pack(layout, params)
    mbuf = packing.pack(layout, mom)
    tau_j = jnp.asarray(tau, jnp.float32)
    for m in M.all_methods():
        abuf = packing.zeros(layout) if m.uses_buffer else None
        out = apply_arrival_packed(pbuf, mbuf, delta, layout,
                                   method=m.name, outer_lr=0.7, mu=0.9,
                                   h=H, rho=0.447, tau=tau, abuf=abuf,
                                   phase=1, with_stats=True)
        got = jnp.sum(out[-1], axis=0)
        ctx = M.ArrivalCtx(outer_lr=0.7, mu=0.9, h=H, rho=0.447,
                           tau=tau_j, phase=1, stacked_axes=stacked)
        corrected = m.correct(m, ctx, delta, mom)
        want = reference_moments(delta, mom, corrected)
        _moments_close(got, want)


def test_fused_stats_int8_packed_delta():
    """The int8 compression path hands the synchronizer a Packed decoded
    buffer; the fused stats must match the reference computed on the
    decoded pytree."""
    params, stacked = _rand_tree(7)
    delta = {k: 0.03 * v for k, v in params.items()}
    mom = {k: -0.2 * v for k, v in params.items()}
    layout = packing.build_layout(params, stacked)
    decoded, _, _ = roundtrip_with_error_feedback(delta, None, "int8",
                                                  layout=layout)
    assert isinstance(decoded, packing.Packed)
    pbuf = packing.pack(layout, params)
    mbuf = packing.pack(layout, mom)
    out = apply_arrival_packed(pbuf, mbuf, decoded, layout,
                               method="heloco", outer_lr=0.7, mu=0.9, h=H,
                               with_stats=True)
    got = jnp.sum(out[-1], axis=0)
    decoded_tree = packing.unpack(layout, decoded.buf, jnp.float32)
    ctx = M.ArrivalCtx(outer_lr=0.7, mu=0.9, h=H, stacked_axes=stacked)
    m = M.get("heloco")
    want = reference_moments(decoded_tree, mom,
                             m.correct(m, ctx, decoded_tree, mom))
    _moments_close(got, want)


def test_stats_from_moments_math():
    s = stats_from_moments([2.0, 4.0, 1.0, 9.0])
    assert s.delta_norm == 2.0 and s.momentum_norm == 1.0
    np.testing.assert_allclose(s.cos_align, 2.0 / (2.0 * 1.0))
    np.testing.assert_allclose(s.corrected_frac, 3.0 / 2.0)
    z = stats_from_moments([0.0, 0.0, 4.0, 0.0])   # dropped arrival shape
    assert z.cos_align == 0.0 and z.corrected_frac == 0.0
    assert z.delta_norm == 0.0 and z.momentum_norm == 2.0


# ---------------------------------------------------------------------------
# Synchronizer integration: packed vs reference engines agree
# ---------------------------------------------------------------------------

def _feed(sv, n=6, stale_by=3):
    params = sv.state.params
    for i in range(n):
        delta = jax.tree.map(
            lambda x: 0.05 * jax.random.normal(
                jax.random.PRNGKey(i), x.shape), params)
        sv.on_arrival(delta, s_i=max(0, sv.t - stale_by), worker_id=0)


def test_synchronizer_stats_packed_matches_reference_path():
    params = {"a": jax.random.normal(jax.random.PRNGKey(0), (24, 10)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (131,))}
    cfg = OuterOptConfig(method="heloco")
    svA = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3,
                       packed=True, telemetry=True)
    svB = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3,
                       packed=False, telemetry=True)
    _feed(svA)
    _feed(svB)
    for ra, rb in zip(svA.records, svB.records):
        assert ra.cos_align is not None and rb.cos_align is not None
        np.testing.assert_allclose(ra.cos_align, rb.cos_align,
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(ra.corrected_frac, rb.corrected_frac,
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(ra.delta_norm, rb.delta_norm,
                                   rtol=1e-3, atol=1e-3)
    # stats off by default: no diagnostics attached
    svC = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3)
    _feed(svC, n=2)
    assert all(r.cos_align is None for r in svC.records)


def test_dropped_arrival_stats_are_momentum_only():
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (40,))}
    cfg = OuterOptConfig(method="heloco", drop_stale_after=1)
    sv = Synchronizer(params, cfg, 2, telemetry=True)
    _feed(sv, n=6, stale_by=4)
    dropped = [r for r in sv.records if r.dropped]
    assert dropped
    for r in dropped:
        assert r.cos_align == 0.0 and r.delta_norm == 0.0
        assert r.momentum_norm > 0.0


# ---------------------------------------------------------------------------
# Schema + recorder round-trip
# ---------------------------------------------------------------------------

def test_schema_roundtrip_and_drift_rejection(tmp_path):
    a = ArrivalMetrics(outer_step=3, worker_id=1, staleness=2, rho=0.5,
                       sim_time=6.0, wall_time=0.1, lang="de",
                       dropped=False, cos_align=0.25, corrected_frac=0.1,
                       delta_norm=1.5, momentum_norm=0.7,
                       mixture=(0.8, 0.2), tokens_total=640)
    assert from_json_line(to_json_line(a)) == a
    with pytest.raises(ValueError):
        from_json_line('{"kind": "arrival", "outer_step": 1, "nope": 2}')
    with pytest.raises(ValueError):
        from_json_line('{"kind": "wat"}')


def test_staleness_alignment_analysis():
    def arr(tau, cos, dropped=False):
        return ArrivalMetrics(outer_step=0, worker_id=0, staleness=tau,
                              rho=1.0, sim_time=0.0, wall_time=0.0,
                              lang="", dropped=dropped, cos_align=cos,
                              corrected_frac=0.1, delta_norm=1.0,
                              momentum_norm=1.0)
    curve = staleness_alignment([arr(0, 0.8), arr(0, 0.6), arr(3, 0.1),
                                 arr(5, -0.2, dropped=True)])
    assert [pt["staleness"] for pt in curve] == [0, 3]
    np.testing.assert_allclose(curve[0]["mean_cos_align"], 0.7)
    assert curve[0]["n"] == 2


# ---------------------------------------------------------------------------
# The acceptance contract: telemetry-on runs are byte-identical
# ---------------------------------------------------------------------------

def test_telemetry_on_arrival_path_is_byte_identical_to_golden():
    """Running a registered scenario WITH telemetry must reproduce its
    committed golden trace exactly (param digest included) — the stats
    are extra kernel outputs, never extra math in the update."""
    scn = registry.get_scenario("paper_hetero_severe")
    rec = TelemetryRecorder()
    doc = trace.run_trace(scn, telemetry=rec)
    res = trace.verify(scn, fresh=doc)
    assert res.ok, res.failures
    arrivals = rec.arrivals()
    assert len(arrivals) == scn.outer_steps
    assert all(a.cos_align is not None for a in arrivals)
    assert rec.evals() and rec.evals()[-1].per_lang
    assert rec.meta is not None and rec.meta.scenario == scn.name


# ---------------------------------------------------------------------------
# Budget accounting (sim engine; the wallclock lane covers the runtime)
# ---------------------------------------------------------------------------

TINY = registry.get_scenario("paper_hetero_severe")
ROUND_TOKENS = TINY.inner_steps * TINY.batch_size * TINY.seq_len


def test_budget_validation():
    with pytest.raises(AssertionError):
        Budget("nope", 10)
    with pytest.raises(AssertionError):
        Budget("fixed_tokens", 0)
    b = Budget("fixed_tokens", 100)
    assert b.over_tokens(100) and not b.over_tokens(99)
    assert not b.over_time(1e9)
    w = Budget("fixed_wallclock", 5.0)
    assert w.over_time(5.01) and not w.over_time(5.0)
    assert not w.over_tokens(10 ** 12)


def test_fixed_tokens_stops_within_one_round_sim():
    target = ROUND_TOKENS * 5
    eng = make_engine(TINY.materialize().run_cfg)
    hist = eng.run(budget=Budget("fixed_tokens", target))
    assert target <= hist.tokens < target + ROUND_TOKENS
    assert len(hist.arrivals) < TINY.outer_steps


def test_fixed_wallclock_never_commits_past_horizon_sim():
    horizon = 8.0
    eng = make_engine(TINY.materialize().run_cfg)
    hist = eng.run(budget=Budget("fixed_wallclock", horizon))
    assert hist.arrivals and len(hist.arrivals) < TINY.outer_steps
    assert all(a["sim_time"] <= horizon for a in hist.arrivals)
    assert hist.final_time <= horizon
    # and the run would have continued: the NEXT arrival of an unbudgeted
    # replay lands past the horizon
    full = make_engine(TINY.materialize().run_cfg).run()
    nxt = [a["sim_time"] for a in full.arrivals
           if a["sim_time"] > horizon]
    assert nxt, "horizon not binding for this scenario"


def test_fixed_tokens_stops_sync_engine_within_one_round():
    scn = registry.get_scenario("sync_baseline")
    rc = scn.materialize().run_cfg
    round_tokens = scn.n_workers * scn.inner_steps * scn.batch_size \
        * scn.seq_len
    target = round_tokens * 2
    hist = make_engine(rc).run(budget=Budget("fixed_tokens", target))
    assert target <= hist.tokens < target + round_tokens


def test_fixed_wallclock_stops_sync_engine_before_horizon():
    scn = registry.get_scenario("sync_baseline")
    rc = scn.materialize().run_cfg
    # slowest worker pace 6.0 x 2 inner steps = 12s per barrier round
    hist = make_engine(rc).run(budget=Budget("fixed_wallclock", 30.0))
    assert hist.final_time <= 30.0
    assert 0 < len(hist.arrivals) < scn.outer_steps


@pytest.mark.wallclock
def test_budget_accounting_wallclock_engine():
    """Both budget kinds stop the deterministic ConcurrentRuntime within
    one outer round, same semantics as the simulator."""
    m = TINY.materialize()
    target = ROUND_TOKENS * 4
    eng = make_engine(m.run_cfg, "wallclock", mode="deterministic")
    hist = eng.run(budget=Budget("fixed_tokens", target))
    assert target <= hist.tokens < target + ROUND_TOKENS

    eng2 = make_engine(m.run_cfg, "wallclock", mode="deterministic")
    hist2 = eng2.run(budget=Budget("fixed_wallclock", 8.0))
    assert hist2.arrivals and all(a["sim_time"] <= 8.0
                                  for a in hist2.arrivals)


@pytest.mark.wallclock
def test_telemetry_streams_from_wallclock_engine():
    rec = TelemetryRecorder()
    m = TINY.materialize()
    eng = make_engine(m.run_cfg, "wallclock", mode="deterministic",
                      telemetry=rec)
    hist = eng.run()
    arrivals = rec.arrivals()
    assert len(arrivals) == len(hist.arrivals)
    assert all(a.cos_align is not None for a in arrivals)
    assert math.isfinite(sum(a.wall_time for a in arrivals))
