"""Delivery-robustness layer: deterministic fault dice, FaultyTransport
injection semantics, at-least-once bookkeeping (DeliveryTracker), payload
checksums, the `fault` telemetry record, and the Scenario.faults axis.

Pure unit tests (no training runs) — tier-1."""
import dataclasses
import json

import numpy as np
import pytest

from repro.async_engine.faults import (
    DELIVERY_COUNTERS, DeliveryTracker, FaultSpec, FaultyTransport,
    PartitionSpec,
)
from repro.async_engine.proc import SocketTransport
from repro.async_engine.transport import (
    Ack, AckWaiter, Envelope, InProcTransport, KIND_HEARTBEAT, KIND_RESULT,
    payload_crc,
)
from repro.scenarios import Scenario, get_scenario, names
from repro.telemetry import TelemetryRecorder, schema


@dataclasses.dataclass
class FakeResult:
    """Duck-types the .delta payload_crc checksums."""
    delta: object


def env_for(seq, *, wid=0, gen=0, kind=KIND_RESULT, payload=None, crc=None,
            attempt=0):
    payload = payload if payload is not None else FakeResult(
        {"w": np.arange(4, dtype=np.float32) + seq})
    if crc is None:
        crc = payload_crc(payload)
    return Envelope(wid=wid, generation=gen, seq=seq, kind=kind,
                    payload=payload, crc=crc, attempt=attempt)


# ---------------------------------------------------------------------------
# FaultSpec: deterministic dice
# ---------------------------------------------------------------------------

def test_fault_dice_deterministic_and_rate():
    a = FaultSpec(drop_p=0.3, seed=1)
    b = FaultSpec(drop_p=0.3, seed=1)
    keys = [(w, s, t) for w in range(4) for s in range(300) for t in range(2)]
    da = [a.drops(*k) for k in keys]
    assert da == [b.drops(*k) for k in keys]     # pure function of the key
    rate = sum(da) / len(da)
    assert 0.25 < rate < 0.35, rate
    # independent streams: a retried frame draws fresh dice
    assert any(a.drops(w, s, 0) != a.drops(w, s, 1)
               for w in range(4) for s in range(50))
    # different seeds give different patterns
    c = FaultSpec(drop_p=0.3, seed=2)
    assert da != [c.drops(*k) for k in keys]


def test_fault_types_roll_independent_dice():
    spec = FaultSpec(drop_p=0.5, dup_p=0.5, seed=3)
    keys = [(0, s, 0) for s in range(200)]
    drops = [spec.drops(*k) for k in keys]
    dups = [spec.duplicates(*k) for k in keys]
    assert drops != dups                          # distinct stream salts


def test_retry_jitter_bounded_and_deterministic():
    spec = FaultSpec(seed=9)
    js = [spec.retry_jitter(0, s, t) for s in range(100) for t in range(3)]
    assert all(0.0 <= j < 0.25 for j in js)
    assert len(set(js)) > 50                      # actually varies
    assert js == [FaultSpec(seed=9).retry_jitter(0, s, t)
                  for s in range(100) for t in range(3)]


def test_partition_spec_covers():
    p = PartitionSpec(start=1.0, end=2.0, wids=(1, 3))
    assert p.covers(1, 1.5) and p.covers(3, 1.0)
    assert not p.covers(2, 1.5)                   # other wid
    assert not p.covers(1, 2.0)                   # end-exclusive
    everyone = PartitionSpec(start=0.0, end=1.0)
    assert everyone.covers(7, 0.5)
    spec = FaultSpec(partitions=(p,))
    assert spec.in_partition(3, 1.2) and not spec.in_partition(3, 5.0)


def test_fault_spec_json_round_trip():
    spec = FaultSpec(drop_p=0.2, corrupt_p=0.1, corrupt_wids=(1, 2),
                     partitions=(PartitionSpec(0.5, 2.5, wids=(0,)),),
                     seed=4, heartbeat_interval=0.1, quarantine_after=3)
    back = FaultSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec


# ---------------------------------------------------------------------------
# FaultyTransport injection semantics — parametrized over both wrapped
# backends: the in-process queue and the socket backend's loopback
# channel (real frames over a real wire, same process — the exact shape
# the child-side chaos wrappers see in a worker process).
# ---------------------------------------------------------------------------

@pytest.fixture(params=["inproc", "socket"])
def make_channel(request):
    made = []

    def make(capacity=16):
        tr = (InProcTransport(capacity=capacity)
              if request.param == "inproc"
              else SocketTransport(capacity=capacity))
        made.append(tr)
        return tr

    yield make
    for tr in made:
        tr.close()


def test_faulty_transport_drops_only_envelopes(make_channel):
    inner = make_channel(16)
    tr = FaultyTransport(inner, FaultSpec(drop_p=1.0, seed=0))
    tr.send(env_for(1))
    tr.send("not-an-envelope")                    # non-frames pass through
    assert tr.counters["injected_drops"] == 1
    assert tr.recv(timeout=0.5) == "not-an-envelope"
    assert tr.depth() == 0


def test_faulty_transport_duplicates_and_dedup(make_channel):
    inner = make_channel(16)
    tr = FaultyTransport(inner, FaultSpec(dup_p=1.0, seed=0))
    tr.send(env_for(1))
    got = [tr.recv(timeout=0.5), tr.recv(timeout=0.5)]
    assert [g.seq for g in got] == [1, 1]
    tracker = DeliveryTracker()
    assert tracker.process(got[0]).status == "accept"
    v = tracker.process(got[1])
    assert v.status == "dup" and v.ack            # redelivery is re-acked
    assert tracker.counters["redelivered_deduped"] == 1


def test_faulty_transport_adjacent_swap_reorder_and_close_flush():
    # inproc-only: the close-flush assertion recv's AFTER close, and the
    # socket loopback tears its connections down concurrently with the
    # in-flight flush frame — the drained-after-close guarantee is the
    # in-process queue's contract
    inner = InProcTransport(capacity=16)
    tr = FaultyTransport(inner, FaultSpec(reorder_p=1.0, seed=0))
    tr.send(env_for(1))                           # shelved
    assert inner.depth() == 0
    tr.send(env_for(2))                           # releases the shelf after
    got = [tr.recv(timeout=0.5).seq for _ in range(2)]
    assert got == [2, 1]                          # FIFO broken by one swap
    tr.send(env_for(3))                           # shelved again
    tr.close()                                    # flush: frame not lost
    assert tr.counters["injected_reorders"] == 2
    assert inner.recv(timeout=0.5).seq == 3


def test_faulty_transport_corrupts_copy_not_sender(make_channel):
    inner = make_channel(16)
    tr = FaultyTransport(inner, FaultSpec(corrupt_p=1.0, seed=0))
    env = env_for(1)
    tr.send(env)
    wire = tr.recv(timeout=0.5)
    assert wire.crc != env.crc                    # corrupted on the wire
    assert env.crc == payload_crc(env.payload)    # sender's frame pristine
    v = DeliveryTracker().process(wire)
    assert v.status == "reject" and not v.ack     # no ack -> sender retries
    # heartbeats carry no checksummed payload: never corrupted
    hb = env_for(2, kind=KIND_HEARTBEAT, payload=None, crc=0)
    tr.send(hb)
    assert tr.recv(timeout=0.5).crc == 0


def test_partition_window_requires_clock(make_channel):
    spec = FaultSpec(partitions=(PartitionSpec(0.0, 1.0),))
    with pytest.raises(ValueError):
        FaultyTransport(make_channel(4), spec)
    t = [0.5]
    tr = FaultyTransport(make_channel(4), spec, clock=lambda: t[0])
    tr.send(env_for(1))
    assert tr.counters["partition_drops"] == 1
    t[0] = 2.0                                    # window over: heals
    tr.send(env_for(1, attempt=1))
    assert tr.recv(timeout=0.5).seq == 1


# ---------------------------------------------------------------------------
# DeliveryTracker: dedup, rejection, quarantine
# ---------------------------------------------------------------------------

def test_tracker_dedup_is_per_stream_high_water():
    tr = DeliveryTracker()
    assert tr.process(env_for(1)).status == "accept"
    assert tr.process(env_for(2)).status == "accept"
    assert tr.process(env_for(2)).status == "dup"     # redelivery
    assert tr.process(env_for(1)).status == "dup"     # late reordered copy
    # a generation bump outranks the seq high-water
    assert tr.process(env_for(3, gen=1)).status == "accept"
    assert tr.process(env_for(3, gen=0)).status == "dup"
    # an independent worker stream is unaffected
    assert tr.process(env_for(1, wid=5)).status == "accept"
    # a restarted thread starts a fresh stream
    tr.reset_stream(0)
    assert tr.process(env_for(1)).status == "accept"


def test_tracker_quarantines_after_consecutive_corruption():
    tr = DeliveryTracker(quarantine_after=3)
    bad = lambda seq: env_for(seq, crc=12345)         # wrong checksum
    assert tr.process(bad(1)).status == "reject"
    assert tr.process(bad(2)).status == "reject"
    v = tr.process(bad(3))                            # third consecutive
    assert v.status == "reject" and v.quarantine and v.ack
    assert 0 in tr.quarantined
    assert tr.counters["quarantines"] == 1
    assert tr.counters["checksum_rejects"] == 3
    # everything from a quarantined worker is acked-and-discarded
    v = tr.process(env_for(4))
    assert v.status == "reject" and v.ack and v.quarantine


def test_tracker_clean_frame_resets_corruption_streak():
    tr = DeliveryTracker(quarantine_after=2)
    assert tr.process(env_for(1, crc=1)).status == "reject"
    assert tr.process(env_for(1)).status == "accept"  # clean retry
    assert tr.process(env_for(2, crc=1)).status == "reject"
    assert not tr.quarantined                         # streak was broken
    assert all(k in tr.counters for k in DELIVERY_COUNTERS)


def test_payload_crc_sensitive_to_values():
    a = FakeResult({"w": np.ones(8, np.float32)})
    b = FakeResult({"w": np.ones(8, np.float32)})
    assert payload_crc(a) == payload_crc(b)
    b.delta["w"][3] = 2.0
    assert payload_crc(a) != payload_crc(b)


def test_ack_waiter_matches_discards_and_closes():
    w = AckWaiter()
    env = env_for(5)
    w.put(Ack(wid=0, generation=0, seq=4))            # stale: discarded
    w.put(Ack(wid=0, generation=0, seq=5))
    ack = w.wait_for(env, timeout=0.5)
    assert ack is not None and ack.seq == 5
    assert w.wait_for(env, timeout=0.05) is None      # timeout path
    assert not w.closed
    w.close()
    assert w.wait_for(env, timeout=0.05) is None and w.closed


# ---------------------------------------------------------------------------
# Telemetry: the `fault` record kind (schema v2)
# ---------------------------------------------------------------------------

def test_schema_v2_fault_record_round_trip():
    # the fault kind arrived in v2; the schema has since grown (v3 added
    # the runtime kind) but fault records must keep round-tripping
    assert schema.SCHEMA_VERSION >= 2
    rec = schema.FaultMetrics(event="checksum_reject", wall_time=1.5,
                              wid=2, seq=7, generation=1)
    back = schema.from_json_line(schema.to_json_line(rec))
    assert back == rec
    summary = schema.FaultMetrics(event="summary", wall_time=9.0,
                                  detail={"retries": 3.0})
    assert schema.from_json_line(schema.to_json_line(summary)) == summary
    with pytest.raises(ValueError):
        schema.from_json_line('{"kind": "fault", "event": "x", '
                              '"wall_time": 0.0, "bogus": 1}')


def test_recorder_fault_records_and_jsonl(tmp_path):
    rec = TelemetryRecorder()
    rec.ensure_meta(method="heloco", engine="wallclock", n_workers=2,
                    outer_steps=4, seed=0)
    rec.record_fault(event="dedup", wid=1, seq=3, generation=0)
    rec.record_fault(event="summary", detail={"retries": 2, "quarantines": 0})
    assert [f.event for f in rec.faults()] == ["dedup", "summary"]
    path = str(tmp_path / "t.jsonl")
    rec.write_jsonl(path)
    back = TelemetryRecorder.read_jsonl(path)
    assert back.meta.schema_version == schema.SCHEMA_VERSION
    assert [f.event for f in back.faults()] == ["dedup", "summary"]
    assert back.faults()[1].detail == {"retries": 2.0, "quarantines": 0.0}


# ---------------------------------------------------------------------------
# Scenario axis
# ---------------------------------------------------------------------------

def test_scenario_faults_round_trip_and_materialize():
    scn = Scenario(name="t", engine="wallclock",
                   faults=FaultSpec(drop_p=0.2, seed=7))
    assert Scenario.from_dict(json.loads(json.dumps(scn.to_dict()))) == scn
    m = scn.materialize()
    assert m.engine_kw["faults"] == scn.faults


def test_scenario_to_dict_omits_faults_when_none():
    # recorded goldens compare the scenario dict byte-for-byte: the new
    # axis must be invisible on every pre-existing (fault-free) scenario
    d = Scenario(name="t").to_dict()
    assert "faults" not in d
    assert Scenario.from_dict(d).faults is None


def test_scenario_rejects_bad_fault_combinations():
    with pytest.raises(AssertionError):
        Scenario(name="t", engine="sim", faults=FaultSpec(drop_p=0.1))
    with pytest.raises(AssertionError):
        Scenario(name="t", engine="wallclock", mode="deterministic",
                 faults=FaultSpec(partitions=(PartitionSpec(0.0, 1.0),)))


def test_chaos_scenarios_registered():
    for name in ("chaos_lossy", "chaos_partition", "chaos_corrupt"):
        assert name in names()
        scn = get_scenario(name)
        assert scn.engine == "wallclock" and scn.faults is not None
    lossy = get_scenario("chaos_lossy")
    twin = get_scenario("wallclock_hetero")
    # the digest-identity claim only holds if the twins share the exact
    # run config — everything but the fault axis
    assert lossy.run_config() == twin.run_config()
    assert get_scenario("chaos_corrupt").run_config() == twin.run_config()
    assert get_scenario("chaos_partition").mode == "free"
