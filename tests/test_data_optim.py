"""Data pipeline + inner optimizer unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.utils.hypcompat import given, settings, st

from repro.configs.base import InnerOptConfig
from repro.data.synthetic import (
    ShardSampler, eval_batches, make_language_specs, sample_tokens,
)
from repro.optim.adamw import (
    AdamState, adamw_update, clip_by_global_norm, global_norm, init_adam,
)
from repro.optim.schedules import cosine_warmup


# ------------------------------- data -------------------------------------

def test_shards_are_deterministic_and_distinct():
    specs = make_language_specs(512, n_langs=5, seed=0)
    s0 = ShardSampler(specs, 0, batch=4, seq=32, seed=7)
    s0b = ShardSampler(specs, 0, batch=4, seq=32, seed=7)
    s1 = ShardSampler(specs, 1, batch=4, seq=32, seed=7)
    a, b, c = s0.sample(3), s0b.sample(3), s1.sample(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # deterministic
    assert not np.array_equal(a["tokens"], c["tokens"])      # non-IID differs


def test_language_token_ranges_disjoint():
    specs = make_language_specs(512, n_langs=5, seed=0)
    rng = np.random.default_rng(0)
    toks0 = sample_tokens(specs[0], 8, 128, rng)
    toks1 = sample_tokens(specs[1], 8, 128, rng)
    shared_hi = specs[0].shared_hi
    own0 = toks0[toks0 >= shared_hi]
    own1 = toks1[toks1 >= shared_hi]
    assert own0.max() < specs[1].lo or own0.min() >= specs[1].hi
    assert len(np.intersect1d(np.unique(own0), np.unique(own1))) == 0


def test_labels_are_shifted_tokens():
    specs = make_language_specs(256, n_langs=2, seed=1)
    s = ShardSampler(specs, 0, batch=2, seq=16, seed=3)
    b = s.sample(0)
    assert b["tokens"].shape == (2, 16)
    assert b["labels"].shape == (2, 16)


def test_eval_batches_cover_all_langs():
    specs = make_language_specs(512, n_langs=5, seed=0)
    evs = eval_batches(specs, 4, 32)
    assert len(evs) == 5
    assert len({e["lang"] for e in evs}) == 5


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_sampler_tokens_in_vocab(step, batch):
    specs = make_language_specs(128, n_langs=3, seed=2)
    s = ShardSampler(specs, step % 3, batch=batch, seq=8, seed=11)
    b = s.sample(step)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < 128


# ------------------------------- optim ------------------------------------

def test_adamw_decreases_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_adam(params)
    cfg = InnerOptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                         weight_decay=0.0, schedule="constant")
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_bounds_norm():
    g = {"a": jnp.full((10,), 100.0), "b": jnp.full((5,), -50.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    lr0 = float(cosine_warmup(0, 1.0, warmup_steps=10, total_steps=100))
    lr_w = float(cosine_warmup(10, 1.0, warmup_steps=10, total_steps=100))
    lr_end = float(cosine_warmup(100, 1.0, warmup_steps=10, total_steps=100))
    assert lr0 == 0.0
    assert lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, rel=1e-3)  # final_frac default


def test_adam_count_increments_and_bias_correction():
    params = {"w": jnp.ones((3,))}
    opt = init_adam(params)
    cfg = InnerOptConfig(lr=0.01, warmup_steps=0, total_steps=10,
                         schedule="constant", weight_decay=0.0)
    g = {"w": jnp.ones((3,))}
    p1, opt = adamw_update(params, g, opt, cfg)
    assert int(opt.count) == 1
    # first Adam step with constant grad ~= lr * sign(g)
    np.testing.assert_allclose(np.asarray(params["w"] - p1["w"]),
                               0.01 * np.ones(3), rtol=1e-3)


# ---------------------------- compression ---------------------------------

def test_error_feedback_converges():
    """With error feedback, repeated compression of a constant signal must
    deliver the full mass over time (unbiasedness over rounds)."""
    from repro.core.compression import roundtrip_with_error_feedback
    target = {"w": jnp.asarray(np.random.default_rng(0).normal(size=512),
                               jnp.float32)}
    ef = None
    delivered = jnp.zeros(512)
    for _ in range(30):
        dec, ef, _ = roundtrip_with_error_feedback(target, ef, "topk", 0.1)
        delivered = delivered + dec["w"]
    avg = delivered / 30
    err = float(jnp.linalg.norm(avg - target["w"]) /
                jnp.linalg.norm(target["w"]))
    assert err < 0.25, err


def test_int8_roundtrip_error_bound():
    from repro.core.compression import compress, decompress
    x = {"w": jnp.linspace(-4.0, 4.0, 1000)}
    c = compress(x, "int8")
    y = decompress(c, x)
    assert float(jnp.abs(y["w"] - x["w"]).max()) <= 4.0 / 127.0 + 1e-6
