"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs. Also exercises prefill+decode for
non-encoder archs (the serving path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, reduced
from repro.models import build_model


def make_batch(cfg, key, batch=2, seq=32):
    ks = jax.random.split(key, 3)
    b = {}
    if cfg.frontend.kind == "audio":
        b["features"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model))
        b["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size)
    elif cfg.frontend.kind == "vision":
        npfx = cfg.frontend.n_prefix_tokens
        b["patches"] = jax.random.normal(ks[0], (batch, npfx, cfg.d_model))
        b["tokens"] = jax.random.randint(ks[1], (batch, seq - npfx), 0, cfg.vocab_size)
        b["labels"] = jax.random.randint(ks[2], (batch, seq - npfx), 0, cfg.vocab_size)
    else:
        b["tokens"] = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size)
        b["labels"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_grad(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    loss, aux = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32))), f"{arch}: NaN grad"
    # at least one nonzero grad
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if not get_config(a).encoder_only])
def test_prefill_decode(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), batch=2, seq=32)
    cache_len = 40

    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=cache_len))(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.asarray(32, jnp.int32)
    logits2, caches = jax.jit(model.decode)(params, tok, caches, pos)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training forward logits."""
    cfg = reduced(get_config("qwen2-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}

    # full forward logits at each position
    from repro.models.layers import apply_norm, lm_logits
    x, positions = model._embed(params, batch)
    def fwd_logits(p):
        from repro.models.transformer import apply_attn_block
        xx = x
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], p["blocks"])
            xx, _ = apply_attn_block(lp, xx, cfg, positions=positions)
        xx = apply_norm(p["final_norm"], xx, cfg)
        return lm_logits(p["embed"], xx, cfg)
    full = fwd_logits(params)

    # prefill on the first 4 tokens then decode the rest teacher-forced
    pre = {"tokens": tokens[:, :4], "labels": tokens[:, :4]}
    logits, caches = model.prefill(params, pre, cache_len=8)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 3]),
                               rtol=2e-4, atol=2e-4)
    for t in range(4, 8):
        logits, caches = model.decode(params, tokens[:, t],
                                      caches, jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_unroll_matches_scan():
    """unroll=True (dry-run cost-probe path) must be numerically identical."""
    for arch in ("qwen2-7b", "zamba2-2.7b", "granite-moe-1b-a400m", "xlstm-125m"):
        cfg = reduced(get_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        l1, _ = model.loss(params, batch, unroll=False)
        l2, _ = model.loss(params, batch, unroll=True)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)


def test_remat_group_matches_per_layer():
    """Grouped remat (k-th-layer checkpointing) must be numerically
    identical to per-layer remat (it only changes what is stored)."""
    import dataclasses
    cfg = reduced(get_config("qwen2-7b"))
    cfg1 = dataclasses.replace(cfg, remat=True, remat_group=1)
    cfg2 = dataclasses.replace(cfg, remat=True, remat_group=2)
    m1, m2 = build_model(cfg1), build_model(cfg2)
    params = m1.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    g2 = jax.grad(lambda p: m2.loss(p, batch)[0])(params)
    # The grouped-remat backward recomputes activations in a different
    # association order (per-group scan vs per-layer scan), so XLA is free
    # to fuse/accumulate fp32 sums differently; observed worst case is
    # ~6.5e-5 relative on isolated elements. 2e-4 is a comfortably
    # fp32-realistic bound while still catching a wrong-group bug (which
    # shifts whole tensors, not lone ulps).
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)
