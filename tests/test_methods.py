"""The pluggable outer-method layer (repro.core.methods): registry
surface, per-method packed <-> per-leaf equivalence (property-based, for
EVERY registered method), the decay-collapse identity the dropped-arrival
fast path assumes, the buffered delayed-Nesterov schedule, and the
no-string-branches contract."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.utils.hypcompat import given, settings, st

from repro.configs.base import HeLoCoConfig, OuterOptConfig
from repro.core import methods as M
from repro.core import packing
from repro.core.heloco import (
    apply_arrival, apply_arrival_packed, init_outer_state,
    momentum_decay_packed, momentum_decay_update,
)
from repro.async_engine.server import Synchronizer

H = HeLoCoConfig()

CANONICAL = ("heloco", "mla", "nesterov", "sync_nesterov",
             "delayed_nesterov", "dcasgd", "fedbuff", "poly_stale")


# ---------------------------------------------------------------------------
# Registry surface
# ---------------------------------------------------------------------------

def test_registry_names_aliases_and_table():
    names = M.names()
    for n in CANONICAL:
        assert n in names, n
    # aliases resolve to the same definition object
    assert M.get("async-heloco") is M.get("heloco")
    assert M.get("sync-nesterov") is M.get("sync_nesterov")
    assert M.canonical("async-delayed-nesterov") == "delayed_nesterov"
    with pytest.raises(KeyError):
        M.get("nope")
    # the Table-3 view matches the definitions field-for-field
    table = M.method_table()
    assert table["nesterov"]["outer_lr"] == 0.07
    assert table["sync_nesterov"]["weight_factor"] == "average"
    for m in M.all_methods():
        assert table[m.name] == m.defaults()
    # every alias maps onto a registered canonical name
    for alias, raw in M.alias_table().items():
        assert raw in table and alias in M.cli_names()


def test_register_rejects_duplicates():
    dup = M.OuterMethod(
        name="heloco", description="dup", outer_lr=0.1,
        correct=lambda m, c, d, mo: d,
        packed_coeffs=lambda m, c, db, mb: (None, None, None))
    with pytest.raises(ValueError):
        M.register(dup)


def test_structural_flags():
    assert M.get("sync_nesterov").sync
    assert not M.get("heloco").sync
    assert M.get("delayed_nesterov").uses_buffer
    assert M.get("delayed_nesterov").custom_update
    assert not M.get("dcasgd").uses_buffer
    assert not M.get("dcasgd").custom_update       # quad term, std schedule
    assert M.get("nesterov").outer_lr_cap == 0.07
    # MLA's magic staleness clip lives in exactly one place
    assert M.get("mla").tau_clip == 10.0


def test_lookahead_participation_replaces_string_gate():
    """Only methods with lookahead_init=True hand out the Eq. 5 model,
    even when the config flag is forced on (the old hard-coded
    ``method in ("heloco", "mla")`` gate, now data)."""
    params = {"w": jnp.ones((4, 4))}
    for name in ("heloco", "mla"):
        sv = Synchronizer(params, OuterOptConfig(method=name), 2)
        got = sv.worker_init()["w"]
        np.testing.assert_array_equal(np.asarray(got), 1.0)  # zero momentum
        assert sv.method.lookahead_init
    for name in ("nesterov", "delayed_nesterov", "dcasgd"):
        sv = Synchronizer(params, OuterOptConfig(method=name,
                                                 lookahead_init=True), 2)
        assert not sv.method.lookahead_init
        assert sv.worker_init() is sv.state.params


def test_no_method_string_branches_outside_registry():
    """The acceptance contract: no ``if method == ...`` dispatch anywhere
    outside core/methods.py."""
    src_root = pathlib.Path(M.__file__).resolve().parents[1]   # src/repro
    bench_root = src_root.parents[1] / "benchmarks"
    offenders = []
    for root in (src_root, bench_root):
        for p in root.rglob("*.py"):
            if p.name == "methods.py":
                continue
            if "method ==" in p.read_text():
                offenders.append(str(p))
    assert not offenders, offenders


# ---------------------------------------------------------------------------
# Property suite: every registered method, random shapes / stacked axes
# ---------------------------------------------------------------------------

def _rand_tree(seed: int):
    """Random multi-leaf pytree incl. a stacked layer axis and an odd-size
    vector (padding boundary coverage)."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 4))
    shapes = {
        "stack": (k, int(rng.integers(1, 5)), int(rng.integers(1, 7))),
        "mat": (int(rng.integers(1, 9)), int(rng.integers(1, 9))),
        "vec": (int(rng.integers(1, 150)),),
    }
    stacked = {"stack": 1, "mat": 0, "vec": 0}
    key = jax.random.PRNGKey(seed)

    def draw(i, shp):
        return jax.random.normal(jax.random.fold_in(key, i), shp)

    tree = {n: draw(i, s) for i, (n, s) in enumerate(sorted(shapes.items()))}
    return tree, stacked


def _rand_like(tree, seed: int):
    """Fresh values, same structure/shapes (pseudo-gradient for `tree`)."""
    key = jax.random.PRNGKey(seed * 7919 + 13)
    leaves, treedef = jax.tree.flatten(tree)
    return jax.tree.unflatten(treedef, [
        jax.random.normal(jax.random.fold_in(key, i), x.shape)
        for i, x in enumerate(leaves)])


def _tree_close(a, b, **tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), **tol)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 12.0, allow_nan=False))
def test_packed_equals_per_leaf_every_method(seed, tau):
    """(a) per-leaf reference <-> packed-path equivalence for EVERY
    registered method, over random shapes and stacked axes."""
    params, stacked = _rand_tree(seed % 10_000)
    delta = _rand_like(params, seed % 10_000)
    mom = jax.tree.map(lambda x: -0.3 * x + 0.1, delta)
    layout = packing.build_layout(params, stacked)
    pbuf = packing.pack(layout, params)
    mbuf = packing.pack(layout, mom)
    for m in M.all_methods():
        state = init_outer_state(
            params, with_aux=m.uses_buffer)._replace(momentum=mom)
        abuf = packing.zeros(layout) if m.uses_buffer else None
        for phase in (0, max(m.buffer_period - 1, 0)):
            ref = apply_arrival(state, delta, method=m.name, outer_lr=0.7,
                                mu=0.9, h=H, rho=0.447, tau=tau,
                                stacked_axes=stacked, phase=phase)
            out = apply_arrival_packed(pbuf, mbuf, delta, layout,
                                       method=m.name, outer_lr=0.7, mu=0.9,
                                       h=H, rho=0.447, tau=tau, abuf=abuf,
                                       phase=phase)
            if m.uses_buffer:
                p2, m2, b2 = out
                _tree_close(ref.aux,
                            packing.unpack(layout, b2, jnp.float32),
                            rtol=3e-5, atol=3e-5)
            else:
                p2, m2 = out
            _tree_close(ref.params, packing.unpack(layout, p2),
                        rtol=3e-5, atol=3e-5)
            _tree_close(ref.momentum,
                        packing.unpack(layout, m2, jnp.float32),
                        rtol=3e-5, atol=3e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.0, 12.0, allow_nan=False))
def test_decay_collapse_identity_every_method(seed, tau):
    """(b) apply_arrival(zero delta) == momentum_decay_update for EVERY
    registered method — the identity the dropped-arrival fast path
    assumes (generalizing the old _decay_coeffs)."""
    params, stacked = _rand_tree(seed % 10_000)
    mom = jax.tree.map(lambda x: 0.1 * x, params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    layout = packing.build_layout(params, stacked)
    for m in M.all_methods():
        state = init_outer_state(
            params, with_aux=m.uses_buffer)._replace(momentum=mom)
        for phase in (0, max(m.buffer_period - 1, 0)):
            want = apply_arrival(state, zeros, method=m.name, outer_lr=0.7,
                                 mu=0.9, h=H, rho=0.447, tau=tau,
                                 stacked_axes=stacked, phase=phase)
            got = momentum_decay_update(state, 0.7, 0.9, method=m.name,
                                        rho=0.447, tau=tau, phase=phase)
            _tree_close(want.params, got.params, rtol=1e-6, atol=1e-6)
            _tree_close(want.momentum, got.momentum, rtol=1e-6, atol=1e-6)
            if m.uses_buffer:
                _tree_close(want.aux, got.aux, rtol=1e-6, atol=1e-6)
            # and the packed decay step agrees with the per-leaf one
            pbuf = packing.pack(layout, params)
            mbuf = packing.pack(layout, mom)
            abuf = packing.zeros(layout) if m.uses_buffer else None
            outp = momentum_decay_packed(pbuf, mbuf, 0.7, 0.9,
                                         method=m.name, rho=0.447, tau=tau,
                                         abuf=abuf, phase=phase)
            _tree_close(got.params, packing.unpack(layout, outp[0]),
                        rtol=3e-5, atol=3e-5)
            _tree_close(got.momentum,
                        packing.unpack(layout, outp[1], jnp.float32),
                        rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# New-method semantics
# ---------------------------------------------------------------------------

def test_delayed_nesterov_momentum_refresh_cycle():
    """Momentum is frozen between boundaries, refreshes from the buffer
    average every N arrivals, and the buffer resets."""
    m = M.get("delayed_nesterov")
    n = m.buffer_period
    params = {"w": jnp.ones((6, 4))}
    sv = Synchronizer(params, OuterOptConfig(method="delayed_nesterov",
                                             weight_factor="one"), 1)
    delta = {"w": 0.1 * jnp.ones((6, 4))}
    mom_before = np.asarray(sv.state.momentum["w"])
    np.testing.assert_array_equal(mom_before, 0.0)
    for i in range(n - 1):
        sv.on_arrival(jax.tree.map(jnp.copy, delta), s_i=sv.t, worker_id=0)
        # momentum still frozen at zero; buffer accumulating
        np.testing.assert_allclose(np.asarray(sv.state.momentum["w"]), 0.0)
        np.testing.assert_allclose(np.asarray(sv.state.aux["w"]),
                                   0.1 * (i + 1), rtol=1e-6)
    sv.on_arrival(jax.tree.map(jnp.copy, delta), s_i=sv.t, worker_id=0)
    # boundary: m = mu*0 + (1-mu) * (n * 0.1)/n ; buffer reset
    np.testing.assert_allclose(np.asarray(sv.state.momentum["w"]),
                               (1 - 0.9) * 0.1, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sv.state.aux["w"]), 0.0,
                               atol=1e-7)


def test_delayed_nesterov_trajectory_packed_matches_per_leaf():
    params = {"a": jax.random.normal(jax.random.PRNGKey(0), (40, 30)),
              "b": jax.random.normal(jax.random.PRNGKey(1), (129,))}
    cfg = OuterOptConfig(method="delayed_nesterov", drop_stale_after=2)
    svA = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3, packed=True)
    svB = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3, packed=False)
    for i in range(9):
        delta = jax.tree.map(
            lambda x: 0.01 * jax.random.normal(jax.random.PRNGKey(i),
                                               x.shape), params)
        ra = svA.on_arrival(jax.tree.map(jnp.copy, delta),
                            s_i=max(0, svA.t - 3), worker_id=0)
        rb = svB.on_arrival(jax.tree.map(jnp.copy, delta),
                            s_i=max(0, svB.t - 3), worker_id=0)
        assert ra.dropped == rb.dropped
    assert any(r.dropped for r in svA.records)      # decay path exercised
    _tree_close(svA.state.params, svB.state.params, rtol=3e-5, atol=3e-5)
    _tree_close(svA.state.momentum, svB.state.momentum,
                rtol=3e-5, atol=3e-5)
    _tree_close(svA.state.aux, svB.state.aux, rtol=3e-5, atol=3e-5)


def test_delayed_nesterov_state_roundtrip_carries_buffer():
    """Checkpoint semantics: the accumulator buffer survives the state
    property/setter round-trip bit-exactly."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (33,))}
    sv = Synchronizer(params, OuterOptConfig(method="delayed_nesterov"), 2)
    sv.on_arrival({"w": 0.1 * jnp.ones((33,))}, s_i=0, worker_id=0)
    snap = sv.state
    assert snap.aux is not None
    sv2 = Synchronizer(params, OuterOptConfig(method="delayed_nesterov"), 2)
    sv2.state = snap
    assert sv2.t == sv.t == 1
    np.testing.assert_array_equal(np.asarray(sv2.state.aux["w"]),
                                  np.asarray(snap.aux["w"]))


def test_fedbuff_applies_buffer_average_every_k_arrivals():
    """FedBuff semantics: nothing moves between boundaries (params AND
    momentum frozen, buffer accumulating); every K-th arrival applies
    the buffer average through one Nesterov step and resets the buffer."""
    m = M.get("fedbuff")
    k = m.buffer_period
    params = {"w": jnp.ones((6, 4))}
    sv = Synchronizer(params, OuterOptConfig(method="fedbuff",
                                             weight_factor="one"), 1)
    delta = {"w": 0.1 * jnp.ones((6, 4))}
    for i in range(k - 1):
        sv.on_arrival(jax.tree.map(jnp.copy, delta), s_i=sv.t, worker_id=0)
        np.testing.assert_allclose(np.asarray(sv.state.params["w"]), 1.0,
                                   rtol=1e-6)          # params frozen
        np.testing.assert_allclose(np.asarray(sv.state.momentum["w"]), 0.0)
        np.testing.assert_allclose(np.asarray(sv.state.aux["w"]),
                                   0.1 * (i + 1), rtol=1e-6)
    sv.on_arrival(jax.tree.map(jnp.copy, delta), s_i=sv.t, worker_id=0)
    # boundary: gbar = K*0.1/K = 0.1; m' = (1-mu)*gbar; p' = p - eta*(gbar
    # + mu*m'); buffer reset
    mu, eta = 0.9, m.outer_lr
    m_new = (1 - mu) * 0.1
    np.testing.assert_allclose(np.asarray(sv.state.momentum["w"]), m_new,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sv.state.params["w"]),
                               1.0 - eta * (0.1 + mu * m_new), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sv.state.aux["w"]), 0.0,
                               atol=1e-7)


def test_fedbuff_trajectory_packed_matches_per_leaf():
    params = {"a": jax.random.normal(jax.random.PRNGKey(3), (32, 20)),
              "b": jax.random.normal(jax.random.PRNGKey(4), (77,))}
    cfg = OuterOptConfig(method="fedbuff")
    svA = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3, packed=True)
    svB = Synchronizer(jax.tree.map(jnp.copy, params), cfg, 3, packed=False)
    for i in range(9):
        delta = jax.tree.map(
            lambda x: 0.02 * jax.random.normal(jax.random.PRNGKey(40 + i),
                                               x.shape), params)
        svA.on_arrival(jax.tree.map(jnp.copy, delta),
                       s_i=max(0, svA.t - 2), worker_id=0)
        svB.on_arrival(jax.tree.map(jnp.copy, delta),
                       s_i=max(0, svB.t - 2), worker_id=0)
    _tree_close(svA.state.params, svB.state.params, rtol=3e-5, atol=3e-5)
    _tree_close(svA.state.momentum, svB.state.momentum,
                rtol=3e-5, atol=3e-5)
    _tree_close(svA.state.aux, svB.state.aux, rtol=3e-5, atol=3e-5)


def test_poly_stale_damps_polynomially_with_staleness():
    m = M.get("poly_stale")
    delta = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    mom = {"w": jnp.asarray([0.3, 0.3, 0.3])}

    def norm_at(tau):
        ctx = M.ArrivalCtx(outer_lr=0.07, mu=0.9, h=H,
                           tau=jnp.asarray(tau, jnp.float32))
        g = m.correct(m, ctx, delta, mom)
        return float(jnp.linalg.norm(g["w"]))

    base = float(jnp.linalg.norm(delta["w"]))
    np.testing.assert_allclose(norm_at(0.0), base, rtol=1e-6)   # tau=0: id
    for tau in (1.0, 3.0, 8.0):
        np.testing.assert_allclose(norm_at(tau),
                                   base * (1.0 + tau) ** -m.stale_alpha,
                                   rtol=1e-5)
    assert norm_at(8.0) < norm_at(1.0) < base


def test_dcasgd_reduces_to_nesterov_at_zero_staleness():
    params, stacked = _rand_tree(5)
    delta = _rand_like(params, 6)
    mom = jax.tree.map(lambda x: 0.2 * x, delta)
    state = init_outer_state(params)._replace(momentum=mom)
    a = apply_arrival(state, delta, method="dcasgd", outer_lr=0.7, mu=0.9,
                      h=H, tau=0.0, stacked_axes=stacked)
    b = apply_arrival(state, delta, method="nesterov", outer_lr=0.7, mu=0.9,
                      h=H, tau=0.0, stacked_axes=stacked)
    _tree_close(a.params, b.params, rtol=1e-6, atol=1e-6)


def test_dcasgd_compensation_scales_with_staleness():
    """The Taylor term actually bites: larger tau moves the corrected
    gradient further from the raw delta, saturating at tau_clip."""
    m = M.get("dcasgd")
    delta = {"w": jnp.asarray([0.5, -0.5, 1.0])}
    mom = {"w": jnp.asarray([1.0, 1.0, -1.0])}

    def gap(tau):
        ctx = M.ArrivalCtx(outer_lr=0.7, mu=0.9, h=H, tau=jnp.asarray(tau))
        g = m.correct(m, ctx, delta, mom)
        return float(jnp.linalg.norm(g["w"] - delta["w"]))

    assert gap(0.0) == 0.0
    assert gap(2.0) < gap(8.0)
    np.testing.assert_allclose(gap(m.tau_clip), gap(m.tau_clip * 5),
                               rtol=1e-6)
