"""Flash (custom_vjp) attention vs. naive reference: forward and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.attention import attend, flash_attention


def make_qkv(key, b=2, sq=32, skv=32, h=8, kv=2, d=16):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, skv, kv, d))
    v = jax.random.normal(ks[2], (b, skv, kv, d))
    return q, k, v


CFG = reduced(get_config("qwen2-7b"))


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("q_chunk", [8, 16, 32])
def test_flash_forward_matches_naive(causal, q_chunk):
    q, k, v = make_qkv(jax.random.PRNGKey(0))
    ref = attend(q, k, v, causal=causal, cfg=CFG, use_flash=False,
                 q_chunk=1 << 30)
    got = attend(q, k, v, causal=causal, cfg=CFG, use_flash=True,
                 q_chunk=q_chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("q_chunk", [8, 32])
def test_flash_grads_match_naive(causal, q_chunk):
    q, k, v = make_qkv(jax.random.PRNGKey(1))

    def loss_flash(q, k, v):
        o = attend(q, k, v, causal=causal, cfg=CFG, use_flash=True,
                   q_chunk=q_chunk)
        return jnp.sum(jnp.sin(o))

    def loss_naive(q, k, v):
        o = attend(q, k, v, causal=causal, cfg=CFG, use_flash=False,
                   q_chunk=1 << 30)
        return jnp.sum(jnp.sin(o))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-5)


def test_flash_unroll_matches_scan():
    q, k, v = make_qkv(jax.random.PRNGKey(2), sq=64)
    a = flash_attention(q, k, v, causal=True, q_chunk=16, unroll=False)
    b = flash_attention(q, k, v, causal=True, q_chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


def test_flash_kv_valid_mask():
    q, k, v = make_qkv(jax.random.PRNGKey(3), sq=1, skv=32)
    # only the first 10 kv entries are valid
    got = flash_attention(q, k, v, causal=False,
                          kv_valid=jnp.asarray(10), q_chunk=1)
    ref = attend(q, k[:, :10], v[:, :10], causal=False, cfg=CFG,
                 use_flash=False, q_chunk=1 << 30)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
