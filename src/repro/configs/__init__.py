"""Architecture registry: ``get_config(name)`` / ``--arch <id>``."""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

from repro.configs.base import (
    HeLoCoConfig,
    InnerOptConfig,
    ModelConfig,
    MoEConfig,
    OuterOptConfig,
    RunConfig,
    ShapeConfig,
    SHAPES,
    SSMConfig,
    XLSTMConfig,
    reduced,
    shape_applicable,
)

from repro.configs.zamba2_2p7b import CONFIG as _zamba2
from repro.configs.qwen2_7b import CONFIG as _qwen2
from repro.configs.granite_3_8b import CONFIG as _granite3
from repro.configs.command_r_35b import CONFIG as _commandr
from repro.configs.starcoder2_15b import CONFIG as _starcoder2
from repro.configs.granite_moe_1b_a400m import CONFIG as _granitemoe
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.tinygpt_15m import CONFIG as _tinygpt

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _zamba2, _qwen2, _granite3, _commandr, _starcoder2,
        _granitemoe, _llama4, _hubert, _xlstm, _paligemma, _tinygpt,
    )
}

ASSIGNED = tuple(n for n in ARCHS if n != "tinygpt-15m")


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return reduced(get_config(name[: -len("-smoke")]))
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def cells() -> Iterator[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    """All 40 assigned (arch x shape) cells with applicability."""
    for arch in ASSIGNED:
        m = ARCHS[arch]
        for shape in SHAPES.values():
            ok, why = shape_applicable(m, shape)
            yield m, shape, ok, why


__all__ = [
    "ARCHS", "ASSIGNED", "SHAPES", "get_config", "cells", "reduced",
    "ModelConfig", "ShapeConfig", "RunConfig", "MoEConfig", "SSMConfig",
    "XLSTMConfig", "HeLoCoConfig", "OuterOptConfig", "InnerOptConfig",
    "shape_applicable",
]
