"""paligemma-3b [vlm] — SigLIP (stub) + gemma decoder, MQA
[arXiv:2407.07726; hf]."""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    rope_theta=10_000.0,
    norm="rmsnorm",
    mlp_act="geglu",
    tied_embeddings=True,
    embed_scale=True,
    frontend=FrontendConfig(kind="vision", n_prefix_tokens=256),
)
