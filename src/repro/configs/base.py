"""Config system: architecture + input-shape + run configs.

Every assigned architecture is a frozen ``ModelConfig``; the four assigned
input shapes are ``ShapeConfig``s. ``reduced()`` derives the smoke-test
variant of any architecture (same family / block pattern, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm")
# Block kinds a layer stack may contain.
BLOCK_KINDS = ("attn_mlp", "moe", "mamba2", "mlstm", "slstm")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    shared_expert: bool = False      # llama4-style always-on shared expert
    capacity_factor: float = 1.25
    group_size: int = 2048           # tokens per dispatch group (memory knob)
    dispatch: str = "scatter"        # "scatter" O(T*d) | "einsum" O(T*E*C*d)
    group_mode: str = "scan"         # "scan" (bounded memory, single-host)
    # | "vmap" (all groups vectorized — REQUIRED at scale: scanning over a
    # data-sharded group axis makes GSPMD emit per-group collectives)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyperparameters."""
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_kernel: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block hyperparameters (mLSTM + sLSTM)."""
    slstm_at: Tuple[int, ...] = ()   # layer indices that are sLSTM blocks
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    conv_kernel: int = 4
    chunk_size: int = 64             # mLSTM chunkwise-parallel chunk length


@dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend STUB: input_specs() provides precomputed embeddings."""
    kind: str = "none"               # "none" | "audio" | "vision"
    n_prefix_tokens: int = 0         # vision: patch tokens prepended
    # audio: the whole sequence is frame embeddings (no token embedding table
    # lookup for inputs; output head still projects to `vocab_size` units).


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # --- attention details ---
    qkv_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    mlp_act: str = "swiglu"          # "swiglu" | "gelu" | "geglu"
    parallel_block: bool = False     # command-r style parallel attn+FFN
    tied_embeddings: bool = False
    causal: bool = True              # encoder-only -> False
    embed_scale: bool = False        # gemma-style sqrt(d_model) input scaling
    # --- block pattern ---
    block_kind: str = "attn_mlp"     # homogeneous kind unless hybrid/ssm
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    xlstm: XLSTMConfig = field(default_factory=XLSTMConfig)
    # zamba2: shared attention block applied every `shared_attn_every` mamba
    # layers (one weight set reused at each application site).
    shared_attn_every: int = 0
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # --- capability flags ---
    encoder_only: bool = False       # no decode step
    subquadratic: bool = False       # can run long_500k
    # --- numerics / training ---
    param_dtype: str = "float32"     # master weights
    compute_dtype: str = "bfloat16"
    remat: bool = True               # checkpoint each layer in train fwd
    remat_group: int = 1             # layers per remat block (k-th-layer ckpt)
    scan_layers: bool = True         # lax.scan over stacked layer params
    # activation sharding hints; empty = no constraints (single-host path).
    act_batch_axes: Tuple[str, ...] = ()   # batch dim of activations
    act_model_axis: str = ""               # TP axis for attention heads
    seq_parallel: bool = False             # Megatron-SP: residual stream's
    # seq dim sharded over the TP axis between blocks (rs/ag pairs instead
    # of all-reduces; norms compute on 1/TP of the tokens)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.family in FAMILIES, self.family
        assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)

    @property
    def is_moe(self) -> bool:
        return self.moe.n_experts > 0


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-skipped) for an (arch, shape) cell."""
    if shape.kind == "decode" and model.encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not model.subquadratic:
        return False, "long_500k requires sub-quadratic attention (full-attention arch)"
    return True, ""


# ---------------------------------------------------------------------------
# Run config (training hyperparameters, HeLoCo knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HeLoCoConfig:
    """Paper Table 3 defaults (Appendix A.5)."""
    c_ok: float = 0.2
    k_s: float = 0.5
    k_d: float = 1.0
    kappa: float = 3.0
    beta_max: float = 0.5
    eps: float = 1e-8


@dataclass(frozen=True)
class OuterOptConfig:
    method: str = "heloco"           # any registered repro.core.methods
    # name or alias (heloco | mla | nesterov | sync_nesterov |
    # delayed_nesterov | dcasgd | ...)
    outer_lr: float = 0.7            # paper: 0.7 (0.07 for async nesterov)
    momentum: float = 0.9
    weight_factor: str = "base"      # "base" sqrt(k)/k | "average" 1/k | "one"
    lookahead_init: bool = True      # HeLoCo Eq. 5 (also used by MLA)
    heloco: HeLoCoConfig = field(default_factory=HeLoCoConfig)
    # staleness management (appendix A.6 + beyond-paper):
    drop_stale_after: Optional[int] = None   # discard if tau > this
    delay_weighting: bool = False            # rho_t = 1/sqrt(1+tau)
    # pseudo-gradient compression (beyond-paper, DiLoCoX-style):
    compression: str = "none"        # none | int8 | topk
    topk_ratio: float = 0.1
    error_feedback: bool = True


@dataclass(frozen=True)
class InnerOptConfig:
    optimizer: str = "adamw"
    lr: float = 4e-4
    warmup_steps: int = 50
    total_steps: int = 24_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"         # matches Liu et al. 2024


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    inner: InnerOptConfig = field(default_factory=InnerOptConfig)
    outer: OuterOptConfig = field(default_factory=OuterOptConfig)
    n_workers: int = 5
    inner_steps: int = 20            # H
    outer_steps: int = 100           # T
    batch_size: int = 8              # per-worker inner batch
    seq_len: int = 64
    seed: int = 0
    # heterogeneity:
    worker_paces: Tuple[float, ...] = (1.0, 1.0, 1.0, 1.0, 1.0)  # sec/step
    non_iid: bool = True
    # Dirichlet language mixtures: when set (and non_iid), each worker
    # samples its batches from a per-worker mixture over languages drawn
    # once from Dirichlet(alpha) — alpha -> 0 recovers one-shard-per-worker
    # severity, alpha -> inf the IID mixture (the paper's non-IID axis).
    mixture_alpha: Optional[float] = None
    shard_assignment: str = "fixed"  # "fixed" | "flexible" (App. A.6)
    dylu: bool = False               # Dynamic Local Updates
    # exchange topology: "hub" (Synchronizer) or a decentralized
    # NoLoCo-style "ring" / "gossip" (repro.async_engine.topology)
    topology: str = "hub"
    # batched-arrival fast path (docs/scale.md): coalesce up to this many
    # same-tick arrivals into one fused multi-apply commit. 1 = the exact
    # sequential path (default; every pre-existing golden).
    commit_batch: int = 1
    # hogwild-style ramp-up (arXiv 2010.14763): per-round mini-batch grows
    # linearly from batch_size to this value across outer steps (None =
    # constant batch_size).
    batch_rampup: Optional[int] = None
    # fault tolerance:
    ckpt_every: int = 0              # outer steps between checkpoints (0=off)
    ckpt_dir: str = ""
    # distribution (dry-run/scale path):
    grad_accum: int = 1


def reduced(model: ModelConfig, *, seq_friendly: bool = False) -> ModelConfig:
    """Smoke-test variant: same family/block pattern, tiny dims."""
    n_layers = min(model.n_layers, 4)
    sa = model.shared_attn_every
    if sa:
        sa = 2
        n_layers = 4
    slstm_at = tuple(i for i in model.xlstm.slstm_at if i < n_layers)
    if model.xlstm.slstm_at and not slstm_at:
        slstm_at = (1,)
    kv = min(model.n_kv_heads, 2)
    heads = max(4, kv)
    moe = model.moe
    if model.is_moe:
        moe = replace(moe, n_experts=4, top_k=min(model.moe.top_k, 2),
                      expert_d_ff=64, group_size=64)
    fe = model.frontend
    if fe.kind == "vision":
        fe = replace(fe, n_prefix_tokens=4)
    return replace(
        model,
        name=model.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=128 if model.d_ff else 0,
        vocab_size=128,
        moe=moe,
        ssm=replace(model.ssm, d_state=8, head_dim=8, chunk_size=16),
        xlstm=replace(model.xlstm, slstm_at=slstm_at, chunk_size=8),
        shared_attn_every=sa,
        frontend=fe,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
