"""tinygpt-15m — the paper's own evaluation model (TinyGPT, GPT-2 tokenizer,
~15M params). Used by the paper-reproduction benchmarks."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinygpt-15m",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1024,
    vocab_size=50257,
    head_dim=32,
    norm="layernorm",
    mlp_act="gelu",
    tied_embeddings=True,
    remat=False,
    scan_layers=False,
)
