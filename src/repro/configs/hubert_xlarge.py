"""hubert-xlarge [audio] — encoder-only (w2v2-style backbone); conv feature
frontend is a STUB (input_specs provides precomputed frame embeddings)
[arXiv:2106.07447; unverified]."""
from repro.configs.base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,                 # masked-unit prediction targets
    head_dim=80,
    norm="layernorm",
    mlp_act="gelu",
    causal=False,
    encoder_only=True,
    frontend=FrontendConfig(kind="audio"),
)
