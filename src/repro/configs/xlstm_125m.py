"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]-style placement)
[arXiv:2405.04517; unverified]."""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                          # xLSTM blocks integrate their projections
    vocab_size=50304,
    head_dim=192,
    norm="layernorm",
    block_kind="mlstm",
    xlstm=XLSTMConfig(slstm_at=(3, 9)),
    subquadratic=True,
    scan_layers=False,               # 12 mixed blocks: unrolled
    tied_embeddings=True,
)
