"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,                      # shared-attention block heads (MHA)
    n_kv_heads=32,
    d_ff=10240,                      # shared-block MLP
    vocab_size=32000,
    head_dim=80,
    norm="rmsnorm",
    mlp_act="gelu",
    block_kind="mamba2",
    shared_attn_every=6,             # one shared attn+MLP block every 6 mamba layers
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, n_groups=1,
                  conv_kernel=4, chunk_size=256),
    subquadratic=True,
    tied_embeddings=True,
)
