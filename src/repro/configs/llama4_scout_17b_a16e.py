"""llama4-scout-17b-a16e [moe] — MoE 16e top-1 + shared expert, early fusion
(multimodal frontend stubbed; text backbone per assignment)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    rope_theta=500_000.0,
    norm="rmsnorm",
    mlp_act="swiglu",
    block_kind="moe",
    moe=MoEConfig(n_experts=16, top_k=1, expert_d_ff=8192, shared_expert=True),
)
