"""Shared building blocks: norms, RoPE, MLPs, embeddings.

All functions are pure; params are plain dict pytrees. Compute runs in
``cfg.compute_dtype``; params are stored in ``cfg.param_dtype``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


def constrain_acts(x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Pin activations at block boundaries (GSPMD hint). No-op unless
    cfg.act_batch_axes is set (the scale/dry-run path). With
    cfg.seq_parallel the seq dim is additionally sharded over the TP axis
    (Megatron-SP): XLA then materialises reduce-scatter/all-gather pairs
    around each block instead of full all-reduces."""
    if not cfg.act_batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    seq_axis = (cfg.act_model_axis or "model") if (
        cfg.seq_parallel and x.ndim >= 3) else None
    spec = P(tuple(cfg.act_batch_axes), seq_axis,
             *((None,) * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int) -> Params:
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf ** 2).mean(-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rmsnorm_gated(x: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    """Mamba2-style gated RMSNorm: norm(x * silu(z)) * scale."""
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    var = (xf ** 2).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S) int32. Half-split rotation."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                            # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d: int, ff: int) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    if cfg.mlp_act in ("swiglu", "geglu"):
        p = {
            "w_gate": _normal(ks[0], (d, ff), s_in, pd),
            "w_up": _normal(ks[1], (d, ff), s_in, pd),
            "w_down": _normal(ks[2], (ff, d), s_out, pd),
        }
    else:  # gelu
        p = {
            "w_in": _normal(ks[0], (d, ff), s_in, pd),
            "w_down": _normal(ks[2], (ff, d), s_out, pd),
        }
    if cfg.mlp_bias:
        if cfg.mlp_act in ("swiglu", "geglu"):
            p["b_gate"] = jnp.zeros((ff,), pd)
            p["b_up"] = jnp.zeros((ff,), pd)
        else:
            p["b_in"] = jnp.zeros((ff,), pd)
        p["b_down"] = jnp.zeros((d,), pd)
    return p


def apply_mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    dt = x.dtype
    if cfg.mlp_act in ("swiglu", "geglu"):
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        if cfg.mlp_bias:
            g = g + p["b_gate"].astype(dt)
            u = u + p["b_up"].astype(dt)
        act = jax.nn.silu(g) if cfg.mlp_act == "swiglu" else jax.nn.gelu(g)
        h = act * u
    else:
        h = x @ p["w_in"].astype(dt)
        if cfg.mlp_bias:
            h = h + p["b_in"].astype(dt)
        h = jax.nn.gelu(h)
    out = h @ p["w_down"].astype(dt)
    if cfg.mlp_bias:
        out = out + p["b_down"].astype(dt)
    return out


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    p = {"tok": _normal(ks[0], (cfg.vocab_size, cfg.d_model), 0.02, pd)}
    if not cfg.tied_embeddings:
        p["lm_head"] = _normal(ks[1], (cfg.d_model, cfg.vocab_size),
                               cfg.d_model ** -0.5, pd)
    return p


def embed_tokens(p: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = p["tok"].astype(dtype_of(cfg))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def lm_logits(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.tied_embeddings:
        w = p["tok"].astype(x.dtype).T
    else:
        w = p["lm_head"].astype(x.dtype)
    return x @ w


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy, computed in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
