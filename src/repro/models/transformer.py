"""Model assembly: composable block stacks for every assigned architecture.

One functional `Model` facade per ModelConfig with:
  - ``init(key)``            -> params pytree
  - ``loss(params, batch)``  -> (scalar loss, aux dict)   [train forward]
  - ``prefill(params, batch)`` -> (last-token logits, caches)
  - ``decode(params, token, caches, pos)`` -> (logits, caches)

Families:
  dense / moe / audio / vlm : homogeneous attention(+MLP|MoE) stack,
                              `lax.scan` over stacked layer params.
  hybrid (zamba2)           : scan over super-blocks of `shared_attn_every`
                              Mamba2 layers + one shared attention block.
  ssm (xlstm)               : unrolled mixed mLSTM/sLSTM stack.

``unroll=True`` replaces every lax.scan/map with Python loops — used only
by the dry-run cost probes so HLO FLOPs count each iteration.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba2 as m2
from repro.models import moe as moe_lib
from repro.models import xlstm as xl
from repro.models.layers import (
    apply_mlp, apply_norm, constrain_acts, cross_entropy, dtype_of,
    embed_tokens, init_embed, init_mlp, init_norm, lm_logits,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Attention(+MLP/MoE) block
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "norm1": init_norm(cfg, cfg.d_model),
        "attn": attn_lib.init_attention(ks[0], cfg),
    }
    if not cfg.parallel_block:
        p["norm2"] = init_norm(cfg, cfg.d_model)
    if cfg.block_kind == "moe":
        p["moe"] = moe_lib.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[2], cfg, cfg.d_model, cfg.d_ff)
    return p


def apply_attn_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                     positions: jnp.ndarray, unroll: bool = False,
                     q_chunk: int = 128) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training/prefill forward without cache. Returns (x', aux)."""
    h = apply_norm(p["norm1"], x, cfg)
    q, k, v = attn_lib.qkv_project(p["attn"], h, cfg, positions)
    ctx = attn_lib.attend(q, k, v, causal=cfg.causal, cfg=cfg,
                          q_chunk=q_chunk, unroll=unroll)
    a = attn_lib.attn_output(p["attn"], ctx)
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        if cfg.block_kind == "moe":
            mo, aux = moe_lib.apply_moe(p["moe"], h, cfg, unroll=unroll)
        else:
            mo = apply_mlp(p["mlp"], h, cfg)
        return x + a + mo, aux
    x = x + a
    h2 = apply_norm(p["norm2"], x, cfg)
    if cfg.block_kind == "moe":
        mo, aux = moe_lib.apply_moe(p["moe"], h2, cfg, unroll=unroll)
    else:
        mo = apply_mlp(p["mlp"], h2, cfg)
    return x + mo, aux


def prefill_attn_block(p, x, cfg, *, positions, cache_len: int,
                       unroll: bool = False, q_chunk: int = 128):
    """Forward that also builds the KV cache (padded to cache_len)."""
    h = apply_norm(p["norm1"], x, cfg)
    q, k, v = attn_lib.qkv_project(p["attn"], h, cfg, positions)
    ctx = attn_lib.attend(q, k, v, causal=cfg.causal, cfg=cfg,
                          q_chunk=q_chunk, unroll=unroll)
    a = attn_lib.attn_output(p["attn"], ctx)
    cache = attn_lib.init_kv_cache(cfg, x.shape[0], cache_len, dtype=x.dtype)
    cache = attn_lib.cache_write(cache, k, v, 0)
    if cfg.parallel_block:
        mo = (moe_lib.apply_moe(p["moe"], h, cfg, unroll=unroll)[0]
              if cfg.block_kind == "moe" else apply_mlp(p["mlp"], h, cfg))
        return x + a + mo, cache
    x = x + a
    h2 = apply_norm(p["norm2"], x, cfg)
    mo = (moe_lib.apply_moe(p["moe"], h2, cfg, unroll=unroll)[0]
          if cfg.block_kind == "moe" else apply_mlp(p["mlp"], h2, cfg))
    return x + mo, cache


def decode_attn_block(p, x, cfg, *, cache, pos):
    h = apply_norm(p["norm1"], x, cfg)
    a, cache = attn_lib.decode_attend(p["attn"], h, cache, pos, cfg)
    if cfg.parallel_block:
        mo = (moe_lib.apply_moe(p["moe"], h, cfg)[0]
              if cfg.block_kind == "moe" else apply_mlp(p["mlp"], h, cfg))
        return x + a + mo, cache
    x = x + a
    h2 = apply_norm(p["norm2"], x, cfg)
    mo = (moe_lib.apply_moe(p["moe"], h2, cfg)[0]
          if cfg.block_kind == "moe" else apply_mlp(p["mlp"], h2, cfg))
    return x + mo, cache


# ---------------------------------------------------------------------------
# Model facade
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- init ----------------

    def init(self, key) -> Params:
        cfg = self.cfg
        k_embed, k_blocks, k_shared = jax.random.split(key, 3)
        params: Params = {"embed": init_embed(k_embed, cfg),
                          "final_norm": init_norm(cfg, cfg.d_model)}
        if cfg.family in ("dense", "moe", "audio", "vlm"):
            keys = jax.random.split(k_blocks, cfg.n_layers)
            if cfg.scan_layers:
                params["blocks"] = jax.vmap(
                    lambda k: init_attn_block(k, cfg))(keys)
            else:
                params["blocks_list"] = {
                    f"layer_{i:02d}": init_attn_block(keys[i], cfg)
                    for i in range(cfg.n_layers)}
        elif cfg.family == "hybrid":
            per = cfg.shared_attn_every
            n_super = cfg.n_layers // per
            keys = jax.random.split(k_blocks, cfg.n_layers).reshape(n_super, per, 2)
            def init_unit(k):
                return {"norm": init_norm(cfg, cfg.d_model),
                        "mamba": m2.init_mamba2(k, cfg)}
            params["super"] = jax.vmap(jax.vmap(init_unit))(keys)
            params["shared"] = init_attn_block(k_shared, cfg)
        elif cfg.family == "ssm":
            keys = jax.random.split(k_blocks, cfg.n_layers)
            blocks = {}
            for i in range(cfg.n_layers):
                kind = "slstm" if i in cfg.xlstm.slstm_at else "mlstm"
                init = xl.init_slstm if kind == "slstm" else xl.init_mlstm
                blocks[f"layer_{i:02d}"] = {
                    "norm": init_norm(cfg, cfg.d_model),
                    kind: init(keys[i], cfg)}
            params["blocks_list"] = blocks
        else:
            raise ValueError(cfg.family)
        return params

    # ---------------- embedding front ----------------

    def _embed(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x (B,S,d), positions (B,S))."""
        cfg = self.cfg
        if cfg.frontend.kind == "audio":
            x = batch["features"].astype(dtype_of(cfg))
        elif cfg.frontend.kind == "vision":
            prefix = batch["patches"].astype(dtype_of(cfg))
            tok = embed_tokens(params["embed"], batch["tokens"], cfg)
            x = jnp.concatenate([prefix, tok], axis=1)
        else:
            x = embed_tokens(params["embed"], batch["tokens"], cfg)
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return constrain_acts(x, cfg), positions

    # ---------------- train forward ----------------

    def loss(self, params: Params, batch: Dict[str, jnp.ndarray], *,
             unroll: bool = False, q_chunk: int = 128
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        aux_sum = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "moe", "audio", "vlm"):
            block = functools.partial(apply_attn_block, cfg=cfg,
                                      positions=positions, unroll=unroll,
                                      q_chunk=q_chunk)
            k = max(cfg.remat_group, 1)

            def group(gp, xc, ac):
                """k consecutive layers; remat checkpoints the whole group
                (store 1 input per k layers -> activation memory / k)."""
                for j in range(k):
                    lp = jax.tree.map(lambda t: t[j], gp) if k > 1 else gp
                    xn, a = block(lp, xc)
                    xc = constrain_acts(xn, cfg)
                    ac = ac + a
                return xc, ac

            grp = jax.checkpoint(group) if cfg.remat else group
            if cfg.scan_layers and not unroll:
                stacked = params["blocks"]
                if k > 1:
                    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
                    stacked = jax.tree.map(
                        lambda t: t.reshape((cfg.n_layers // k, k)
                                            + t.shape[1:]), stacked)
                def body(carry, gp):
                    xc, ac = carry
                    xn, an = grp(gp, xc, ac)
                    return (xn, an), None
                (x, aux_sum), _ = jax.lax.scan(body, (x, aux_sum), stacked)
            else:
                blocks = (params["blocks_list"] if "blocks_list" in params
                          else None)
                assert cfg.n_layers % k == 0 or blocks is not None
                if blocks is not None:
                    for i in range(cfg.n_layers):
                        lp = blocks[f"layer_{i:02d}"]
                        xb = (jax.checkpoint(block) if cfg.remat else block)
                        x, a = xb(lp, x)
                        x = constrain_acts(x, cfg)
                        aux_sum = aux_sum + a
                else:
                    for i in range(cfg.n_layers // k):
                        gp = jax.tree.map(
                            lambda t: t[i * k:(i + 1) * k] if k > 1
                            else t[i], params["blocks"])
                        x, aux_sum = grp(gp, x, aux_sum)
        elif cfg.family == "hybrid":
            x = self._hybrid_forward(params, x, positions, unroll, q_chunk)
        elif cfg.family == "ssm":
            x = self._xlstm_forward(params, x, unroll)

        x = apply_norm(params["final_norm"], x, cfg)
        if cfg.frontend.kind == "vision":
            x = x[:, cfg.frontend.n_prefix_tokens:]
        logits = lm_logits(params["embed"], x, cfg)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = (labels >= 0)
        loss = cross_entropy(logits, jnp.maximum(labels, 0), mask)
        aux = {"aux_loss": aux_sum / max(cfg.n_layers, 1)}
        if cfg.is_moe:
            loss = loss + 0.01 * aux["aux_loss"]
        return loss, aux

    def _hybrid_forward(self, params, x, positions, unroll, q_chunk):
        cfg = self.cfg
        per = cfg.shared_attn_every
        n_super = cfg.n_layers // per

        def mamba_unit(up, xc):
            h = apply_norm(up["norm"], xc, cfg)
            y, _ = m2.apply_mamba2(up["mamba"], h, cfg, unroll=unroll)
            return xc + y

        def super_block(sp, xc):
            if unroll:
                for j in range(per):
                    up = jax.tree.map(lambda t: t[j], sp)
                    xc = constrain_acts(mamba_unit(up, xc), cfg)
            else:
                xc, _ = jax.lax.scan(
                    lambda c, up: (constrain_acts(mamba_unit(up, c), cfg),
                                   None), xc, sp)
            xc, _ = apply_attn_block(params["shared"], xc, cfg,
                                     positions=positions, unroll=unroll,
                                     q_chunk=q_chunk)
            return constrain_acts(xc, cfg)

        sb = jax.checkpoint(super_block) if cfg.remat else super_block
        if unroll:
            for i in range(n_super):
                sp = jax.tree.map(lambda t: t[i], params["super"])
                x = sb(sp, x)
        else:
            x, _ = jax.lax.scan(lambda c, sp: (sb(sp, c), None),
                                x, params["super"])
        return x

    def _xlstm_forward(self, params, x, unroll):
        cfg = self.cfg
        for i in range(cfg.n_layers):
            lp = params["blocks_list"][f"layer_{i:02d}"]
            kind = "slstm" if i in cfg.xlstm.slstm_at else "mlstm"

            def blk(lp_, x_):
                h = apply_norm(lp_["norm"], x_, cfg)
                if kind == "slstm":
                    y, _ = xl.apply_slstm_block(lp_["slstm"], h, cfg,
                                                unroll=unroll)
                else:
                    y, _ = xl.apply_mlstm_block(lp_["mlstm"], h, cfg,
                                                unroll=unroll)
                return x_ + y

            if cfg.remat:
                blk = jax.checkpoint(blk)
            x = constrain_acts(blk(lp, x), cfg)
        return x

    # ---------------- serving: prefill ----------------

    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray], *,
                cache_len: Optional[int] = None, unroll: bool = False,
                q_chunk: int = 128):
        """Returns (last-position logits, caches)."""
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        s = x.shape[1]
        cache_len = cache_len or s

        if cfg.family in ("dense", "moe", "audio", "vlm"):
            block = functools.partial(prefill_attn_block, cfg=cfg,
                                      positions=positions, cache_len=cache_len,
                                      unroll=unroll, q_chunk=q_chunk)
            if cfg.scan_layers and not unroll:
                def body(xc, lp):
                    xn, cache = block(lp, xc)
                    return xn, cache
                x, caches = jax.lax.scan(body, x, params["blocks"])
            else:
                caches = {}
                for i in range(cfg.n_layers):
                    lp = (params["blocks_list"][f"layer_{i:02d}"]
                          if "blocks_list" in params
                          else jax.tree.map(lambda t: t[i], params["blocks"]))
                    x, c = block(lp, x)
                    caches[f"layer_{i:02d}"] = c
        elif cfg.family == "hybrid":
            x, caches = self._hybrid_prefill(params, x, positions, cache_len,
                                             unroll, q_chunk)
        elif cfg.family == "ssm":
            x, caches = self._xlstm_prefill(params, x, unroll)

        x = apply_norm(params["final_norm"], x[:, -1:], cfg)
        logits = lm_logits(params["embed"], x, cfg)[:, 0]
        return logits, caches

    def _hybrid_prefill(self, params, x, positions, cache_len, unroll, q_chunk):
        cfg = self.cfg
        per = cfg.shared_attn_every
        n_super = cfg.n_layers // per

        # Prefill needs the final SSM state of every mamba layer plus the
        # shared block's KV cache.
        def super_block_with_states(sp, xc):
            def body(c, up):
                h = apply_norm(up["norm"], c, cfg)
                # run ssd and capture final state
                y, st = self._mamba_with_state(up["mamba"], h, cfg, unroll)
                return c + y, st
            if unroll:
                sts = []
                for j in range(per):
                    up = jax.tree.map(lambda t: t[j], sp)
                    xc, st = body(xc, up)
                    sts.append(st)
                sts = jax.tree.map(lambda *t: jnp.stack(t), *sts)
            else:
                xc, sts = jax.lax.scan(body, xc, sp)
            xn, cache = prefill_attn_block(params["shared"], xc, cfg,
                                           positions=positions,
                                           cache_len=cache_len,
                                           unroll=unroll, q_chunk=q_chunk)
            return xn, (sts, cache)

        if unroll:
            caches = []
            for i in range(n_super):
                sp = jax.tree.map(lambda t: t[i], params["super"])
                x, c = super_block_with_states(sp, x)
                caches.append(c)
            caches = jax.tree.map(lambda *t: jnp.stack(t), *caches)
        else:
            x, caches = jax.lax.scan(
                lambda c, sp: super_block_with_states(sp, c), x, params["super"])
        return x, caches

    def _mamba_with_state(self, mp, h, cfg, unroll):
        """Mamba2 forward that also returns the post-sequence SSM+conv state."""
        return m2.apply_mamba2_with_final_state(mp, h, cfg, unroll=unroll)

    def _xlstm_prefill(self, params, x, unroll):
        cfg = self.cfg
        caches = {}
        for i in range(cfg.n_layers):
            lp = params["blocks_list"][f"layer_{i:02d}"]
            kind = "slstm" if i in cfg.xlstm.slstm_at else "mlstm"
            h = apply_norm(lp["norm"], x, cfg)
            if kind == "slstm":
                y, st = xl.apply_slstm_block_with_state(lp["slstm"], h, cfg,
                                                        unroll=unroll)
            else:
                y, st = xl.apply_mlstm_block_with_state(lp["mlstm"], h, cfg,
                                                        unroll=unroll)
            x = x + y
            caches[f"layer_{i:02d}"] = st
        return x, caches

    # ---------------- serving: decode ----------------

    def init_caches(self, batch: int, cache_len: int):
        cfg = self.cfg
        cache_dtype = dtype_of(cfg)
        if cfg.family in ("dense", "moe", "audio", "vlm"):
            one = lambda: attn_lib.init_kv_cache(cfg, batch, cache_len,
                                                 dtype=cache_dtype)
            if cfg.scan_layers:
                return jax.tree.map(
                    lambda t: jnp.broadcast_to(t, (cfg.n_layers,) + t.shape),
                    one())
            return {f"layer_{i:02d}": one() for i in range(cfg.n_layers)}
        if cfg.family == "hybrid":
            per = cfg.shared_attn_every
            n_super = cfg.n_layers // per
            st = m2.init_mamba2_state(cfg, batch)
            sts = jax.tree.map(
                lambda t: jnp.broadcast_to(t, (n_super, per) + t.shape), st)
            kv = attn_lib.init_kv_cache(cfg, batch, cache_len,
                                        dtype=cache_dtype)
            kvs = jax.tree.map(
                lambda t: jnp.broadcast_to(t, (n_super,) + t.shape), kv)
            return (sts, kvs)
        if cfg.family == "ssm":
            caches = {}
            for i in range(cfg.n_layers):
                kind = "slstm" if i in cfg.xlstm.slstm_at else "mlstm"
                caches[f"layer_{i:02d}"] = (
                    xl.init_slstm_state(cfg, batch) if kind == "slstm"
                    else xl.init_mlstm_state(cfg, batch))
            return caches
        raise ValueError(cfg.family)

    def decode(self, params: Params, token: jnp.ndarray, caches, pos):
        """One decode step. token: (B,) int32; pos: scalar int32 (same for
        all batch rows; continuous batching handles ragged pos upstream)."""
        cfg = self.cfg
        x = embed_tokens(params["embed"], token[:, None], cfg)

        if cfg.family in ("dense", "moe", "audio", "vlm"):
            if cfg.scan_layers:
                def body(xc, inp):
                    lp, cache = inp
                    xn, c2 = decode_attn_block(lp, xc, cfg, cache=cache, pos=pos)
                    return xn, c2
                x, caches = jax.lax.scan(body, x, (params["blocks"], caches))
            else:
                new = {}
                for i in range(cfg.n_layers):
                    key = f"layer_{i:02d}"
                    x, c2 = decode_attn_block(params["blocks_list"][key], x,
                                              cfg, cache=caches[key], pos=pos)
                    new[key] = c2
                caches = new
        elif cfg.family == "hybrid":
            sts, kvs = caches
            def body(xc, inp):
                sp, st, kv = inp
                def inner(c, inp2):
                    up, stt = inp2
                    h = apply_norm(up["norm"], c, cfg)
                    y, st2 = m2.apply_mamba2(up["mamba"], h, cfg, state=stt)
                    return c + y, st2
                xc, st2 = jax.lax.scan(inner, xc, (sp, st))
                xc, kv2 = decode_attn_block(params["shared"], xc, cfg,
                                            cache=kv, pos=pos)
                return xc, (st2, kv2)
            x, (sts, kvs) = jax.lax.scan(body, x, (params["super"], sts, kvs))
            caches = (sts, kvs)
        elif cfg.family == "ssm":
            new = {}
            for i in range(cfg.n_layers):
                key = f"layer_{i:02d}"
                lp = params["blocks_list"][key]
                kind = "slstm" if i in cfg.xlstm.slstm_at else "mlstm"
                h = apply_norm(lp["norm"], x, cfg)
                if kind == "slstm":
                    y, st = xl.apply_slstm_block(lp["slstm"], h, cfg,
                                                 state=caches[key])
                else:
                    y, st = xl.apply_mlstm_block(lp["mlstm"], h, cfg,
                                                 state=caches[key])
                x = x + y
                new[key] = st
            caches = new

        x = apply_norm(params["final_norm"], x, cfg)
        logits = lm_logits(params["embed"], x, cfg)[:, 0]
        return logits, caches


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
