"""Mixture-of-Experts FFN: top-k token-choice routing with capacity-based
einsum dispatch (GSPMD-friendly; experts shard over the `model` mesh axis).

Tokens are processed in groups of ``cfg.moe.group_size`` (scanned in
production, Python loop under ``unroll=True``) so the one-hot dispatch
tensor (g*k, E, C) stays small. Router runs in fp32; an auxiliary
load-balancing loss (Switch-style) is returned for logging / training.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _normal


def init_moe(key, cfg: ModelConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    e, ff = cfg.moe.n_experts, cfg.moe.expert_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "router": _normal(ks[0], (d, e), 0.02, jnp.float32),
        "w_gate": _normal(ks[1], (e, d, ff), s_in, pd),
        "w_up": _normal(ks[2], (e, d, ff), s_in, pd),
        "w_down": _normal(ks[3], (e, ff, d), s_out, pd),
    }
    if cfg.moe.shared_expert:
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _normal(ks2[0], (d, ff), s_in, pd),
            "w_up": _normal(ks2[1], (d, ff), s_in, pd),
            "w_down": _normal(ks2[2], (ff, d), s_out, pd),
        }
    return p


def expert_capacity(cfg: ModelConfig, group: int) -> int:
    m = cfg.moe
    c = int(math.ceil(m.top_k * group / m.n_experts * m.capacity_factor))
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def _group_moe(p: Params, xg: jnp.ndarray, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """xg: (g, d) -> (out (g, d), aux loss scalar)."""
    m = cfg.moe
    g, d = xg.shape
    e, k = m.n_experts, m.top_k
    cap = expert_capacity(cfg, g)
    dt = xg.dtype

    logits = xg.astype(jnp.float32) @ p["router"]          # (g, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # (g, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss.
    density = jax.nn.one_hot(top_i[:, 0], e).mean(0)
    density_prob = probs.mean(0)
    aux = e * jnp.sum(density * density_prob)

    sel = jax.nn.one_hot(top_i.reshape(-1), e, dtype=jnp.int32)   # (g*k, E)
    pos = jnp.cumsum(sel, axis=0) - sel                            # (g*k, E)
    pos = (pos * sel).sum(-1)                                      # (g*k,)
    within = pos < cap
    expert_of = top_i.reshape(-1)
    gate_of = jnp.where(within, top_p.reshape(-1), 0.0)
    x_rep = jnp.repeat(xg, k, axis=0)                              # (g*k, d)

    if m.dispatch == "einsum":
        # one-hot matmul dispatch: O(T*E*C*d) but purely dense (MXU-shaped)
        oh_e = (jax.nn.one_hot(expert_of, e, dtype=dt)
                * within[:, None].astype(dt))
        oh_c = jax.nn.one_hot(jnp.minimum(pos, cap - 1), cap, dtype=dt)
        dispatch = oh_e[:, :, None] * oh_c[:, None, :]             # (g*k, E, C)
        expert_in = jnp.einsum("tec,td->ecd", dispatch, x_rep)     # (E, C, d)
    else:
        # scatter dispatch: O(T*d). Slots are unique among within-capacity
        # entries, so scatter-add has no collisions.
        slot = expert_of * cap + jnp.minimum(pos, cap - 1)         # (g*k,)
        contrib = jnp.where(within[:, None], x_rep, 0).astype(dt)
        expert_in = (jnp.zeros((e * cap, d), dt).at[slot].add(contrib)
                     .reshape(e, cap, d))

    h_gate = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"].astype(dt))
    h_up = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"].astype(dt))
    h = jax.nn.silu(h_gate) * h_up
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))

    if m.dispatch == "einsum":
        combine = dispatch * gate_of[:, None, None].astype(dt)     # (g*k, E, C)
        out = jnp.einsum("tec,ecd->td", combine, expert_out)       # (g*k, d)
    else:
        gathered = expert_out.reshape(e * cap, d)[slot]            # (g*k, d)
        out = gathered * (gate_of * within).astype(dt)[:, None]
    out = out.reshape(g, k, d).sum(1)

    if m.shared_expert:
        sp = p["shared"]
        sh = jax.nn.silu(xg @ sp["w_gate"].astype(dt)) * (xg @ sp["w_up"].astype(dt))
        out = out + sh @ sp["w_down"].astype(dt)
    return out, aux


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              unroll: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux). Groups tokens and dispatches."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    t = flat.shape[0]
    gsz = min(cfg.moe.group_size, t)
    n_groups = t // gsz
    assert t % gsz == 0, (t, gsz)
    groups = flat.reshape(n_groups, gsz, d)
    if cfg.moe.group_mode == "vmap" and n_groups > 1:
        out, auxs = jax.vmap(lambda xg: _group_moe(p, xg, cfg))(groups)
        aux = auxs.mean()
    elif unroll or n_groups == 1:
        outs, auxs = zip(*[_group_moe(p, groups[i], cfg) for i in range(n_groups)])
        out = jnp.stack(outs)
        aux = jnp.stack(auxs).mean()
    else:
        out, auxs = jax.lax.map(lambda xg: _group_moe(p, xg, cfg), groups)
        aux = auxs.mean()
    return out.reshape(b, s, d), aux
