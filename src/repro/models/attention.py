"""GQA attention with a chunked (flash-style) training path and a KV-cache
serving path.

The training/prefill path never materialises the full (Sq, Skv) score
matrix: it scans over query chunks, computing each chunk's full score row
in fp32 (memory: B*H*q_chunk*Skv). On TPU the per-chunk einsum maps onto
the MXU; the q-chunk loop is `lax.scan` in production and a Python loop
under ``unroll=True`` (dry-run cost probes, where scan bodies must appear
once per iteration in the HLO).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _normal, apply_rope

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": _normal(ks[0], (d, h, hd), s, pd),
        "wk": _normal(ks[1], (d, kv, hd), s, pd),
        "wv": _normal(ks[2], (d, kv, hd), s, pd),
        "wo": _normal(ks[3], (h, hd, d), (h * hd) ** -0.5, pd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), pd)
        p["bk"] = jnp.zeros((kv, hd), pd)
        p["bv"] = jnp.zeros((kv, hd), pd)
    return p


def _constrain_heads(t: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Pin (B,S,H,D) activations to (batch=data, heads=TP). Padding-sharding
    of non-divisible head counts is legal for intermediates (only jit
    inputs must divide), which keeps e.g. 28-head models on head-TP instead
    of falling into resharding storms."""
    if not cfg.act_model_axis or not cfg.act_batch_axes:
        return t
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        t, P(tuple(cfg.act_batch_axes), None, cfg.act_model_axis, None))


def qkv_project(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = _constrain_heads(q, cfg)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = _constrain_heads(q, cfg)
    return q, k, v


def _chunk_attend(qc: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  q_idx: jnp.ndarray, kv_valid: int | jnp.ndarray,
                  causal: bool, head_dim: int) -> jnp.ndarray:
    """One query chunk vs. the full KV. qc: (B,C,KV,G,D), k/v: (B,S,KV,D)."""
    scale = head_dim ** -0.5
    scores = jnp.einsum("bckgd,bskd->bkgcs", qc, k).astype(jnp.float32) * scale
    kv_idx = jnp.arange(k.shape[1])
    mask = kv_idx[None, :] < kv_valid  # (1, S) or broadcast
    if causal:
        mask = mask & (kv_idx[None, :] <= q_idx[:, None])
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(qc.dtype)
    return jnp.einsum("bkgcs,bskd->bckgd", probs, v)


# ---------------------------------------------------------------------------
# Flash attention (training path): custom_vjp that saves only (out, lse) and
# recomputes scores in the backward — removes the O(Sq*Skv) fp32 softmax
# residuals that otherwise dominate activation memory.
# ---------------------------------------------------------------------------

def _flash_chunk_fwd(qc, k, v, q_idx, kv_valid, causal, scale):
    """qc: (B,C,KV,G,D) -> (out, lse). lse: (B,KV,G,C) fp32."""
    s = jnp.einsum("bckgd,bskd->bkgcs", qc, k,
                   preferred_element_type=jnp.float32) * scale
    kv_idx = jnp.arange(k.shape[1])
    mask = kv_idx[None, :] < kv_valid
    if causal:
        mask = mask & (kv_idx[None, :] <= q_idx[:, None])
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jax.lax.stop_gradient(s.max(-1, keepdims=True))
    p = jnp.exp(s - m)
    l = p.sum(-1, keepdims=True)
    lse = (m + jnp.log(l))[..., 0]
    out = jnp.einsum("bkgcs,bskd->bckgd", (p / l).astype(qc.dtype), v)
    return out, lse


def _flash_chunk_bwd(qc, k, v, oc, lse, doc, q_idx, kv_valid, causal, scale):
    """Gradients for one q-chunk: returns (dqc, dk_contrib, dv_contrib)."""
    s = jnp.einsum("bckgd,bskd->bkgcs", qc, k,
                   preferred_element_type=jnp.float32) * scale
    kv_idx = jnp.arange(k.shape[1])
    mask = kv_idx[None, :] < kv_valid
    if causal:
        mask = mask & (kv_idx[None, :] <= q_idx[:, None])
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - lse[..., None])                         # (B,KV,G,C,S)
    dv = jnp.einsum("bkgcs,bckgd->bskd", p.astype(doc.dtype), doc)
    dp = jnp.einsum("bckgd,bskd->bkgcs", doc, v,
                    preferred_element_type=jnp.float32)
    delta = jnp.einsum("bckgd,bckgd->bkgc", doc.astype(jnp.float32),
                       oc.astype(jnp.float32))
    ds = p * (dp - delta[..., None]) * scale                # (B,KV,G,C,S)
    dqc = jnp.einsum("bkgcs,bskd->bckgd", ds.astype(qc.dtype), k)
    dk = jnp.einsum("bkgcs,bckgd->bskd", ds.astype(qc.dtype), qc)
    return dqc, dk, dv


def _make_flash(causal: bool, q_chunk: int, q_offset: int, unroll: bool):
    @jax.custom_vjp
    def flash(q5, k, v, kv_valid):
        out, _ = flash_fwd(q5, k, v, kv_valid)
        return out

    def chunks_of(q5):
        b, sq, kvh, g, d = q5.shape
        n = max(1, sq // q_chunk)
        return q5.reshape(b, n, sq // n, kvh, g, d), n, sq // n

    def flash_fwd(q5, k, v, kv_valid):
        scale = q5.shape[-1] ** -0.5
        qs, n, c = chunks_of(q5)

        def one(i):
            q_idx = q_offset + i * c + jnp.arange(c)
            return _flash_chunk_fwd(qs[:, i], k, v, q_idx, kv_valid, causal,
                                    scale)

        if unroll or n == 1:
            outs, lses = zip(*[one(i) for i in range(n)])
            out = jnp.stack(outs, 1)
            lse = jnp.stack(lses, 1)
        else:
            out, lse = jax.lax.map(one, jnp.arange(n))
            out = jnp.moveaxis(out, 0, 1)
            lse = jnp.moveaxis(lse, 0, 1)
        # lse: (B, n_chunks, KV, G, C)
        return out.reshape(q5.shape), (q5, k, v, kv_valid,
                                       out.reshape(q5.shape), lse)

    def flash_bwd(res, do):
        q5, k, v, kv_valid, out, lse = res
        scale = q5.shape[-1] ** -0.5
        qs, n, c = chunks_of(q5)
        os_ = out.reshape(qs.shape)
        dos = do.reshape(qs.shape)

        def one(i, dk, dv):
            q_idx = q_offset + i * c + jnp.arange(c)
            dqc, dkc, dvc = _flash_chunk_bwd(
                qs[:, i], k, v, os_[:, i], lse[:, i], dos[:, i], q_idx,
                kv_valid, causal, scale)
            return dqc, dk + dkc.astype(dk.dtype), dv + dvc.astype(dv.dtype)

        dk0 = jnp.zeros(k.shape, jnp.float32)
        dv0 = jnp.zeros(v.shape, jnp.float32)
        if unroll or n == 1:
            dqs = []
            dk, dv = dk0, dv0
            for i in range(n):
                dqc, dk, dv = one(i, dk, dv)
                dqs.append(dqc)
            dq = jnp.stack(dqs, 1)
        else:
            def body(carry, i):
                dk, dv = carry
                dqc, dk, dv = one(i, dk, dv)
                return (dk, dv), dqc
            (dk, dv), dq = jax.lax.scan(body, (dk0, dv0), jnp.arange(n))
            dq = jnp.moveaxis(dq, 0, 1)
        return (dq.reshape(q5.shape), dk.astype(k.dtype), dv.astype(v.dtype),
                None)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool, q_offset: int = 0,
                    kv_valid: Optional[jnp.ndarray] = None,
                    q_chunk: int = 128, unroll: bool = False) -> jnp.ndarray:
    """Memory-lean attention: q (B,Sq,H,D), k/v (B,Skv,KV,D)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    q5 = q.reshape(b, sq, kvh, h // kvh, hd)
    if kv_valid is None:
        kv_valid = jnp.asarray(k.shape[1], jnp.int32)
    q_chunk = min(q_chunk, sq)
    if sq % q_chunk != 0:
        q_chunk = sq
    fn = _make_flash(causal, q_chunk, q_offset, unroll)
    out = fn(q5, k, v, jnp.asarray(kv_valid))
    return out.reshape(b, sq, h, hd)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
           causal: bool, cfg: ModelConfig, q_offset: int = 0,
           kv_valid: Optional[jnp.ndarray] = None,
           q_chunk: int = 128, unroll: bool = False,
           use_flash: bool = True) -> jnp.ndarray:
    """q: (B,Sq,H,D); k,v: (B,Skv,KV,D) -> (B,Sq,H,D).

    use_flash=True routes through the custom_vjp flash path (O(Sq) softmax
    residuals); use_flash=False is the naive reference used by tests.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if use_flash:
        return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_valid=kv_valid, q_chunk=q_chunk,
                               unroll=unroll)
    if kv_valid is None:
        kv_valid = k.shape[1]
    qg = q.reshape(b, sq, kvh, g, hd)
    if sq <= q_chunk:
        q_idx = q_offset + jnp.arange(sq)
        out = _chunk_attend(qg, k, v, q_idx, kv_valid, causal, hd)
        return out.reshape(b, sq, h, hd)
    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qg = qg.reshape(b, n_chunks, q_chunk, kvh, g, hd)

    def body(i):
        q_idx = q_offset + i * q_chunk + jnp.arange(q_chunk)
        return _chunk_attend(qg[:, i], k, v, q_idx, kv_valid, causal, hd)

    if unroll:
        out = jnp.stack([body(i) for i in range(n_chunks)], axis=1)
    else:
        out = jax.lax.map(lambda i: body(i), jnp.arange(n_chunks))  # (n,B,C,KV,G,D)
        out = jnp.moveaxis(out, 0, 1)
    return out.reshape(b, sq, h, hd)


def attn_output(p: Params, ctx: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(ctx.dtype))


# ---------------------------------------------------------------------------
# KV cache (serving)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> Dict[str, jnp.ndarray]:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def cache_write(cache: Dict[str, jnp.ndarray], k_new: jnp.ndarray,
                v_new: jnp.ndarray, pos: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Write (B, S_new, KV, D) at position `pos` (scalar int32)."""
    return {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1),
    }


def decode_attend(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                  pos: jnp.ndarray, cfg: ModelConfig
                  ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode: x (B,1,d), cache (B,S,KV,D), pos scalar."""
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k_new, v_new = qkv_project(p, x, cfg, positions)
    cache = cache_write(cache, k_new, v_new, pos)
    ctx = attend(q, cache["k"].astype(x.dtype), cache["v"].astype(x.dtype),
                 causal=False, cfg=cfg, kv_valid=pos + 1)
    return attn_output(p, ctx), cache
