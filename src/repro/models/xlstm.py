"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel training form +
exact recurrent decode) and sLSTM (scalar memory, exponential gating,
sequential scan).

The chunkwise mLSTM follows the stabilized formulation: per head it carries
(C (P,P), n (P), m (scalar max-state)); within a chunk the quadratic
attention-like form is used, across chunks a `lax.scan` propagates the
carry. The recurrent step form is mathematically identical and serves as
the decode path and the test oracle.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _normal, apply_norm, init_norm

NEG = -1e30


def mlstm_dims(cfg: ModelConfig) -> Dict[str, int]:
    di = int(cfg.xlstm.proj_factor_mlstm * cfg.d_model)
    h = cfg.n_heads
    return dict(d_inner=di, n_heads=h, head_dim=di // h)


def slstm_dims(cfg: ModelConfig) -> Dict[str, int]:
    d = cfg.d_model
    h = cfg.n_heads
    ff = int(round(cfg.xlstm.proj_factor_slstm * d))
    return dict(d=d, n_heads=h, head_dim=d // h, d_ff=ff)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg: ModelConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    dm = mlstm_dims(cfg)
    d, di, h = cfg.d_model, dm["d_inner"], dm["n_heads"]
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    si = di ** -0.5
    return {
        "w_up": _normal(ks[0], (d, 2 * di), s, pd),
        "conv_w": _normal(ks[1], (cfg.xlstm.conv_kernel, di), 0.5, pd),
        "conv_b": jnp.zeros((di,), pd),
        "w_q": _normal(ks[2], (di, di), si, pd),
        "w_k": _normal(ks[3], (di, di), si, pd),
        "w_v": _normal(ks[4], (di, di), si, pd),
        "w_i": _normal(ks[5], (di, h), si, jnp.float32),
        "b_i": jnp.zeros((h,), jnp.float32),
        "w_f": _normal(ks[6], (di, h), si, jnp.float32),
        "b_f": 3.0 * jnp.ones((h,), jnp.float32),
        "headnorm": jnp.ones((di,), pd),
        "w_down": _normal(ks[7], (di, d), si, pd),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 cache: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    ext = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + ext[:, i: i + x.shape[1]] * w[i].astype(x.dtype)
    out = jax.nn.silu(out + b.astype(x.dtype))
    return out, ext[:, ext.shape[1] - (k - 1):]


def _mlstm_chunk(carry, inputs):
    """carry: (C (B,H,P,P), n (B,H,P), m (B,H)) fp32.
    inputs: q,k,v (B,L,H,P); logi, logf (B,L,H) fp32."""
    c_prev, n_prev, m_prev = carry
    q, k, v, logi, logf = inputs
    b, l, h, p = q.shape
    scale = p ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    fcum = jnp.cumsum(logf, axis=1)                                # (B,L,H)
    # intra-chunk log weights: D[l,m] = fcum_l - fcum_m + logi_m (m <= l)
    dmat = fcum[:, :, None, :] - fcum[:, None, :, :] + logi[:, None, :, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    dmat = jnp.where(mask[None, :, :, None], dmat, NEG)            # (B,L,M,H)
    # inter weight: fcum_l + m_prev
    inter_log = fcum + m_prev[:, None, :]                          # (B,L,H)
    m_loc = jnp.maximum(dmat.max(axis=2), inter_log)               # (B,L,H)
    w_intra = jnp.exp(dmat - m_loc[:, :, None, :])                 # (B,L,M,H)
    w_inter = jnp.exp(inter_log - m_loc)                           # (B,L,H)
    scores = jnp.einsum("blhp,bmhp->blmh", qf, kf)                 # (B,L,M,H)
    num = (jnp.einsum("blmh,bmhp->blhp", scores * w_intra, vf)
           + jnp.einsum("blhp,bhpq->blhq", qf * w_inter[..., None], c_prev))
    # denominator: q_l . n_state_l where n_state_l = decayed n_prev + sum w k
    qn = (jnp.einsum("blmh,blmh->blh", w_intra, scores)
          + jnp.einsum("blhp,bhp->blh", qf * w_inter[..., None], n_prev))
    den = jnp.maximum(jnp.abs(qn), jnp.exp(-m_loc))
    y = num / den[..., None]
    # --- carry update ---
    flast = fcum[:, -1]                                            # (B,H)
    m_new = jnp.maximum(flast + m_prev, (flast[:, None] - fcum + logi).max(axis=1))
    wk = jnp.exp(flast[:, None] - fcum + logi - m_new[:, None])    # (B,L,H)
    c_new = (c_prev * jnp.exp(flast + m_prev - m_new)[:, :, None, None]
             + jnp.einsum("blhp,blhq->bhpq", kf * wk[..., None], vf))
    n_new = (n_prev * jnp.exp(flast + m_prev - m_new)[:, :, None]
             + (kf * wk[..., None]).sum(axis=1))
    return (c_new, n_new, m_new), y


def mlstm_sequence(q, k, v, logi, logf, chunk: int,
                   state: Optional[Tuple] = None, unroll: bool = False):
    """Chunkwise mLSTM. q,k,v: (B,S,H,P); logi/logf: (B,S,H) fp32."""
    b, s, h, p = q.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    if state is None:
        state = (jnp.zeros((b, h, p, p), jnp.float32),
                 jnp.zeros((b, h, p), jnp.float32),
                 jnp.full((b, h), 0.0, jnp.float32))

    def rs(t):
        return t.reshape((b, nc, chunk) + t.shape[2:])

    qs, ks_, vs, iis, ffs = rs(q), rs(k), rs(v), rs(logi), rs(logf)
    step = lambda carry, i: _mlstm_chunk(
        carry, (qs[:, i], ks_[:, i], vs[:, i], iis[:, i], ffs[:, i]))
    if unroll or nc == 1:
        ys = []
        for i in range(nc):
            state, y = step(state, i)
            ys.append(y)
        y = jnp.stack(ys, axis=1)
    else:
        state, y = jax.lax.scan(step, state, jnp.arange(nc))
        y = jnp.moveaxis(y, 0, 1)
    return y.reshape(b, s, h, p), state


def mlstm_step(q, k, v, logi, logf, state):
    """Exact recurrent step. q,k,v: (B,H,P); logi/logf: (B,H)."""
    c_prev, n_prev, m_prev = state
    p = q.shape[-1]
    qf = q.astype(jnp.float32) * p ** -0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    m_new = jnp.maximum(logf + m_prev, logi)
    fz = jnp.exp(logf + m_prev - m_new)
    iz = jnp.exp(logi - m_new)
    c_new = c_prev * fz[..., None, None] + iz[..., None, None] * kf[..., :, None] * vf[..., None, :]
    n_new = n_prev * fz[..., None] + iz[..., None] * kf
    num = jnp.einsum("bhp,bhpq->bhq", qf, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", qf, n_new)), jnp.exp(-m_new))
    return num / den[..., None], (c_new, n_new, m_new)


def apply_mlstm_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                      state: Optional[Dict] = None, unroll: bool = False,
                      return_state: bool = False):
    """Pre-norm residual mLSTM block. x: (B,S,d)."""
    dm = mlstm_dims(cfg)
    h, hd = dm["n_heads"], dm["head_dim"]
    dt = x.dtype
    up = x @ p["w_up"].astype(dt)
    xm, z = jnp.split(up, 2, axis=-1)
    conv_cache = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xm, p["conv_w"], p["conv_b"], conv_cache)
    b, s, _ = x.shape
    q = (xc @ p["w_q"].astype(dt)).reshape(b, s, h, hd)
    k = (xc @ p["w_k"].astype(dt)).reshape(b, s, h, hd)
    v = (xm @ p["w_v"].astype(dt)).reshape(b, s, h, hd)
    logi = xm.astype(jnp.float32) @ p["w_i"] + p["b_i"]
    logf = jax.nn.log_sigmoid(xm.astype(jnp.float32) @ p["w_f"] + p["b_f"])
    if state is not None:
        y, new_m = mlstm_step(q[:, 0], k[:, 0], v[:, 0], logi[:, 0], logf[:, 0],
                              state["mlstm"])
        y = y[:, None]
        new_state = {"mlstm": new_m, "conv": new_conv}
    else:
        y, mstate = mlstm_sequence(q, k, v, logi, logf, cfg.xlstm.chunk_size,
                                   unroll=unroll)
        new_state = ({"mlstm": mstate, "conv": new_conv.astype(jnp.bfloat16)}
                     if return_state else None)
    # headwise rmsnorm then flatten
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf ** 2).mean(-1, keepdims=True) + 1e-5)).astype(dt)
    y = y.reshape(b, s, dm["d_inner"]) * p["headnorm"].astype(dt)
    out = (y * jax.nn.silu(z)) @ p["w_down"].astype(dt)
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, cfg: ModelConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    dm = slstm_dims(cfg)
    d, h, hd, ff = dm["d"], dm["n_heads"], dm["head_dim"], dm["d_ff"]
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    p = {"conv_w": _normal(ks[0], (cfg.xlstm.conv_kernel, d), 0.5, pd),
         "conv_b": jnp.zeros((d,), pd)}
    for gi, gate in enumerate(("z", "i", "f", "o")):
        p[f"w_{gate}"] = _normal(ks[1 + gi], (d, d), s, pd)
        p[f"r_{gate}"] = _normal(ks[5 + gi], (h, hd, hd), hd ** -0.5, pd)
        p[f"b_{gate}"] = (3.0 * jnp.ones((d,), jnp.float32) if gate == "f"
                          else jnp.zeros((d,), jnp.float32))
    p["groupnorm"] = jnp.ones((d,), pd)
    p["ffn"] = {
        "w_gate": _normal(ks[9], (d, ff), s, pd),
        "w_up": _normal(ks[10], (d, ff), s, pd),
        "w_down": _normal(ks[11], (ff, d), ff ** -0.5, pd),
    }
    return p


def _slstm_cell(p: Params, xz, xi, xf, xo, state, n_heads: int):
    """One time step. x*: (B,d) fp32 pre-activations (input part).
    state: (c, n, m, h) each (B,d) fp32."""
    c, n, m, hprev = state
    b, d = xz.shape
    hd = d // n_heads
    hh = hprev.reshape(b, n_heads, hd)

    def rec(name):
        return jnp.einsum("bhp,hpq->bhq", hh, p[f"r_{name}"].astype(jnp.float32)
                          ).reshape(b, d)

    zt = jnp.tanh(xz + rec("z"))
    it = xi + rec("i")                       # log-space input gate
    ft = jax.nn.log_sigmoid(xf + rec("f"))   # log forget gate
    ot = jax.nn.sigmoid(xo + rec("o"))
    m_new = jnp.maximum(ft + m, it)
    iz = jnp.exp(it - m_new)
    fz = jnp.exp(ft + m - m_new)
    c_new = fz * c + iz * zt
    n_new = fz * n + iz
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new)


def apply_slstm_block(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                      state: Optional[Dict] = None, unroll: bool = False,
                      return_state: bool = False):
    """Pre-norm residual sLSTM block with post-FFN. x: (B,S,d)."""
    dm = slstm_dims(cfg)
    dt = x.dtype
    b, s, d = x.shape
    conv_cache = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(x, p["conv_w"], p["conv_b"], conv_cache)
    xz = (xc @ p["w_z"].astype(dt)).astype(jnp.float32) + p["b_z"]
    xi = (xc @ p["w_i"].astype(dt)).astype(jnp.float32) + p["b_i"]
    xf = (xc @ p["w_f"].astype(dt)).astype(jnp.float32) + p["b_f"]
    xo = (x @ p["w_o"].astype(dt)).astype(jnp.float32) + p["b_o"]
    if state is not None:
        st = _slstm_cell(p, xz[:, 0], xi[:, 0], xf[:, 0], xo[:, 0],
                         state["slstm"], cfg.n_heads)
        h = st[3][:, None].astype(dt)
        new_state = {"slstm": st, "conv": new_conv}
    else:
        init = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(4))

        def step(carry, t):
            st = _slstm_cell(p, xz[:, t], xi[:, t], xf[:, t], xo[:, t], carry,
                             cfg.n_heads)
            return st, st[3]

        if unroll:
            carry, hs = init, []
            for t in range(s):
                carry, ht = step(carry, t)
                hs.append(ht)
            h = jnp.stack(hs, axis=1).astype(dt)
        else:
            carry, h = jax.lax.scan(step, init, jnp.arange(s))
            h = jnp.moveaxis(h, 0, 1).astype(dt)
        new_state = ({"slstm": carry, "conv": new_conv.astype(jnp.bfloat16)}
                     if return_state else None)
    # group norm (per head) then FFN
    hf = h.astype(jnp.float32).reshape(b, s, cfg.n_heads, -1)
    mu = hf.mean(-1, keepdims=True)
    var = ((hf - mu) ** 2).mean(-1, keepdims=True)
    hf = ((hf - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d)
    h = hf.astype(dt) * p["groupnorm"].astype(dt)
    fp = p["ffn"]
    ff = jax.nn.gelu(h @ fp["w_gate"].astype(dt)) * (h @ fp["w_up"].astype(dt))
    out = ff @ fp["w_down"].astype(dt)
    return h + out, new_state


def apply_mlstm_block_with_state(p, x, cfg, unroll=False):
    return apply_mlstm_block(p, x, cfg, unroll=unroll, return_state=True)


def apply_slstm_block_with_state(p, x, cfg, unroll=False):
    return apply_slstm_block(p, x, cfg, unroll=unroll, return_state=True)


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict:
    dm = mlstm_dims(cfg)
    h, hd, di = dm["n_heads"], dm["head_dim"], dm["d_inner"]
    return {
        "mlstm": (jnp.zeros((batch, h, hd, hd), jnp.float32),
                  jnp.zeros((batch, h, hd), jnp.float32),
                  jnp.zeros((batch, h), jnp.float32)),
        "conv": jnp.zeros((batch, cfg.xlstm.conv_kernel - 1, di), jnp.bfloat16),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict:
    d = cfg.d_model
    return {
        "slstm": tuple(jnp.zeros((batch, d), jnp.float32) for _ in range(4)),
        "conv": jnp.zeros((batch, cfg.xlstm.conv_kernel - 1, d), jnp.bfloat16),
    }
