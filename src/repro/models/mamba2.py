"""Mamba2 (State-Space Duality) block.

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of length Q; each chunk computes its quadratic intra-chunk part and
the recurrence over chunk states is a `lax.scan` carrying the SSM state
(B, H, P, N). Decode is the exact single-step recurrence. Sub-quadratic in
sequence length; the per-chunk einsums are MXU-shaped on TPU.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, _normal


def ssm_dims(cfg: ModelConfig) -> Dict[str, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return dict(d_inner=d_inner, n_heads=n_heads, conv_dim=conv_dim,
                d_state=s.d_state, head_dim=s.head_dim, n_groups=s.n_groups,
                conv_kernel=s.conv_kernel)


def init_mamba2(key, cfg: ModelConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    dm = ssm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    in_dim = 2 * dm["d_inner"] + 2 * dm["n_groups"] * dm["d_state"] + dm["n_heads"]
    p = {
        "w_in": _normal(ks[0], (d, in_dim), d ** -0.5, pd),
        "conv_w": _normal(ks[1], (dm["conv_kernel"], dm["conv_dim"]), 0.5, pd),
        "conv_b": jnp.zeros((dm["conv_dim"],), pd),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, dm["n_heads"])).astype(jnp.float32),
        "d_skip": jnp.ones((dm["n_heads"],), jnp.float32),
        "dt_bias": jnp.zeros((dm["n_heads"],), jnp.float32),
        "norm_scale": jnp.ones((dm["d_inner"],), pd),
        "w_out": _normal(ks[2], (dm["d_inner"], d), dm["d_inner"] ** -0.5, pd),
    }
    return p


def _split_in(proj: jnp.ndarray, dm: Dict[str, int]):
    di, gn, h = dm["d_inner"], dm["n_groups"] * dm["d_state"], dm["n_heads"]
    z = proj[..., :di]
    xbc = proj[..., di: di + di + 2 * gn]
    dt = proj[..., di + di + 2 * gn:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _conv1d(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
            cache: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over seq. xbc: (B,S,C); w: (K,C).

    Returns (out (B,S,C), new_cache (B,K-1,C)). `cache` holds the last K-1
    inputs from the previous call (decode), zeros otherwise.
    """
    k = w.shape[0]
    if cache is None:
        cache = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    ext = jnp.concatenate([cache.astype(xbc.dtype), xbc], axis=1)   # (B, S+K-1, C)
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + ext[:, i: i + xbc.shape[1]] * w[i].astype(xbc.dtype)
    out = jax.nn.silu(out + b.astype(xbc.dtype))
    new_cache = ext[:, ext.shape[1] - (k - 1):]
    return out, new_cache


def _ssd_chunk(carry, inputs, *, head_dim: int):
    """One SSD chunk. carry: state (B,H,P,N) fp32. inputs per chunk:
    x (B,L,H,P), dt (B,L,H) fp32, A (H,) fp32, Bm/Cm (B,L,G,N)."""
    state = carry
    x, dt, a, bm, cm = inputs
    b, l, h, p = x.shape
    g = bm.shape[2]
    rep = h // g
    dt_a = dt * a[None, None, :]                                   # (B,L,H) <=0
    cum = jnp.cumsum(dt_a, axis=1)                                 # (B,L,H)
    # --- inter-chunk: contribution of carried state ---
    cm_h = jnp.repeat(cm, rep, axis=2)                             # (B,L,H,N)
    bm_h = jnp.repeat(bm, rep, axis=2)
    decay_in = jnp.exp(cum)                                        # (B,L,H)
    y_inter = jnp.einsum("blhn,bhpn->blhp", cm_h * decay_in[..., None], state)
    # --- intra-chunk (quadratic in L) ---
    seg = cum[:, :, None, :] - cum[:, None, :, :]                  # (B,L,M,H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    seg = jnp.where(mask[None, :, :, None], seg, -jnp.inf)
    decay = jnp.exp(seg)                                           # (B,L,M,H)
    scores = jnp.einsum("blhn,bmhn->blmh", cm_h, bm_h)             # (B,L,M,H)
    w = scores * decay * dt[:, None, :, :]                         # weight for x_m
    y_intra = jnp.einsum("blmh,bmhp->blhp", w.astype(x.dtype), x)
    # --- state update ---
    decay_out = jnp.exp(cum[:, -1:, :] - cum)                      # (B,L,H)
    contrib = jnp.einsum("blhn,blhp->bhpn",
                         (bm_h * (decay_out * dt)[..., None]).astype(jnp.float32),
                         x.astype(jnp.float32))
    state = state * jnp.exp(cum[:, -1])[:, :, None, None] + contrib
    return state, (y_inter.astype(x.dtype) + y_intra)


def ssd_forward(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                bm: jnp.ndarray, cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                unroll: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,H,P); dt: (B,S,H) fp32 (post-softplus); bm/cm: (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32)."""
    b, s, h, p = x.shape
    n = bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def reshape_c(t):
        return t.reshape((t.shape[0], nc, chunk) + t.shape[2:])

    xs = (reshape_c(x), reshape_c(dt), a, reshape_c(bm), reshape_c(cm))
    step = lambda carry, i: _ssd_chunk(
        carry, (xs[0][:, i], xs[1][:, i], xs[2], xs[3][:, i], xs[4][:, i]),
        head_dim=p)
    if unroll or nc == 1:
        state = init_state
        ys = []
        for i in range(nc):
            state, y = step(state, i)
            ys.append(y)
        y = jnp.stack(ys, axis=1)
    else:
        state, y = jax.lax.scan(step, init_state, jnp.arange(nc))
        y = jnp.moveaxis(y, 0, 1)                                  # (B,nc,L,H,P)
    return y.reshape(b, s, h, p), state


def apply_mamba2(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                 state: Optional[Dict[str, jnp.ndarray]] = None,
                 unroll: bool = False, return_state: bool = False
                 ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full Mamba2 mixer. x: (B,S,d). If `state` is given (decode), uses and
    returns {"ssm": (B,H,P,N), "conv": (B,K-1,C)}; S must be 1 then.
    ``return_state=True`` (prefill) returns the end-of-sequence state."""
    from repro.models.layers import rmsnorm_gated
    dm = ssm_dims(cfg)
    dt_ = x.dtype
    proj = x @ p["w_in"].astype(dt_)
    z, xbc, dt_raw = _split_in(proj, dm)
    conv_cache = state["conv"] if state is not None else None
    xbc, new_conv = _conv1d(xbc, p["conv_w"], p["conv_b"], conv_cache)
    di = dm["d_inner"]
    gn = dm["n_groups"] * dm["d_state"]
    xs = xbc[..., :di]
    bm = xbc[..., di: di + gn].reshape(x.shape[0], x.shape[1], dm["n_groups"], dm["d_state"])
    cm = xbc[..., di + gn:].reshape(x.shape[0], x.shape[1], dm["n_groups"], dm["d_state"])
    h, hd = dm["n_heads"], dm["head_dim"]
    xh = xs.reshape(x.shape[0], x.shape[1], h, hd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["a_log"])                                         # (H,) < 0

    if state is not None:  # exact recurrent decode (S == 1)
        s0 = state["ssm"]
        dta = jnp.exp(dt[:, 0] * a[None, :])                         # (B,H)
        bm_h = jnp.repeat(bm[:, 0], h // dm["n_groups"], axis=1)     # (B,H,N)
        cm_h = jnp.repeat(cm[:, 0], h // dm["n_groups"], axis=1)
        contrib = jnp.einsum("bhn,bhp->bhpn", bm_h.astype(jnp.float32) * dt[:, 0][..., None],
                             xh[:, 0].astype(jnp.float32))
        s1 = s0 * dta[:, :, None, None] + contrib
        y = jnp.einsum("bhpn,bhn->bhp", s1, cm_h.astype(jnp.float32))
        y = y[:, None].astype(dt_)
        new_state = {"ssm": s1, "conv": new_conv.astype(jnp.bfloat16)}
    else:
        y, s1 = ssd_forward(xh, dt, a, bm, cm, cfg.ssm.chunk_size, unroll=unroll)
        new_state = ({"ssm": s1, "conv": new_conv.astype(jnp.bfloat16)}
                     if return_state else None)
    y = y + xh * p["d_skip"][None, None, :, None].astype(dt_)
    y = y.reshape(x.shape[0], x.shape[1], di)
    y = rmsnorm_gated(y, z, p["norm_scale"])
    out = y @ p["w_out"].astype(dt_)
    return out, new_state


def apply_mamba2_with_final_state(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                                  unroll: bool = False):
    return apply_mamba2(p, x, cfg, unroll=unroll, return_state=True)


def init_mamba2_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    dm = ssm_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, dm["n_heads"], dm["head_dim"], dm["d_state"]), jnp.float32),
        "conv": jnp.zeros((batch, dm["conv_kernel"] - 1, dm["conv_dim"]), jnp.bfloat16),
    }
