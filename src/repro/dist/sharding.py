"""Sharding rules for the production meshes.

One function per artifact class:

  spec_for            one parameter leaf -> PartitionSpec (name + shape
                      heuristics; every rule degrades to replication when
                      an axis is not divisible by the mesh axis size)
  param_specs         whole parameter pytree
  batch_specs         input batches (leading batch dim over the data axes)
  cache_specs         KV caches (batch- or sequence-sharded decode)
  stacked_axes_tree   leading layer-axis count per leaf (scanned stacks)
  shardings_of        PartitionSpec pytree -> NamedSharding pytree

The layout strategy is FSDP over ``data`` + tensor parallelism over
``model``: weights shard their d_model (or expert-input) dimension over
the data axis and their heads / experts / head_dim dimension over the
model axis; norms and biases are tiny and stay replicated. The ``pod``
axis never appears here — it is the DiLoCo worker boundary and carries
only the outer exchange (see ``repro.dist.steps.make_outer_exchange``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
AxisName = Union[str, Tuple[str, ...]]


def _divisible(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def _leaf_path(path) -> str:
    parts = []
    for k in path:
        key = getattr(k, "key", None)
        if key is None:
            key = getattr(k, "name", None)
        if key is None:
            key = getattr(k, "idx", k)
        parts.append(str(key))
    return "/".join(parts)


def n_layer_axes(name: str) -> int:
    """Leading scanned-layer axes of a leaf (1 for stacked block params)."""
    return 1 if name.split("/", 1)[0] == "blocks" else 0


def stacked_axes_tree(params: PyTree) -> PyTree:
    """Pytree of ints (same structure as ``params``): how many leading
    axes of each leaf are scanned layer axes — the granularity contract
    of ``repro.core.heloco.block_correct`` / ``repro.core.packing``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [n_layer_axes(_leaf_path(p)) for p, _ in flat])


def spec_for(name: str, shape: Sequence[int], *,
             data_axis: AxisName = "data", model_axis: str = "model",
             axis_sizes: Dict[str, int],
             attn_style: str = "tp") -> P:
    """PartitionSpec for one parameter leaf.

    Rules (first match wins, every assignment requires divisibility):
      - norms / biases / rank<=1 payloads: fully replicated
      - embeddings: vocab axis over model, d_model over data
      - MoE expert stacks: expert axis over model, expert-input over data
      - attention projections: heads over model, falling back to head_dim
        when the head count does not divide the model axis (e.g. qwen2's
        28 heads on a 16-way axis); d_model over data
      - everything else: last axis over model, first remaining over data

    attn_style="dp" drops the tensor-parallel (model) assignment and
    keeps only the FSDP data-axis sharding.
    """
    shape = tuple(int(s) for s in shape)
    rank = len(shape)
    dsz = (axis_sizes.get(data_axis, 1) if isinstance(data_axis, str)
           else 1)  # tuple data axes: divisibility checked against product
    if not isinstance(data_axis, str):
        dsz = 1
        for a in data_axis:
            dsz *= axis_sizes.get(a, 1)
    msz = axis_sizes.get(model_axis, 1)
    parts = name.split("/")
    leaf = parts[-1]
    spec = [None] * rank
    n_layer = n_layer_axes(name)

    # tiny / vector-like leaves stay replicated
    if ("norm" in name or leaf in ("scale", "bias")
            or leaf in ("bq", "bk", "bv", "bo", "b_up", "b_gate", "b_down")
            or rank - n_layer <= 1):
        return P(*spec)

    # --- model (tensor-parallel) axis ------------------------------------
    model_idx: Optional[int] = None
    if attn_style != "dp":
        if "embed" in parts[0]:
            vocab = max(range(rank), key=lambda i: shape[i])
            candidates = [vocab]
        elif "moe" in parts:
            candidates = [n_layer]               # expert axis
        elif "attn" in parts and rank - n_layer >= 2:
            candidates = [rank - 2, rank - 1]    # heads, then head_dim
        else:
            candidates = [rank - 1]
        for i in candidates:
            if i >= n_layer and _divisible(shape[i], msz):
                model_idx = i
                spec[i] = model_axis
                break

    # --- data (FSDP) axis ------------------------------------------------
    for i in range(n_layer, rank):
        if i != model_idx and _divisible(shape[i], dsz):
            spec[i] = data_axis
            break

    return P(*spec)


def param_specs(params: PyTree, *, axis_sizes: Dict[str, int],
                data_axis: AxisName = "data", model_axis: str = "model",
                attn_style: str = "tp") -> PyTree:
    """PartitionSpec pytree for a whole parameter tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_for(_leaf_path(p), leaf.shape, data_axis=data_axis,
                      model_axis=model_axis, axis_sizes=axis_sizes,
                      attn_style=attn_style)
             for p, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch: PyTree, *, batch_axes: Tuple[str, ...] = ("data",)
                ) -> PyTree:
    """Leading (batch) dim over ``batch_axes``; everything else replicated."""
    axes = tuple(batch_axes)
    entry = axes if len(axes) > 1 else axes[0]

    def one(x):
        return P(*([entry] + [None] * (len(x.shape) - 1)))

    return jax.tree_util.tree_map(one, batch)


def cache_specs(caches: PyTree, *, batch_sharded: bool,
                axis_sizes: Dict[str, int],
                data_axis: AxisName = "data",
                model_axis: str = "model") -> PyTree:
    """KV-cache PartitionSpecs for decode: layout (L, B, S, kv_heads, hd).

    batch_sharded=True  -> batch over the data axis (throughput decode)
    batch_sharded=False -> sequence over the data axis (context-parallel
                           long decode, batch too small to split)
    kv heads shard over the model axis only when there are at least as
    many heads as devices; GQA's few kv heads fall back to head_dim TP.
    """
    msz = axis_sizes.get(model_axis, 1)
    dsz = 1
    for a in ([data_axis] if isinstance(data_axis, str) else data_axis):
        dsz *= axis_sizes.get(a, 1)

    def one(x):
        L, B, S, KV, HD = x.shape
        spec = [None] * 5
        if _divisible(KV, msz):
            spec[3] = model_axis
        elif _divisible(HD, msz):
            spec[4] = model_axis
        if batch_sharded:
            if B % dsz == 0:
                spec[1] = data_axis
        elif S % dsz == 0:
            spec[2] = data_axis
        return P(*spec)

    return jax.tree_util.tree_map(one, caches)


def shardings_of(specs: PyTree, mesh: Mesh) -> PyTree:
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
