"""Jit-able distributed steps for the production meshes.

  init_train_state / make_train_step      single-pod AdamW training step
  make_multipod_train_step                per-pod independent replicas:
                                          vmap over the leading pod axis,
                                          so GSPMD can emit NO cross-pod
                                          collective — inner DiLoCo rounds
                                          never talk across the pod axis
  make_prefill_step / make_decode_step    serving path
  make_outer_exchange                     the HeLoCo outer round: the only
                                          cross-pod traffic (one pod's
                                          pseudo-gradient in, corrected
                                          outer update + broadcast
                                          look-ahead init out)

All steps are pure functions built from the single-host reference math in
``repro.core.heloco`` / ``repro.optim.adamw`` — placement is expressed
exclusively through sharding constraints, never through per-device code,
so the same step lowers on 8 fake CPU devices (tests) and a v5e-512
(dry-run) unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import HeLoCoConfig, InnerOptConfig, ModelConfig
from repro.core import methods as outer_methods
from repro.core.heloco import OuterState, lookahead_init, outer_update
from repro.models import build_model
from repro.optim.adamw import AdamState, adamw_update, init_adam

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamState
    step: jnp.ndarray


def init_train_state(params: PyTree) -> TrainState:
    return TrainState(params=params, opt=init_adam(params),
                      step=jnp.zeros((), jnp.int32))


def _constrain(tree: PyTree, pspecs: Optional[PyTree]) -> PyTree:
    if pspecs is None:
        return tree
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, pspecs,
        is_leaf=lambda x: isinstance(x, P))


def _microbatches(batch: PyTree, n: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, inner: InnerOptConfig, *,
                    grad_accum: int = 1, q_chunk: int = 128,
                    unroll: bool = False,
                    param_pspecs: Optional[PyTree] = None):
    """One AdamW step; ``grad_accum`` splits the batch into microbatches
    scanned sequentially (mean loss/grads — identical math, 1/n the
    activation memory)."""
    model = build_model(cfg)

    def loss_fn(params, batch):
        loss, _aux = model.loss(params, batch, unroll=unroll,
                                q_chunk=q_chunk)
        return loss

    def step(state: TrainState, batch) -> tuple:
        params = _constrain(state.params, param_pspecs)
        if grad_accum > 1:
            micro = _microbatches(batch, grad_accum)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(carry, mb):
                acc, lacc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                    acc, grads)
                return (acc, lacc + loss / grad_accum), None

            (grads, loss), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt = adamw_update(params, grads, state.opt, inner)
        new_params = _constrain(new_params, param_pspecs)
        return TrainState(new_params, new_opt, state.step + 1), loss

    return step


def make_multipod_train_step(cfg: ModelConfig, inner: InnerOptConfig, mesh, *,
                             grad_accum: int = 1, q_chunk: int = 128,
                             unroll: bool = False,
                             param_pspecs: Optional[PyTree] = None):
    """Per-pod replica step: every leaf of state/batch carries a leading
    pod axis; the body is vmapped over it, which structurally guarantees
    pod independence (no cross-pod psum can appear — the DiLoCo inner
    round is communication-free across the worker boundary)."""
    # inner-body constraints can't mention the pod axis (they sit under
    # vmap); the pod placement is constrained on the stacked leaves here.
    base = make_train_step(cfg, inner, grad_accum=grad_accum,
                           q_chunk=q_chunk, unroll=unroll, param_pspecs=None)
    pod_pspecs = None
    if param_pspecs is not None:
        pod_pspecs = jax.tree_util.tree_map(
            lambda s: P("pod", *tuple(s)), param_pspecs,
            is_leaf=lambda x: isinstance(x, P))

    def step(state: TrainState, batch) -> tuple:
        state = state._replace(
            params=_constrain(state.params, pod_pspecs))
        new_state, loss = jax.vmap(base)(state, batch)
        new_state = new_state._replace(
            params=_constrain(new_state.params, pod_pspecs))
        return new_state, loss              # loss: (n_pods,)

    return step


def make_prefill_step(cfg: ModelConfig, *, cache_len: int,
                      q_chunk: int = 128, unroll: bool = False):
    model = build_model(cfg)

    def step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len,
                             unroll=unroll, q_chunk=q_chunk)

    return step


def make_decode_step(cfg: ModelConfig):
    model = build_model(cfg)

    def step(params, token, caches, pos):
        return model.decode(params, token, caches, pos)

    return step


# ---------------------------------------------------------------------------
# HeLoCo outer exchange — the only cross-pod communication
# ---------------------------------------------------------------------------

def _int8_roundtrip_leaf(x: jnp.ndarray) -> jnp.ndarray:
    """Per-tensor-block absmax int8 fake-quantization (wire format of the
    compressed exchange; error feedback lives worker-side, see
    ``repro.core.compression``)."""
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def make_outer_exchange(cfg: ModelConfig, mesh, *, h: HeLoCoConfig,
                        outer_lr: float, mu: float, method: str = "heloco",
                        arriving_pod: int = 0,
                        stacked_axes: Optional[PyTree] = None,
                        compress_int8: bool = False):
    """Build the outer round for one arriving pod.

    fn(params, momentum, worker_params) -> (new_params, new_momentum, bar)

    ``worker_params`` carries a leading pod axis; the arriving pod's
    pseudo-gradient Delta = theta - theta_w[arriving_pod] is (optionally
    int8-compressed, then) corrected per block against the server momentum
    and applied through the Nesterov outer update; ``bar`` is the Eq. 5
    look-ahead initialization broadcast back to every pod. On the
    multi-pod mesh this lowers to the pod-axis collectives that ARE the
    paper's communication cost — everything else in training is pod-local.
    """
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    m = outer_methods.resolve(method)
    if m.custom_update:
        raise NotImplementedError(
            f"outer method {m.name!r} needs per-method auxiliary state; "
            "the multi-pod outer exchange only supports methods on the "
            "standard Nesterov schedule")
    ctx = outer_methods.ArrivalCtx(outer_lr=outer_lr, mu=mu, h=h,
                                   tau=jnp.zeros((), jnp.float32),
                                   stacked_axes=stacked_axes)

    def fn(params: PyTree, momentum: PyTree, worker_params: PyTree):
        delta = jax.tree_util.tree_map(
            lambda p, wp: (p.astype(jnp.float32)
                           - wp[arriving_pod].astype(jnp.float32)),
            params, worker_params)
        if compress_int8:
            delta = jax.tree_util.tree_map(_int8_roundtrip_leaf, delta)
        g = m.correct(m, ctx, delta, momentum)
        state = outer_update(
            OuterState(params=params, momentum=momentum,
                       step=jnp.zeros((), jnp.int32)),
            g, outer_lr, mu)
        bar = lookahead_init(state, outer_lr, mu)
        bar_pods = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), bar)
        return state.params, state.momentum, bar_pods

    return fn
