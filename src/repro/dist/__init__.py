"""Distribution layer: sharding rules (PartitionSpecs for params, batches,
KV caches) and jit-able train/prefill/decode/outer-exchange steps for the
production meshes in ``repro.launch.mesh``."""
from repro.dist import sharding, steps  # noqa: F401

__all__ = ["sharding", "steps"]
