"""Deterministic synthetic "multilingual" corpus for non-IID experiments.

Each language (= shard = data domain) is a distinct stochastic process over
its own token sub-range plus a shared token pool, mimicking the paper's
multilingual mC4 setup: distributions differ per shard (non-IID) but share
structure. Sequences come from a per-language affine bigram process with
Zipf-distributed innovations — cheap, deterministic, and learnable, so
validation loss decreases with training and differs measurably across
languages (what Fig. 3 needs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

LANGS = ("de", "en", "es", "fr", "it")


@dataclass(frozen=True)
class LanguageSpec:
    lang: str
    index: int
    vocab_size: int          # model vocab
    lo: int                  # language-private token range [lo, hi)
    hi: int
    shared_lo: int           # shared token range
    shared_hi: int
    a: int                   # affine bigram multiplier
    b: int                   # affine bigram offset
    noise: float             # innovation probability
    share_p: float           # probability of emitting a shared token


def make_language_specs(vocab_size: int, n_langs: int = 5,
                        seed: int = 0) -> List[LanguageSpec]:
    rng = np.random.default_rng(seed)
    shared = max(8, vocab_size // 8)
    per = (vocab_size - shared) // n_langs
    specs = []
    for i in range(n_langs):
        lo = shared + i * per
        specs.append(LanguageSpec(
            lang=LANGS[i % len(LANGS)] + ("" if i < len(LANGS) else str(i)),
            index=i,
            vocab_size=vocab_size,
            lo=lo, hi=lo + per,
            shared_lo=0, shared_hi=shared,
            a=int(rng.integers(3, 17)) * 2 + 1,
            b=int(rng.integers(1, per)),
            noise=0.12 + 0.03 * i,
            share_p=0.15,
        ))
    return specs


def sample_tokens(spec: LanguageSpec, batch: int, seq: int,
                  rng: np.random.Generator) -> np.ndarray:
    """(batch, seq+1) int32 token ids from language `spec`."""
    width = spec.hi - spec.lo
    out = np.empty((batch, seq + 1), np.int64)
    state = rng.integers(0, width, size=batch)
    zipf = np.minimum(rng.zipf(1.5, size=(batch, seq + 1)), width) - 1
    noise_mask = rng.random((batch, seq + 1)) < spec.noise
    share_mask = rng.random((batch, seq + 1)) < spec.share_p
    shared_tok = rng.integers(spec.shared_lo, spec.shared_hi,
                              size=(batch, seq + 1))
    for t in range(seq + 1):
        state = (spec.a * state + spec.b) % width
        state = np.where(noise_mask[:, t], (state + zipf[:, t]) % width, state)
        out[:, t] = np.where(share_mask[:, t], shared_tok[:, t],
                             spec.lo + state)
    return out.astype(np.int32)


def mixture_weights(n_langs: int, alpha: float, wid: int,
                    seed: int = 0) -> np.ndarray:
    """Per-worker language mixture ~ Dirichlet(alpha): the paper's
    data-heterogeneity axis between one-shard-per-worker (alpha -> 0) and
    the IID global mixture (alpha -> inf). Deterministic in (seed, wid)."""
    rng = np.random.default_rng([seed, 7919, wid])
    return rng.dirichlet(np.full(n_langs, float(alpha)))


class ShardSampler:
    """Deterministic batch stream for one worker.

    non-IID: the worker draws from a single language, or — when `mixture`
    is given — each sequence from its per-worker language mixture
    (Dirichlet non-IID, see `mixture_weights`).
    IID: the worker draws each sequence from a uniformly random language
    (the global mixture), so all workers share one distribution.
    """

    def __init__(self, specs: Sequence[LanguageSpec], lang_index: Optional[int],
                 batch: int, seq: int, seed: int,
                 mixture: Optional[Sequence[float]] = None):
        self.specs = list(specs)
        self.lang_index = lang_index
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.mixture = None if mixture is None else np.asarray(mixture, float)

    def sample(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + (self.lang_index or 0) * 101 + step)
            % (2 ** 63))
        if self.mixture is not None:
            langs = rng.choice(len(self.specs), size=self.batch,
                               p=self.mixture / self.mixture.sum())
            toks = np.concatenate([
                sample_tokens(self.specs[li], 1, self.seq, rng)
                for li in langs], axis=0)
        elif self.lang_index is None:  # IID mixture
            langs = rng.integers(0, len(self.specs), size=self.batch)
            toks = np.concatenate([
                sample_tokens(self.specs[li], 1, self.seq, rng)
                for li in langs], axis=0)
        else:
            toks = sample_tokens(self.specs[self.lang_index], self.batch,
                                 self.seq, rng)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def eval_batches(specs: Sequence[LanguageSpec], batch: int, seq: int,
                 seed: int = 10_007) -> List[dict]:
    """Held-out per-language eval batches (Fig. 3 protocol)."""
    rng = np.random.default_rng(seed)
    out = []
    for spec in specs:
        toks = sample_tokens(spec, batch, seq, rng)
        out.append({"tokens": toks[:, :-1], "labels": toks[:, 1:],
                    "lang": spec.lang})
    return out
