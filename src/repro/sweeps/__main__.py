"""Sweep CLI.

    PYTHONPATH=src python -m repro.sweeps list
    PYTHONPATH=src python -m repro.sweeps run smoke
    PYTHONPATH=src python -m repro.sweeps run paper_table2 --force
    PYTHONPATH=src python -m repro.sweeps report smoke   # re-render only
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.sweeps import (
    SWEEP_DIR, all_sweeps, generate_report, get_sweep, run_sweep,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.sweeps")
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="registered sweep specs")

    p_run = sub.add_parser("run", help="execute a sweep + write its report")
    p_run.add_argument("name")
    p_run.add_argument("--out", default=SWEEP_DIR)
    p_run.add_argument("--force", action="store_true",
                       help="ignore cached cell results")
    p_run.add_argument("--no-report", action="store_true")

    p_rep = sub.add_parser("report", help="re-render the report from an "
                                          "existing results.json")
    p_rep.add_argument("name")
    p_rep.add_argument("--out", default=SWEEP_DIR)

    args = ap.parse_args(argv)

    if args.cmd == "list":
        for s in all_sweeps():
            grid = (f"{len(s.methods)}m x {len(s.scenarios)}s x "
                    f"{len(s.budgets)}b")
            print(f"{s.name:20s} [{grid:14s}] {s.description}")
        return 0

    if args.cmd == "run":
        run_sweep(args.name, out_dir=args.out, force=args.force,
                  report=not args.no_report)
        return 0

    # report
    spec = get_sweep(args.name)
    path = os.path.join(args.out, spec.name, "results.json")
    if not os.path.exists(path):
        print(f"no results at {path}; run the sweep first",
              file=sys.stderr)
        return 2
    with open(path) as f:
        doc = json.load(f)
    for p in generate_report(spec, doc, os.path.join(args.out, spec.name)):
        print(f"# report -> {p}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
