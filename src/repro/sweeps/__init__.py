"""Budgeted hyperparameter/ablation sweeps over the scenario grid.

The paper's headline evidence is comparative at FIXED budgets: every
method gets the same token count (Table 2, fixed-token) or the same
clock horizon (fixed-wallclock), and Section 5 analyzes update quality
along the way. This package makes that grid declarative:

    from repro.sweeps import SweepSpec, BudgetSpec, run_sweep
    run_sweep("smoke")                        # registered CI grid
    run_sweep(SweepSpec(name="mine", methods=("heloco", "mla"),
                        scenarios=("paper_hetero_severe",),
                        budgets=(BudgetSpec("fixed_tokens", 4096),)))

CLI: ``python -m repro.sweeps {list, run} ...`` (see docs/sweeps.md).
"""
from repro.sweeps.report import (               # noqa: F401
    alignment_curves, comparison_tables, generate_report,
)
from repro.sweeps.runner import SWEEP_DIR, run_sweep  # noqa: F401
from repro.sweeps.spec import (                 # noqa: F401
    BudgetSpec, SweepAxis, SweepCell, SweepSpec, all_sweeps, get_sweep,
    names, register,
)
