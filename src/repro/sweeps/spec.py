"""Declarative sweep specs: the paper's ablation grid as data.

A ``SweepSpec`` names a grid of (method x scenario x hyperparameter-axis
x budget) cells. Every cell compiles to a derived ``Scenario`` (method
swapped in with its Table-3 defaults, axis overrides applied, outer-step
cap raised so the BUDGET is the binding stopping rule) plus an engine
``Budget``; the runner (``repro.sweeps.runner``) executes cells through
the cached benchmark harness with telemetry streaming, and the report
generator (``repro.sweeps.report``) renders the paper-style comparison
tables from the results.

Budget kinds (the paper's two headline comparisons + plain steps):

  fixed_tokens     every method sees the same token count (Table 2 left)
  fixed_wallclock  every method gets the same clock horizon (Table 2
                   right — where asynchrony actually pays)
  outer_steps      classic fixed-step run (analysis sweeps)
"""
from __future__ import annotations

import itertools
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.async_engine.engine import Budget
from repro.scenarios.spec import Scenario

# Scenario fields a method swap must reset so the incoming method's
# Table-3 defaults apply instead of the base scenario's tuning.
_METHOD_DEFAULT_FIELDS = dict(outer_lr=None, momentum=None,
                              weight_factor=None, lookahead_init=None)


@dataclass(frozen=True)
class SweepAxis:
    """One hyperparameter axis: a Scenario field swept over values."""
    key: str
    values: Tuple[Any, ...]

    def __post_init__(self):
        assert self.values, "empty axis"
        assert self.key in Scenario.__dataclass_fields__, self.key


@dataclass(frozen=True)
class BudgetSpec:
    """Stopping rule of one grid slice."""
    kind: str                        # Budget.KINDS + "outer_steps"
    amount: float

    def __post_init__(self):
        assert self.kind in (*Budget.KINDS, "outer_steps"), self.kind
        assert self.amount > 0, self.amount

    def to_budget(self) -> Optional[Budget]:
        if self.kind == "outer_steps":
            return None
        return Budget(self.kind, self.amount)

    @property
    def label(self) -> str:
        short = {"fixed_tokens": "tok", "fixed_wallclock": "sec",
                 "outer_steps": "steps"}[self.kind]
        amt = int(self.amount) if float(self.amount).is_integer() \
            else self.amount
        return f"{short}{amt}"


@dataclass(frozen=True)
class SweepCell:
    """One fully-resolved grid cell, ready to run."""
    cell_id: str
    scenario: Scenario               # derived spec (method/axes applied)
    base: str                        # base scenario name
    method: str
    budget: BudgetSpec
    overrides: Dict[str, Any] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"cell_id": self.cell_id, "base": self.base,
                "method": self.method,
                "budget": {"kind": self.budget.kind,
                           "amount": self.budget.amount},
                "overrides": dict(self.overrides)}


def _slug(v: Any) -> str:
    return re.sub(r"[^\w.]+", "-", str(v)).strip("-")


@dataclass(frozen=True)
class SweepSpec:
    name: str
    description: str = ""
    methods: Tuple[str, ...] = ("heloco",)
    scenarios: Tuple[str, ...] = ("paper_hetero_severe",)
    budgets: Tuple[BudgetSpec, ...] = (BudgetSpec("outer_steps", 12),)
    axes: Tuple[SweepAxis, ...] = ()
    outer_cap: int = 64              # step cap when a budget is binding
    baseline: str = ""               # %-comparison anchor (default: first
    # method of the spec)
    eval_every: int = 0              # 0 -> the derived scenario's cadence
    telemetry: bool = True

    def __post_init__(self):
        assert self.methods and self.scenarios and self.budgets

    @property
    def baseline_method(self) -> str:
        from repro.core import methods as outer_methods
        return outer_methods.canonical(self.baseline or self.methods[0])

    def cells(self) -> List[SweepCell]:
        """Enumerate the full grid, validating every base scenario."""
        from repro.scenarios import registry
        out: List[SweepCell] = []
        combos = list(itertools.product(*(ax.values for ax in self.axes))) \
            or [()]
        for budget in self.budgets:
            for base_name in self.scenarios:
                base = registry.get_scenario(base_name)
                if base.failures or base.elastic:
                    raise ValueError(
                        f"sweep base scenario {base_name!r} carries a "
                        "failure/elastic schedule; budgeted cached runs "
                        "do not support those")
                for method in self.methods:
                    for combo in combos:
                        overrides = {ax.key: v
                                     for ax, v in zip(self.axes, combo)}
                        steps = (int(budget.amount)
                                 if budget.kind == "outer_steps"
                                 else max(self.outer_cap, base.outer_steps))
                        parts = [self.name, budget.label, base_name, method]
                        parts += [f"{k}-{_slug(v)}"
                                  for k, v in overrides.items()]
                        cell_id = "__".join(parts)
                        scn = base.overridden(
                            name=cell_id, method=method,
                            outer_steps=steps,
                            **_METHOD_DEFAULT_FIELDS, **overrides)
                        out.append(SweepCell(
                            cell_id=cell_id, scenario=scn, base=base_name,
                            method=scn.method, budget=budget,
                            overrides=overrides))
        ids = [c.cell_id for c in out]
        assert len(set(ids)) == len(ids), "duplicate sweep cell ids"
        return out


# ---------------------------------------------------------------------------
# Named sweeps (the enumerable ablation grids; ``python -m repro.sweeps``)
# ---------------------------------------------------------------------------

_SWEEPS: Dict[str, SweepSpec] = {}


def register(spec: SweepSpec) -> SweepSpec:
    if spec.name in _SWEEPS:
        raise ValueError(f"duplicate sweep name {spec.name!r}")
    _SWEEPS[spec.name] = spec
    return spec


def get_sweep(name: str) -> SweepSpec:
    try:
        return _SWEEPS[name]
    except KeyError:
        raise KeyError(f"unknown sweep {name!r}; registered: "
                       f"{', '.join(_SWEEPS)}") from None


def names() -> List[str]:
    return list(_SWEEPS)


def all_sweeps() -> List[SweepSpec]:
    return list(_SWEEPS.values())


register(SweepSpec(
    name="smoke",
    description="CI-sized 2-method x 2-scenario grid under both paper "
                "budgets; produces the comparison tables + the "
                "staleness-alignment artifact in a couple of minutes.",
    methods=("heloco", "nesterov"),
    scenarios=("paper_hetero_severe", "noniid_dirichlet"),
    budgets=(BudgetSpec("fixed_tokens", 512),
             BudgetSpec("fixed_wallclock", 12.0)),
    outer_cap=24, baseline="nesterov"))

register(SweepSpec(
    name="paper_table2",
    description="Every registered async method on the paper's severe-"
                "heterogeneity and Dirichlet non-IID scenarios at a fixed "
                "token AND a fixed wall-clock budget (Table 2 protocol).",
    methods=("heloco", "mla", "nesterov", "delayed_nesterov", "dcasgd",
             "fedbuff", "poly_stale"),
    scenarios=("paper_hetero_severe", "noniid_dirichlet", "drop_stale"),
    budgets=(BudgetSpec("fixed_tokens", 4096),
             BudgetSpec("fixed_wallclock", 120.0)),
    outer_cap=96, baseline="nesterov"))

register(SweepSpec(
    name="staleness_analysis",
    description="Section-5 update-quality analysis: HeLoCo vs MLA vs "
                "plain Nesterov over a staleness-inducing pace profile, "
                "with the drop threshold swept (App. A.6).",
    methods=("heloco", "mla", "nesterov"),
    scenarios=("paper_hetero_severe",),
    budgets=(BudgetSpec("outer_steps", 24),),
    axes=(SweepAxis("drop_stale_after", (None, 2)),),
    baseline="nesterov"))
