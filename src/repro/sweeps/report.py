"""Sweep report generator: the paper-style artifacts.

From a sweep results document (``repro.sweeps.runner``) this renders:

  tables.json / report.md   Table-2-like comparison grids — one table
                            per budget, methods x scenario-cells, final
                            eval loss with the %-delta against the
                            spec's baseline method (negative = better);
  staleness_alignment.json  the Section-5 staleness -> update-quality
                            curves per method, aggregated from the real
                            per-arrival telemetry streams;
  report.md also carries the per-language final-loss breakdown (Fig. 3 /
  Dirichlet non-IID fairness) and the per-method telemetry summaries.
"""
from __future__ import annotations

import json
import os
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

from repro.telemetry import TelemetryRecorder, staleness_alignment


# ---------------------------------------------------------------------------
# Table assembly
# ---------------------------------------------------------------------------

def _col_label(row: Dict) -> str:
    parts = [row["base"]]
    parts += [f"{k}={v}" for k, v in sorted(row.get("overrides",
                                                    {}).items())]
    return " ".join(parts)


def _budget_label(b: Dict) -> str:
    amt = int(b["amount"]) if float(b["amount"]).is_integer() \
        else b["amount"]
    return {"fixed_tokens": f"fixed token budget ({amt} tokens)",
            "fixed_wallclock": f"fixed wall-clock budget ({amt}s)",
            "outer_steps": f"fixed outer steps ({amt})"}[b["kind"]]


def comparison_tables(doc: Dict) -> List[Dict]:
    """One table per budget: {budget, columns, rows: {method: {col:
    {loss, delta_pct}}}} — delta_pct is vs the baseline method."""
    from repro.core import methods as outer_methods
    baseline = doc["baseline"]
    tables = []
    for b in doc["budgets"]:
        cells = [r for r in doc["cells"] if r["budget"] == b]
        if not cells:
            continue
        cols = sorted({_col_label(r) for r in cells})
        by = {(r["method"], _col_label(r)): r for r in cells}
        rows: Dict[str, Dict[str, Dict]] = {}
        for method in doc["methods"]:
            method = outer_methods.canonical(method)
            row = {}
            for col in cols:
                r = by.get((method, col))
                if r is None or r["final_loss"] is None:
                    continue
                base_r = by.get((baseline, col))
                delta = None
                if (method != baseline and base_r is not None
                        and base_r["final_loss"]):
                    delta = 100.0 * (r["final_loss"] - base_r["final_loss"]) \
                        / base_r["final_loss"]
                row[col] = {"loss": r["final_loss"], "delta_pct": delta,
                            "tokens": r["tokens"],
                            "final_time": r["final_time"],
                            "arrivals": r["arrivals"]}
            if row:
                rows[method] = row
        tables.append({"budget": b, "label": _budget_label(b),
                       "baseline": baseline, "columns": cols, "rows": rows})
    return tables


def _fmt_cell(c: Optional[Dict]) -> str:
    if c is None:
        return "—"
    if c["delta_pct"] is None:
        return f"{c['loss']:.4f} (baseline)"
    return f"{c['loss']:.4f} ({c['delta_pct']:+.1f}%)"


def _render_table(t: Dict) -> List[str]:
    lines = [f"## {t['label']}", ""]
    lines.append("| method | " + " | ".join(t["columns"]) + " |")
    lines.append("|---" * (len(t["columns"]) + 1) + "|")
    for method, row in t["rows"].items():
        cells = [_fmt_cell(row.get(col)) for col in t["columns"]]
        lines.append(f"| `{method}` | " + " | ".join(cells) + " |")
    lines.append("")
    lines.append(f"Final mean eval loss; %-delta vs `{t['baseline']}` "
                 "under the same budget (negative = better).")
    lines.append("")
    return lines


# ---------------------------------------------------------------------------
# Section-5 artifacts from the telemetry streams
# ---------------------------------------------------------------------------

def alignment_curves(doc: Dict) -> Dict[str, List[Dict]]:
    """method -> staleness->alignment curve, aggregated over every cell
    of that method that produced a telemetry stream."""
    per_method = defaultdict(list)
    for row in doc["cells"]:
        path = row.get("telemetry")
        if path and os.path.exists(path):
            rec = TelemetryRecorder.read_jsonl(path)
            per_method[row["method"]].extend(rec.arrivals())
    return {m: staleness_alignment(arr) for m, arr in per_method.items()}


def _render_alignment(curves: Dict[str, List[Dict]]) -> List[str]:
    lines = ["## Staleness -> update quality (Section 5)", ""]
    if not any(curves.values()):
        return lines + ["(no telemetry streams recorded)", ""]
    lines.append("| method | staleness | n | mean cos(D, m) | "
                 "mean corrected mass |")
    lines.append("|---|---|---|---|---|")
    for method, curve in sorted(curves.items()):
        for pt in curve:
            lines.append(
                f"| `{method}` | {pt['staleness']} | {pt['n']} | "
                f"{pt['mean_cos_align']:+.4f} | "
                f"{pt['mean_corrected_frac']:.4f} |")
    lines.append("")
    lines.append("cos(D, m): alignment of arriving pseudo-gradients with "
                 "the outer momentum; corrected mass: ||g−D||/||D|| — how "
                 "much the method's correction moved (from the fused-"
                 "kernel telemetry stats, see docs/telemetry.md).")
    lines.append("")
    return lines


def _render_per_language(doc: Dict) -> List[str]:
    lines = ["## Per-language final loss (non-IID fairness)", ""]
    rows = [r for r in doc["cells"] if r.get("per_lang")]
    if not rows:
        return lines + ["(no per-language evals)", ""]
    langs = sorted({lang for r in rows for lang in r["per_lang"]})
    lines.append("| cell | " + " | ".join(langs) + " | spread |")
    lines.append("|---" * (len(langs) + 2) + "|")
    for r in rows:
        per = r["per_lang"]
        vals = [f"{per[lg]:.4f}" if lg in per else "—" for lg in langs]
        spread = max(per.values()) - min(per.values())
        lines.append(f"| `{r['cell_id']}` | " + " | ".join(vals)
                     + f" | {spread:.4f} |")
    lines.append("")
    return lines


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def generate_report(spec, doc: Dict, sweep_dir: str) -> Sequence[str]:
    """Write tables.json + staleness_alignment.json + report.md; returns
    the written paths."""
    tables = comparison_tables(doc)
    curves = alignment_curves(doc)
    paths = []

    p = os.path.join(sweep_dir, "tables.json")
    with open(p, "w") as f:
        json.dump({"sweep": doc["sweep"], "tables": tables}, f, indent=1)
    paths.append(p)

    p = os.path.join(sweep_dir, "staleness_alignment.json")
    with open(p, "w") as f:
        json.dump({"sweep": doc["sweep"], "curves": curves}, f, indent=1)
    paths.append(p)

    lines = [f"# Sweep report: {doc['sweep']}", ""]
    if doc.get("description"):
        lines += [doc["description"], ""]
    lines += [f"{doc['n_cells']} cells = "
              f"{len(doc['methods'])} methods x "
              f"{len(doc['scenarios'])} scenarios x "
              f"{len(doc['budgets'])} budgets"
              f" ({doc['wall_seconds']:.0f}s wall).", ""]
    for t in tables:
        lines += _render_table(t)
    lines += _render_alignment(curves)
    lines += _render_per_language(doc)
    p = os.path.join(sweep_dir, "report.md")
    with open(p, "w") as f:
        f.write("\n".join(lines))
    paths.append(p)
    return paths
