"""Sweep executor: every grid cell through the cached run harness.

Built on ``benchmarks.common.run_cached_scenario`` (the same cache the
paper-reproduction benchmarks use, so a sweep rerun after an interrupted
grid only recomputes the missing cells), with the cell's ``Budget`` as
the stopping rule and a per-cell telemetry JSONL stream.

Layout under ``<out_dir>/<spec.name>/``:

  results.json                     cell descriptors + per-cell summaries
  telemetry/<cell_id>.jsonl        per-arrival update-quality streams
  report.md, tables.json,
  staleness_alignment.json         see ``repro.sweeps.report``
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

from repro.sweeps.spec import SweepCell, SweepSpec, get_sweep

SWEEP_DIR = os.environ.get("REPRO_SWEEPS", "results/sweeps")


def _run_cell(cell: SweepCell, spec: SweepSpec, sweep_dir: str,
              force: bool) -> Dict:
    # benchmarks/ ships alongside src/; the harness adds both to the path
    # (repo root for -m, src for the package) — fail loudly otherwise.
    try:
        from benchmarks.common import run_cached_scenario
    except ImportError as e:                     # pragma: no cover
        raise ImportError(
            "repro.sweeps needs the benchmarks/ harness on sys.path "
            "(run from the repo root)") from e
    telemetry_path = (os.path.join(sweep_dir, "telemetry",
                                   cell.cell_id + ".jsonl")
                      if spec.telemetry else None)
    res = run_cached_scenario(cell.cell_id, cell.scenario,
                              eval_every=spec.eval_every, force=force,
                              budget=cell.budget.to_budget(),
                              telemetry_path=telemetry_path)
    return {
        **cell.to_dict(),
        "final_loss": res.get("final_loss"),
        "per_lang": res.get("per_lang"),
        "tokens": res.get("tokens"),
        "final_time": res.get("final_time"),
        "arrivals": len(res.get("staleness", [])),
        "n_dropped": res.get("n_dropped", 0),
        "telemetry": res.get("telemetry"),
        "telemetry_summary": res.get("telemetry_summary"),
        "wall_seconds": res.get("wall_seconds"),
    }


def run_sweep(spec, out_dir: Optional[str] = None, force: bool = False,
              report: bool = True, verbose: bool = True) -> Dict:
    """Execute a sweep (by ``SweepSpec`` or registered name); returns the
    results document and writes the report artifacts."""
    if isinstance(spec, str):
        spec = get_sweep(spec)
    sweep_dir = os.path.join(out_dir or SWEEP_DIR, spec.name)
    os.makedirs(sweep_dir, exist_ok=True)
    cells = spec.cells()
    rows: List[Dict] = []
    t0 = time.time()
    for i, cell in enumerate(cells):
        if verbose:
            print(f"[{i + 1}/{len(cells)}] {cell.cell_id}", flush=True)
        rows.append(_run_cell(cell, spec, sweep_dir, force))
    doc = {
        "sweep": spec.name,
        "description": spec.description,
        "baseline": spec.baseline_method,
        "methods": list(spec.methods),
        "scenarios": list(spec.scenarios),
        "budgets": [{"kind": b.kind, "amount": b.amount}
                    for b in spec.budgets],
        "n_cells": len(cells),
        "cells": rows,
        "wall_seconds": time.time() - t0,
    }
    path = os.path.join(sweep_dir, "results.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    if verbose:
        print(f"# results -> {path}")
    if report:
        from repro.sweeps.report import generate_report
        for p in generate_report(spec, doc, sweep_dir):
            if verbose:
                print(f"# report  -> {p}")
    return doc
