"""Declarative scenario specs: one frozen dataclass is the single source
of truth for how a run is constructed.

Before this layer, the launcher (``repro.launch.train``), the benchmark
harness (``benchmarks.common``), and individual tests each spoke their own
flag dialect for the same grid of paper scenarios — worker speed profiles,
non-IID language mixtures, staleness regimes, compression, crash/elastic
membership. A ``Scenario`` names one cell of that grid; ``materialize()``
compiles it into the engine/runtime/data keyword sets every entry point
consumes, and ``build()`` hands back a ready engine.

The named instances live in ``repro.scenarios.registry``; golden-trace
recording/verification on top of them in ``repro.scenarios.trace``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.async_engine.faults import FaultSpec
from repro.configs import get_config, reduced
from repro.configs.base import (
    HeLoCoConfig, InnerOptConfig, OuterOptConfig, RunConfig,
)
from repro.core import methods as outer_methods

# Paper Table 3 (Appendix A.5): per-method outer-optimizer defaults.
# A VIEW over the ``repro.core.methods`` registry — the single source of
# truth; the old hand-maintained dict (and the METHOD_PRESETS alias table
# it dragged along) are gone. Benchmark-dialect names ("async-heloco")
# resolve through ``outer_methods.canonical``.
METHOD_TABLE: Dict[str, Dict[str, Any]] = outer_methods.method_table()

ENGINES = ("sim", "wallclock")
MODES = ("deterministic", "free")
TRANSPORTS = ("inproc", "socket")
TOPOLOGIES = ("hub", "ring", "gossip")

#: committed straggler/churn trace files live here (docs/scale.md)
TRACE_DIR = "results/traces"

_TRACE_CACHE: Dict[str, Dict[str, Any]] = {}


def load_pace_trace(name: str) -> Dict[str, Any]:
    """Load a committed worker-speed/churn trace file. ``name`` resolves
    relative to ``TRACE_DIR`` unless it is a path that exists as given.
    Format (JSON): {"paces": [sec/step, ...] cycled to n_workers,
    "failures": [[time, wid, restart_delay], ...],
    "elastic": [[time, action, wid, pace, lang], ...]}."""
    import json
    import os
    path = name if os.path.exists(name) else os.path.join(TRACE_DIR, name)
    cached = _TRACE_CACHE.get(path)
    if cached is None:
        with open(path) as f:
            cached = json.load(f)
        _TRACE_CACHE[path] = cached
    return cached


@dataclass(frozen=True)
class FailureSpec:
    """A worker crash (in-flight round lost) with a scheduled rejoin."""
    time: float
    wid: int
    restart_delay: float = 60.0


@dataclass(frozen=True)
class ElasticSpec:
    """Elastic membership change: a worker joins or leaves at `time`."""
    time: float
    action: str                      # "join" | "leave"
    wid: int
    pace: float = 1.0
    lang: Optional[int] = None

    def __post_init__(self):
        assert self.action in ("join", "leave"), self.action


@dataclass(frozen=True)
class Materialized:
    """What ``Scenario.materialize()`` compiles a spec into: the exact
    keyword sets the engine factory consumes."""
    run_cfg: RunConfig
    engine: str
    engine_kw: Dict[str, Any]
    failures: List[Any]              # engine FailureEvent list
    elastic: List[Any]               # engine ElasticEvent list


@dataclass(frozen=True)
class Scenario:
    """One named cell of the paper's scenario grid."""
    name: str
    description: str = ""
    # -- model -------------------------------------------------------------
    arch: str = "tinygpt-15m"
    smoke: bool = True               # reduced() CPU-friendly variant
    # -- engine ------------------------------------------------------------
    engine: str = "sim"              # "sim" | "wallclock"
    mode: str = "deterministic"      # wallclock commit order
    pace_scale: float = 0.0          # wallclock free-running throttle
    transport: str = "inproc"        # wallclock backend: "inproc" | "socket"
    topology: str = "hub"            # "hub" | "ring" | "gossip" (NoLoCo)
    # -- schedule / heterogeneity -------------------------------------------
    n_workers: int = 4
    worker_paces: Tuple[float, ...] = (1.0,)     # cycled to n_workers
    inner_steps: int = 2
    outer_steps: int = 12
    batch_size: int = 2
    seq_len: int = 16
    # batched-arrival fast path (docs/scale.md): coalesce up to this many
    # same-tick arrivals into one fused multi-apply commit (1 = exact
    # sequential semantics; every pre-existing golden).
    commit_batch: int = 1
    # hogwild-style ramp-up (arXiv 2010.14763): per-round mini-batch grows
    # linearly from batch_size to this value over the run (None = constant).
    batch_rampup: Optional[int] = None
    # committed straggler/churn trace file (docs/scale.md): worker paces
    # plus failure/elastic schedules replayed from results/traces/<file>.
    pace_trace: str = ""
    non_iid: bool = True
    mixture_alpha: Optional[float] = None        # Dirichlet language mixture
    shard_assignment: str = "fixed"              # "fixed" | "flexible"
    dylu: bool = False
    # -- outer optimizer -----------------------------------------------------
    method: str = "heloco"
    outer_lr: Optional[float] = None             # None -> METHOD_TABLE default
    momentum: Optional[float] = None
    weight_factor: Optional[str] = None
    lookahead_init: Optional[bool] = None
    heloco: HeLoCoConfig = field(default_factory=HeLoCoConfig)
    compression: str = "none"                    # none | int8 | topk
    topk_ratio: float = 0.1
    error_feedback: bool = True
    drop_stale_after: Optional[int] = None
    delay_weighting: bool = False
    # -- inner optimizer -----------------------------------------------------
    inner_lr: float = 3e-3
    # -- failure / elastic schedules ------------------------------------------
    failures: Tuple[FailureSpec, ...] = ()
    elastic: Tuple[ElasticSpec, ...] = ()
    # -- unreliable delivery (chaos scenarios; wallclock engine only) ---------
    faults: Optional[FaultSpec] = None
    # -- eval / reproducibility ----------------------------------------------
    eval_every: int = 0              # 0 -> outer_steps // 4 (min 1)
    eval_batch: int = 8
    seed: int = 0
    # -- observability (observation only: never changes run behavior) --------
    telemetry_every: int = 0         # emit a "runtime" telemetry health
    # snapshot every N commits when a TelemetryRecorder is attached
    # (0 = off; docs/observability.md)

    def __post_init__(self):
        assert self.engine in ENGINES, self.engine
        assert self.mode in MODES, self.mode
        assert self.transport in TRANSPORTS, self.transport
        assert self.topology in TOPOLOGIES, self.topology
        if self.transport == "socket":
            # the socket backend is a wallclock runtime feature: the
            # simulator has no processes to rendezvous with
            assert self.engine == "wallclock", \
                f"transport='socket' needs engine='wallclock', " \
                f"got {self.engine!r}"
        # canonicalize benchmark-dialect aliases ("async-heloco" -> heloco);
        # raises KeyError for unknown methods
        object.__setattr__(self, "method",
                           outer_methods.canonical(self.method))
        assert self.n_workers >= 1 and self.worker_paces
        if self.topology != "hub":
            # decentralized mixing has no barrier to synchronize on
            assert not outer_methods.get(self.method).sync, \
                f"topology={self.topology!r} needs an async method, " \
                f"got {self.method!r}"
        if self.faults is not None:
            # the simulator has no transport to inject faults into, and
            # partition windows live on the free-running virtual clock
            assert self.engine == "wallclock", \
                f"faults need engine='wallclock', got {self.engine!r}"
            assert not self.faults.partitions or self.mode == "free", \
                "partition windows require mode='free'"

    # ------------------------------------------------------------ properties
    @property
    def exact(self) -> bool:
        """Whether a golden trace of this scenario is fp32-exact
        reproducible (sim and deterministic wallclock) or only
        tolerance-banded (free-running wallclock)."""
        return self.engine == "sim" or self.mode == "deterministic"

    @property
    def paces(self) -> Tuple[float, ...]:
        base = self.worker_paces
        if self.pace_trace:
            base = tuple(load_pace_trace(self.pace_trace)["paces"]) or base
        return tuple(base[i % len(base)]
                     for i in range(self.n_workers))

    @property
    def eval_cadence(self) -> int:
        return self.eval_every or max(self.outer_steps // 4, 1)

    # --------------------------------------------------------------- configs
    def model_config(self):
        model = get_config(self.arch)
        return reduced(model) if self.smoke else model

    def outer_config(self) -> OuterOptConfig:
        preset = outer_methods.get(self.method)
        return OuterOptConfig(
            method=self.method,
            outer_lr=(self.outer_lr if self.outer_lr is not None
                      else preset.outer_lr),
            momentum=(self.momentum if self.momentum is not None
                      else preset.momentum),
            weight_factor=self.weight_factor or preset.weight_factor,
            lookahead_init=(self.lookahead_init
                            if self.lookahead_init is not None
                            else preset.lookahead_init),
            heloco=self.heloco,
            compression=self.compression,
            topk_ratio=self.topk_ratio,
            error_feedback=self.error_feedback,
            drop_stale_after=self.drop_stale_after,
            delay_weighting=self.delay_weighting)

    def inner_config(self) -> InnerOptConfig:
        total = self.outer_steps * self.inner_steps
        return InnerOptConfig(lr=self.inner_lr,
                              warmup_steps=max(total // 20, 2),
                              total_steps=total)

    def run_config(self) -> RunConfig:
        return RunConfig(
            model=self.model_config(),
            inner=self.inner_config(),
            outer=self.outer_config(),
            n_workers=self.n_workers,
            inner_steps=self.inner_steps,
            outer_steps=self.outer_steps,
            batch_size=self.batch_size,
            seq_len=self.seq_len,
            worker_paces=self.paces,
            non_iid=self.non_iid,
            mixture_alpha=self.mixture_alpha,
            shard_assignment=self.shard_assignment,
            dylu=self.dylu,
            topology=self.topology,
            commit_batch=self.commit_batch,
            batch_rampup=self.batch_rampup,
            seed=self.seed)

    # ----------------------------------------------------------- materialize
    def materialize(self) -> Materialized:
        """Compile the spec into the engine/runtime kwargs every entry
        point (launcher, benchmarks, examples, tests) consumes."""
        from repro.async_engine.engine import ElasticEvent, FailureEvent
        engine_kw: Dict[str, Any] = {}
        if self.engine == "wallclock":
            engine_kw = dict(mode=self.mode, pace_scale=self.pace_scale)
            if self.faults is not None:
                engine_kw["faults"] = self.faults
            if self.transport != "inproc":
                engine_kw["transport"] = self.transport
        failures = [FailureEvent(time=f.time, wid=f.wid,
                                 restart_delay=f.restart_delay)
                    for f in self.failures]
        elastic = [ElasticEvent(time=e.time, action=e.action, wid=e.wid,
                                pace=e.pace, lang=e.lang)
                   for e in self.elastic]
        if self.pace_trace:
            # straggler/churn schedules replayed from the committed trace
            tr = load_pace_trace(self.pace_trace)
            failures += [FailureEvent(time=float(t), wid=int(w),
                                      restart_delay=float(d))
                         for t, w, d in tr.get("failures", [])]
            elastic += [ElasticEvent(time=float(t), action=str(a),
                                     wid=int(w), pace=float(p),
                                     lang=(None if l is None else int(l)))
                        for t, a, w, p, l in tr.get("elastic", [])]
        return Materialized(run_cfg=self.run_config(), engine=self.engine,
                            engine_kw=engine_kw, failures=failures,
                            elastic=elastic)

    def build(self):
        """Ready-to-run engine for this scenario."""
        from repro.async_engine.engine import make_engine
        m = self.materialize()
        return make_engine(m.run_cfg, m.engine, failures=m.failures,
                           elastic=m.elastic, **m.engine_kw)

    # ------------------------------------------------------------- overrides
    def overridden(self, **kw) -> "Scenario":
        """Derived scenario (dataclasses.replace with nested spec support)."""
        if "failures" in kw:
            kw["failures"] = tuple(kw["failures"])
        if "elastic" in kw:
            kw["elastic"] = tuple(kw["elastic"])
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------ json
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        # fault-free scenario dicts are identical to their pre-faults form
        # (recorded goldens compare the scenario dict byte-for-byte);
        # same discipline for the observability cadence knob
        if self.faults is None:
            d.pop("faults")
        else:
            d["faults"] = self.faults.to_dict()
        if not self.telemetry_every:
            d.pop("telemetry_every")
        # new axes pop at their defaults so every pre-existing golden's
        # scenario dict stays byte-identical
        if self.transport == "inproc":
            d.pop("transport")
        if self.topology == "hub":
            d.pop("topology")
        if self.commit_batch == 1:
            d.pop("commit_batch")
        if self.batch_rampup is None:
            d.pop("batch_rampup")
        if not self.pace_trace:
            d.pop("pace_trace")
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Scenario":
        d = dict(d)
        d["worker_paces"] = tuple(d.get("worker_paces", (1.0,)))
        d["heloco"] = HeLoCoConfig(**d.get("heloco", {}))
        d["failures"] = tuple(FailureSpec(**f) for f in d.get("failures", ()))
        d["elastic"] = tuple(ElasticSpec(**e) for e in d.get("elastic", ()))
        if d.get("faults") is not None:
            d["faults"] = FaultSpec.from_dict(d["faults"])
        return cls(**d)
