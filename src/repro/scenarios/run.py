"""Scenario runner CLI: list, record, and verify golden traces.

    PYTHONPATH=src python -m repro.scenarios.run list
    PYTHONPATH=src python -m repro.scenarios.run record --all
    PYTHONPATH=src python -m repro.scenarios.run verify --all
    PYTHONPATH=src python -m repro.scenarios.run verify --engine-filter sim
    PYTHONPATH=src python -m repro.scenarios.run verify --all --cross

``verify`` exits non-zero on any mismatch and writes a machine-readable
diff per failing scenario under ``--diff-dir`` (uploaded as a CI
artifact). ``--cross`` additionally replays every sim scenario on the
deterministic wall-clock engine and demands the identical trace.
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from repro.scenarios import registry, trace
from repro.scenarios.spec import Scenario


def _select(args) -> List[Scenario]:
    if args.all or not args.names:
        scns = registry.all_scenarios()
    else:
        scns = [registry.get_scenario(n) for n in args.names]
    if args.engine_filter:
        scns = [s for s in scns if s.engine == args.engine_filter]
    return scns


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.scenarios.run")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="registered scenarios")
    p_list.add_argument("--engine-filter", choices=["sim", "wallclock"])

    for name, hlp in (("record", "(re)write golden traces"),
                      ("verify", "re-run + compare against goldens")):
        p = sub.add_parser(name, help=hlp)
        p.add_argument("names", nargs="*", help="scenario names "
                       "(default: all)")
        p.add_argument("--all", action="store_true")
        p.add_argument("--dir", default=trace.GOLDEN_DIR,
                       help="golden trace directory")
        p.add_argument("--engine-filter", choices=["sim", "wallclock"])
        if name == "verify":
            p.add_argument("--cross", action="store_true",
                           help="also replay sim scenarios on the "
                                "deterministic wall-clock engine")
            p.add_argument("--cross-only", action="store_true",
                           help="run ONLY the cross-engine replays (skips "
                                "the plain verification the scenarios-sim "
                                "CI lane already runs)")
            p.add_argument("--diff-dir", default="results/golden_diffs",
                           help="where failure diffs are written")
    args = ap.parse_args(argv)

    if args.cmd == "list":
        scns = registry.all_scenarios()
        if args.engine_filter:
            scns = [s for s in scns if s.engine == args.engine_filter]
        for s in scns:
            exact = "exact" if s.exact else "banded"
            print(f"{s.name:24s} engine={s.engine}/{s.mode:13s} "
                  f"[{exact}]  {s.description}")
        return 0

    scns = _select(args)
    if not scns:
        print("no scenarios selected", file=sys.stderr)
        return 2

    if args.cmd == "record":
        for s in scns:
            path = trace.record(s, args.dir)
            print(f"recorded {s.name} -> {path}")
        return 0

    def checks_for(s) -> List[bool]:
        cross = ([True] if (args.cross or args.cross_only)
                 and s.engine == "sim" else [])
        return ([] if args.cross_only else [False]) + cross

    failed = total = 0
    for s in scns:
        for cross in checks_for(s):
            total += 1
            res = trace.verify(s, args.dir, cross_engine=cross)
            print(res.report())
            if not res.ok:
                failed += 1
                diff = trace.write_diff(res, args.diff_dir)
                print(f"    diff -> {diff}")
    if not total:
        print("no applicable golden-trace checks for this selection "
              "(--cross-only applies to sim scenarios)", file=sys.stderr)
        return 2
    print(f"\n{total - failed}/{total} golden-trace checks passed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
