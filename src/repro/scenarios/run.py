"""Scenario runner CLI: list, record, and verify golden traces.

    PYTHONPATH=src python -m repro.scenarios.run list
    PYTHONPATH=src python -m repro.scenarios.run record --all
    PYTHONPATH=src python -m repro.scenarios.run verify --all
    PYTHONPATH=src python -m repro.scenarios.run verify --engine-filter sim
    PYTHONPATH=src python -m repro.scenarios.run verify --all --cross
    PYTHONPATH=src python -m repro.scenarios.run verify chaos_lossy \
        --transport socket

``verify`` exits non-zero on any mismatch and writes a machine-readable
diff per failing scenario under ``--diff-dir`` (uploaded as a CI
artifact). ``--cross`` additionally replays every sim scenario on the
deterministic wall-clock engine and demands the identical trace;
``--transport socket`` reruns wallclock scenarios (and cross-engine
replays) over the multi-process socket backend against the UNMODIFIED
committed goldens — the backend must not change the trace.
``--obs`` reruns with the full observability stack on (live-sink
telemetry + span tracing + cross-process collection on socket) against
the same goldens — observation must not change the trace either
(docs/observability.md, byte-identity contract).
"""
from __future__ import annotations

import argparse
import sys
from typing import List

from repro.scenarios import registry, trace
from repro.scenarios.spec import Scenario


def _select(args) -> List[Scenario]:
    if args.all or not args.names:
        scns = registry.all_scenarios()
    else:
        scns = [registry.get_scenario(n) for n in args.names]
    if args.engine_filter:
        scns = [s for s in scns if s.engine == args.engine_filter]
    if getattr(args, "transport_filter", None):
        scns = [s for s in scns if s.transport == args.transport_filter]
    return scns


def _grouped(scns: List[Scenario]) -> List[Scenario]:
    """Group by execution substrate: engine, then transport, then mode —
    the order the CI lanes slice the registry in."""
    return sorted(scns, key=lambda s: (s.engine, s.transport, s.mode,
                                       s.name))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.scenarios.run")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="registered scenarios")
    p_list.add_argument("--engine-filter", choices=["sim", "wallclock"])
    p_list.add_argument("--transport-filter", choices=["inproc", "socket"])

    for name, hlp in (("record", "(re)write golden traces"),
                      ("verify", "re-run + compare against goldens")):
        p = sub.add_parser(name, help=hlp)
        p.add_argument("names", nargs="*", help="scenario names "
                       "(default: all)")
        p.add_argument("--all", action="store_true")
        p.add_argument("--dir", default=trace.GOLDEN_DIR,
                       help="golden trace directory")
        p.add_argument("--engine-filter", choices=["sim", "wallclock"])
        p.add_argument("--transport-filter", choices=["inproc", "socket"],
                       help="select only scenarios registered on this "
                            "transport")
        if name == "verify":
            p.add_argument("--cross", action="store_true",
                           help="also replay sim scenarios on the "
                                "deterministic wall-clock engine")
            p.add_argument("--cross-only", action="store_true",
                           help="run ONLY the cross-engine replays (skips "
                                "the plain verification the scenarios-sim "
                                "CI lane already runs)")
            p.add_argument("--transport", choices=["socket"],
                           help="rerun over this wallclock backend against "
                                "the unmodified committed goldens")
            p.add_argument("--obs", action="store_true",
                           help="rerun with the FULL observability stack "
                                "on (live-sink telemetry + span tracing; "
                                "cross-process collection on the socket "
                                "transport) — observation must not "
                                "perturb the golden trace")
            p.add_argument("--diff-dir", default="results/golden_diffs",
                           help="where failure diffs are written")
    args = ap.parse_args(argv)

    if args.cmd == "list":
        scns = registry.all_scenarios()
        if args.engine_filter:
            scns = [s for s in scns if s.engine == args.engine_filter]
        if args.transport_filter:
            scns = [s for s in scns if s.transport == args.transport_filter]
        group = None
        for s in _grouped(scns):
            key = (s.engine, s.transport)
            if key != group:
                group = key
                print(f"-- engine={s.engine} transport={s.transport} --")
            exact = "exact" if s.exact else "banded"
            topo = "" if s.topology == "hub" else f" topo={s.topology}"
            print(f"  {s.name:24s} {s.mode:13s} [{exact}]{topo}  "
                  f"{s.description}")
        print(f"\n{len(scns)} scenarios")
        return 0

    scns = _grouped(_select(args))
    if not scns:
        print("no scenarios selected", file=sys.stderr)
        return 2

    if args.cmd == "record":
        for s in scns:
            path = trace.record(s, args.dir)
            print(f"recorded {s.name} -> {path}")
        return 0

    def checks_for(s) -> List[bool]:
        cross = ([True] if (args.cross or args.cross_only)
                 and s.engine == "sim" else [])
        return ([] if args.cross_only else [False]) + cross

    transport = args.transport
    failed = total = skipped = 0
    for s in scns:
        for cross in checks_for(s):
            # a transport override reruns wallclock scenarios on the
            # other backend; sim scenarios only via their cross replay
            tr = transport if (cross or s.engine == "wallclock") else None
            if transport and tr is None:
                skipped += 1
                continue
            total += 1
            res = trace.verify(s, args.dir, cross_engine=cross,
                               transport=tr, obs=args.obs)
            print(res.report())
            if not res.ok:
                failed += 1
                diff = trace.write_diff(res, args.diff_dir)
                print(f"    diff -> {diff}")
    if skipped:
        print(f"({skipped} sim-only checks skipped under "
              f"--transport {transport}; use --cross for those)")
    if not total:
        print("no applicable golden-trace checks for this selection "
              "(--cross-only applies to sim scenarios)", file=sys.stderr)
        return 2
    print(f"\n{total - failed}/{total} golden-trace checks passed")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
