"""Declarative scenario layer: specs, named registry, golden traces.

    from repro.scenarios import Scenario, get_scenario, registry, trace

    eng = get_scenario("paper_hetero_severe").build()
    hist = eng.run()

See docs/scenarios.md for the spec schema and the golden-trace workflow.
"""
from repro.async_engine.faults import (       # noqa: F401
    FaultSpec, PartitionSpec,
)
from repro.scenarios.spec import (            # noqa: F401
    ElasticSpec, FailureSpec, Materialized, METHOD_TABLE, Scenario,
)
from repro.scenarios.registry import (        # noqa: F401
    all_scenarios, get_scenario, names, register,
)
from repro.scenarios import registry, trace   # noqa: F401
