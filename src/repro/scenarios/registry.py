"""Named scenario registry: the enumerable form of the paper's claim grid.

Every registered scenario has a committed golden trace under
``results/golden/<name>.json`` (see ``repro.scenarios.trace``); ``make
scenarios`` verifies all of them and the CI matrix gates on the result.

Axes covered (HeLoCo Secs. 4-5 + App. A.6; async Local-SGD grid of Liu
et al. 2024): worker speed profiles (1, 2, 6, 15), non-IID language
assignment and Dirichlet mixtures, staleness regimes (drop / delay
weighting), DyLU, int8 compression with error feedback, crash/rejoin,
elastic membership, flexible shard assignment, the synchronous barrier
baseline, the delayed-Nesterov and DC-ASGD outer-method baselines (sim +
wall-clock), both wall-clock commit orders, decentralized ring/gossip
topologies (docs/topologies.md), and the multi-process socket transport
(docs/runtime.md, "Process transport").
"""
from __future__ import annotations

from typing import Dict, List

from repro.async_engine.faults import FaultSpec, PartitionSpec
from repro.scenarios.spec import ElasticSpec, FailureSpec, Scenario

_REGISTRY: Dict[str, Scenario] = {}


def register(scn: Scenario) -> Scenario:
    if scn.name in _REGISTRY:
        raise ValueError(f"duplicate scenario name: {scn.name!r}")
    _REGISTRY[scn.name] = scn
    return scn


def get_scenario(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{', '.join(names())}") from None


def names() -> List[str]:
    return list(_REGISTRY)


def all_scenarios() -> List[Scenario]:
    return list(_REGISTRY.values())


# ---------------------------------------------------------------------------
# The registered grid. Tiny smoke-model budgets: each scenario is a full
# training run that must stay cheap enough to verify on every CI push.
# ---------------------------------------------------------------------------

register(Scenario(
    name="paper_hetero_severe",
    description="Severe device heterogeneity: the paper's (1, 2, 6, 15) "
                "pace profile, non-IID fixed shards, async HeLoCo.",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2))

register(Scenario(
    name="noniid_dirichlet",
    description="Dirichlet(0.3) per-worker language mixtures instead of "
                "one-shard-per-worker: the soft non-IID axis.",
    n_workers=5, worker_paces=(1.0, 1.0, 2.0, 6.0, 6.0),
    mixture_alpha=0.3, outer_steps=12, inner_steps=2, seed=1))

register(Scenario(
    name="crash_rejoin",
    description="Fault tolerance: worker 0 crashes mid-round at t=5 "
                "(in-flight round lost) and rejoins at t=15.",
    n_workers=3, worker_paces=(1.0, 2.0, 6.0),
    outer_steps=12, inner_steps=2,
    failures=(FailureSpec(time=5.0, wid=0, restart_delay=10.0),)))

register(Scenario(
    name="elastic_membership",
    description="Elastic membership: worker 7 joins at t=4, worker 2 "
                "leaves at t=20 (its in-flight round is discarded).",
    n_workers=3, worker_paces=(1.0, 2.0, 6.0),
    outer_steps=12, inner_steps=2,
    elastic=(ElasticSpec(time=4.0, action="join", wid=7, pace=1.0, lang=1),
             ElasticSpec(time=20.0, action="leave", wid=2))))

register(Scenario(
    name="int8_dylu",
    description="Communication efficiency: int8 pseudo-gradient "
                "compression with error feedback + Dynamic Local Updates.",
    n_workers=3, worker_paces=(1.0, 2.0, 6.0),
    outer_steps=8, inner_steps=4, dylu=True, compression="int8"))

register(Scenario(
    name="drop_stale",
    description="Staleness regime (App. A.6): arrivals with tau > 2 "
                "dropped (momentum-decay-only step), delay weighting on.",
    n_workers=4, worker_paces=(1.0, 1.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2,
    drop_stale_after=2, delay_weighting=True))

register(Scenario(
    name="flexible_shards",
    description="Flexible shard assignment: each round trains the "
                "least-served language (App. A.6).",
    n_workers=4, worker_paces=(1.0, 1.0, 2.0, 6.0),
    outer_steps=12, inner_steps=2, shard_assignment="flexible"))

register(Scenario(
    name="delayed_nesterov",
    description="Delayed-Nesterov baseline (Liu et al. 2024): buffered "
                "pseudo-gradients, momentum refresh every N arrivals.",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2, method="delayed_nesterov"))

register(Scenario(
    name="dcasgd",
    description="DC-ASGD-style delay compensation: stale pseudo-gradients "
                "Taylor-corrected along the momentum, scaled by tau.",
    n_workers=4, worker_paces=(1.0, 1.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2, method="dcasgd"))

register(Scenario(
    name="fedbuff",
    description="FedBuff-style buffered aggregation baseline: the server "
                "averages every K=4 arrivals into one outer step.",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2, method="fedbuff"))

register(Scenario(
    name="poly_stale",
    description="Polynomial staleness weighting baseline: pseudo-"
                "gradients damped by (1+tau)^-alpha before the outer "
                "step.",
    n_workers=4, worker_paces=(1.0, 1.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2, method="poly_stale"))

register(Scenario(
    name="sync_baseline",
    description="Synchronous DiLoCo/Nesterov barrier baseline: the "
                "slowest worker gates every round.",
    n_workers=3, worker_paces=(1.0, 2.0, 6.0),
    outer_steps=4, inner_steps=2, method="sync_nesterov"))

register(Scenario(
    name="wallclock_hetero",
    description="Deterministic wall-clock runtime (threaded workers, "
                "FIFO-forced commits): trace-identical to the simulator.",
    engine="wallclock", mode="deterministic",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=10, inner_steps=2))

register(Scenario(
    name="delayed_nesterov_wallclock",
    description="Delayed-Nesterov on the deterministic wall-clock "
                "runtime: the buffered schedule commits trace-identically "
                "to the simulator.",
    engine="wallclock", mode="deterministic", method="delayed_nesterov",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=10, inner_steps=2))

register(Scenario(
    name="fedbuff_wallclock",
    description="FedBuff buffered aggregation on the deterministic "
                "wall-clock runtime: the K-arrival boundary schedule "
                "commits trace-identically to the simulator.",
    engine="wallclock", mode="deterministic", method="fedbuff",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=10, inner_steps=2))

register(Scenario(
    name="dcasgd_wallclock",
    description="DC-ASGD delay compensation on the deterministic "
                "wall-clock runtime (threaded workers, FIFO-forced "
                "commits).",
    engine="wallclock", mode="deterministic", method="dcasgd",
    n_workers=4, worker_paces=(1.0, 1.0, 6.0, 15.0),
    outer_steps=10, inner_steps=2))

register(Scenario(
    name="wallclock_free",
    description="Free-running wall-clock runtime: true arrival order "
                "with pace-scaled throttling; tolerance-banded golden.",
    engine="wallclock", mode="free", pace_scale=0.02,
    n_workers=4, worker_paces=(1.0, 1.0, 2.0, 6.0),
    outer_steps=10, inner_steps=1))

# -- chaos: unreliable delivery (docs/faults.md) ----------------------------
# chaos_lossy / chaos_corrupt share wallclock_hetero's exact run config:
# with at-least-once retries and idempotent commit, a deterministic-mode
# run under drop/dup/reorder (or corruption) commits the IDENTICAL history
# — their golden param digests must equal wallclock_hetero's.

register(Scenario(
    name="chaos_lossy",
    description="wallclock_hetero under a lossy channel: 20% drop, 10% "
                "duplicate, 20% reorder, delays and lost acks — the "
                "delivery layer makes the committed history (and the "
                "final param digest) identical to the fault-free twin.",
    engine="wallclock", mode="deterministic",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=10, inner_steps=2,
    faults=FaultSpec(drop_p=0.2, dup_p=0.1, reorder_p=0.2,
                     delay_p=0.1, delay_s=0.01, ack_drop_p=0.05, seed=7)))

register(Scenario(
    name="chaos_corrupt",
    description="wallclock_hetero under payload corruption: 25% of frames "
                "arrive checksum-broken and are rejected (never folded "
                "into outer state); retries redeliver clean copies, so "
                "the digest still matches the fault-free twin.",
    engine="wallclock", mode="deterministic",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=10, inner_steps=2,
    faults=FaultSpec(corrupt_p=0.25, ack_drop_p=0.1, seed=11)))

# -- topology: decentralized NoLoCo-style mixing (docs/topologies.md) -------

register(Scenario(
    name="gossip_ring",
    description="Decentralized ring topology: each arrival applies a "
                "local Nesterov step on the worker's own replica and "
                "averages with the next worker in the ring — no hub, "
                "O(1) communication per round.",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2, method="nesterov", topology="ring"))

register(Scenario(
    name="gossip_random",
    description="Decentralized gossip topology: peer sampled by a "
                "deterministic hash of (seed, outer_step, wid) — the "
                "NoLoCo-style random pairwise average, exactly "
                "replayable across engines and process boundaries.",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=12, inner_steps=2, method="nesterov", topology="gossip"))

# -- transport: the multi-process socket backend ----------------------------

register(Scenario(
    name="socket_hetero",
    description="wallclock_hetero over the multi-process socket backend: "
                "real worker processes, socket rendezvous, length-"
                "prefixed Envelope frames — trace-identical to the "
                "threaded twin (and the simulator).",
    engine="wallclock", mode="deterministic", transport="socket",
    n_workers=4, worker_paces=(1.0, 2.0, 6.0, 15.0),
    outer_steps=10, inner_steps=2))

# -- scale: batched-arrival fast path (docs/scale.md) -----------------------
# Small-N golden cells for the O(10k)-worker machinery: the bench grid
# (benchmarks/bench_scale.py) exercises N in {64, 1k, 10k}; these keep the
# coalesced-commit semantics pinned under CI-sized budgets.

register(Scenario(
    name="hogwild_rampup",
    description="Hogwild-style batch ramp-up (arXiv 2010.14763): per-round "
                "mini-batch grows linearly 2->8 across the run while the "
                "server coalesces up to 4 same-tick arrivals per fused "
                "commit (commit_batch=4).",
    n_workers=8, worker_paces=(1.0, 1.0, 2.0, 6.0),
    outer_steps=12, inner_steps=2,
    commit_batch=4, batch_rampup=8))

register(Scenario(
    name="trace_paced",
    description="Worker speeds and churn replayed from a committed trace "
                "file (results/traces/straggler_n8.json): pace schedule, "
                "one crash/rejoin and one elastic join, committed through "
                "the batched fast path (commit_batch=4).",
    n_workers=8, outer_steps=12, inner_steps=2,
    commit_batch=4, pace_trace="straggler_n8.json"))

register(Scenario(
    name="chaos_partition",
    description="Free-running runtime with a network partition: worker 3 "
                "is black-holed from t=2 on the virtual clock, heartbeats "
                "stop, the liveness monitor routes it through the crash "
                "machinery, and the survivors finish the run "
                "(tolerance-banded golden).",
    engine="wallclock", mode="free", pace_scale=0.02,
    n_workers=4, worker_paces=(1.0, 1.0, 2.0, 6.0),
    outer_steps=10, inner_steps=1,
    faults=FaultSpec(drop_p=0.05, seed=13,
                     partitions=(PartitionSpec(start=2.0, end=1e9,
                                               wids=(3,)),),
                     heartbeat_interval=0.05, liveness_misses=3)))
