"""Golden-trace recording and verification for registered scenarios.

A trace is everything a scenario run promises to reproduce:

  - the arrival sequence ``(outer_step, wid, s_i, staleness, lang, rho,
    sim_time, dropped)`` — the scheduling semantics;
  - the eval-loss curve (mean + per-language) — the learning dynamics;
  - a SHA-256 digest of the final parameters (canonical leaf order,
    fp32 bytes) plus a per-leaf ``[sum, l2]`` fingerprint — the numerics.

``record()`` writes ``<dir>/<name>.json``; ``verify()`` re-runs the
scenario and compares. Comparison discipline follows the engine
contracts: fp32-EXACT for the simulator and the deterministic wall-clock
runtime (same jitted programs, same inputs, virtual-deadline commit
order), tolerance-BANDED for the free-running runtime (true arrival
order is scheduler-dependent). ``verify(cross_engine=True)`` additionally
replays a sim scenario on the deterministic wall-clock engine and demands
the identical trace — the determinism contract of docs/runtime.md as a
CI-gated artifact.

Exactness is a same-binary, same-machine statement: XLA CPU codegen may
vectorize differently across microarchitectures. ``REPRO_GOLDEN_RTOL``
loosens the numeric comparison for such environments (traces stay exact).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.scenarios.spec import Scenario

SCHEMA_VERSION = 1
GOLDEN_DIR = os.environ.get("REPRO_GOLDEN", "results/golden")

# Numeric slack for "exact" comparisons (0.0 = bitwise via JSON round-trip).
_RTOL = float(os.environ.get("REPRO_GOLDEN_RTOL", "0") or 0)

# Tolerance bands for free-running (non-exact) scenarios.
FREE_BANDS = {
    "final_mean_abs": 0.75,          # final eval mean loss, absolute
    "tokens_rel": 0.5,
    "comm_bytes_rel": 0.5,
    "staleness_mean_abs": 3.0,
}


# ---------------------------------------------------------------------------
# Canonical parameter digests
# ---------------------------------------------------------------------------

def _canonical_leaves(params) -> List[Any]:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return sorted(((jax.tree_util.keystr(path), leaf) for path, leaf in flat),
                  key=lambda kv: kv[0])

def param_digest(params) -> str:
    """SHA-256 over fp32 bytes of every leaf in canonical (path-sorted)
    order; shapes are part of the digest."""
    h = hashlib.sha256()
    for path, leaf in _canonical_leaves(params):
        arr = np.asarray(leaf, dtype=np.float32)
        h.update(path.encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def param_fingerprint(params) -> Dict[str, List[float]]:
    """Low-dimensional per-leaf [sum, l2] view — lets cross-engine checks
    compare numerics within fp32 tolerance where the digest is all-or-
    nothing."""
    out = {}
    for path, leaf in _canonical_leaves(params):
        arr = np.asarray(leaf, dtype=np.float64)
        out[path] = [float(arr.sum()), float(np.sqrt((arr ** 2).sum()))]
    return out


# ---------------------------------------------------------------------------
# Running a scenario into a trace document
# ---------------------------------------------------------------------------

def run_trace(scn: Scenario, telemetry=None,
              tracer=None) -> Dict[str, Any]:
    """Execute the scenario and collect its full replayable trace.

    telemetry: optional ``repro.telemetry.TelemetryRecorder`` — the
    telemetry-on arrival path is contract-bound to be byte-identical, so
    a trace recorded with telemetry must verify against the committed
    golden (asserted in tests/test_telemetry.py).
    tracer: optional ``repro.obs.spans.SpanTracer`` — same contract for
    span profiling; on the socket transport this also turns on the
    cross-process collection path (child obs frames), which must not
    perturb the trace either."""
    from repro.async_engine.engine import make_engine, make_eval_fn
    eng = make_engine(scn, telemetry=telemetry, tracer=tracer)
    hist = eng.run(eval_every=scn.eval_cadence,
                   eval_fn=make_eval_fn(eng, batch=scn.eval_batch))
    if ((telemetry is not None or tracer is not None)
            and hasattr(eng, "assert_child_reports")):
        # observability was requested over real worker processes: a child
        # that never shipped an obs frame means silent collection rot
        eng.assert_child_reports()
    arrivals = [[a["outer_step"], a["worker_id"],
                 a["outer_step"] - 1 - a["staleness"], a["staleness"],
                 a["lang"], a["rho"], a["sim_time"], bool(a["dropped"])]
                for a in hist.arrivals]
    params = eng.server.state.params
    return {
        "schema": SCHEMA_VERSION,
        "scenario": scn.to_dict(),
        "engine": scn.engine,
        "mode": scn.mode,
        "exact": scn.exact,
        "arrivals": arrivals,
        "evals": hist.evals,
        "tokens": int(hist.tokens),
        "comm_bytes": int(hist.comm_bytes),
        "final_time": float(hist.final_time),
        "param_digest": param_digest(params),
        "param_fingerprint": param_fingerprint(params),
    }


def golden_path(name: str, golden_dir: str = GOLDEN_DIR) -> str:
    return os.path.join(golden_dir, f"{name}.json")


def record(scn: Scenario, golden_dir: str = GOLDEN_DIR) -> str:
    os.makedirs(golden_dir, exist_ok=True)
    path = golden_path(scn.name, golden_dir)
    doc = run_trace(scn)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path


# ---------------------------------------------------------------------------
# Verification
# ---------------------------------------------------------------------------

@dataclass
class VerifyResult:
    name: str
    ok: bool
    failures: List[str] = field(default_factory=list)
    details: Dict[str, Any] = field(default_factory=dict)

    def report(self) -> str:
        status = "PASS" if self.ok else "FAIL"
        lines = [f"[{status}] {self.name}"]
        lines += [f"    - {f}" for f in self.failures]
        return "\n".join(lines)


def _close(a: float, b: float) -> bool:
    if _RTOL <= 0:
        return a == b
    return bool(np.isclose(a, b, rtol=_RTOL, atol=_RTOL))


def _cmp_arrivals(fails: List[str], got: List[List[Any]],
                  want: List[List[Any]]):
    if len(got) != len(want):
        fails.append(f"arrival count: got {len(got)}, golden {len(want)}")
        return
    labels = ("outer_step", "wid", "s_i", "staleness", "lang", "rho",
              "sim_time", "dropped")
    for i, (g, w) in enumerate(zip(got, want)):
        for lab, gv, wv in zip(labels, g, w):
            equal = (_close(gv, wv) if isinstance(wv, float) else gv == wv)
            if not equal:
                fails.append(f"arrival[{i}].{lab}: got {gv!r}, "
                             f"golden {wv!r}")
                if len(fails) > 12:
                    fails.append("... (diff truncated)")
                    return


def _cmp_evals(fails: List[str], got: List[Dict], want: List[Dict],
               close) -> None:
    """Eval-curve comparison under a float comparator: `_close` on the
    exact path, an fp32-tolerance isclose for cross-engine replays."""
    if len(got) != len(want):
        fails.append(f"eval count: got {len(got)}, golden {len(want)}")
        return
    for i, (g, w) in enumerate(zip(got, want)):
        if g["step"] != w["step"] or not close(g["mean"], w["mean"]):
            fails.append(f"eval[{i}]: got step={g['step']} "
                         f"mean={g['mean']!r}, golden step={w['step']} "
                         f"mean={w['mean']!r}")
            continue
        gp, wp = g.get("per_lang", {}), w.get("per_lang", {})
        if set(gp) != set(wp) or any(not close(gp[k], wp[k]) for k in wp):
            fails.append(f"eval[{i}].per_lang: got {gp!r}, golden {wp!r}")


def _close_f32(a: float, b: float, rtol: float = 1e-4,
               atol: float = 1e-4) -> bool:
    return bool(np.isclose(a, b, rtol=rtol, atol=atol))


def _verify_exact(fails: List[str], got: Dict, want: Dict,
                  require_digest: bool = True):
    _cmp_arrivals(fails, got["arrivals"], want["arrivals"])
    _cmp_evals(fails, got["evals"], want["evals"], _close)
    for key in ("tokens", "comm_bytes"):
        if got[key] != want[key]:
            fails.append(f"{key}: got {got[key]}, golden {want[key]}")
    if not _close(got["final_time"], want["final_time"]):
        fails.append(f"final_time: got {got['final_time']!r}, "
                     f"golden {want['final_time']!r}")
    if require_digest:
        if _RTOL <= 0 and got["param_digest"] != want["param_digest"]:
            fails.append(f"param_digest: got {got['param_digest'][:16]}..., "
                         f"golden {want['param_digest'][:16]}...")
        _cmp_fingerprint(fails, got["param_fingerprint"],
                         want["param_fingerprint"],
                         rtol=max(_RTOL, 0.0), atol=max(_RTOL, 1e-6),
                         exact=_RTOL <= 0)


def _cmp_fingerprint(fails: List[str], got: Dict, want: Dict,
                     rtol: float = 1e-5, atol: float = 1e-6,
                     exact: bool = False):
    if set(got) != set(want):
        fails.append(f"fingerprint leaves differ: "
                     f"{sorted(set(got) ^ set(want))[:4]}")
        return
    bad = []
    for path, wv in want.items():
        gv = got[path]
        if exact:
            ok = gv == wv
        else:
            ok = np.allclose(gv, wv, rtol=rtol, atol=atol)
        if not ok:
            bad.append((path, gv, wv))
    for path, gv, wv in bad[:4]:
        fails.append(f"fingerprint[{path}]: got {gv}, golden {wv}")
    if len(bad) > 4:
        fails.append(f"... {len(bad) - 4} more fingerprint mismatches")


def _verify_banded(fails: List[str], got: Dict, want: Dict,
                   bands: Dict[str, float]):
    if len(got["arrivals"]) != len(want["arrivals"]):
        fails.append(f"arrival count: got {len(got['arrivals'])}, "
                     f"golden {len(want['arrivals'])}")
    gm = got["evals"][-1]["mean"] if got["evals"] else float("nan")
    wm = want["evals"][-1]["mean"] if want["evals"] else float("nan")
    if not abs(gm - wm) <= bands["final_mean_abs"]:
        fails.append(f"final eval mean drifted: got {gm:.4f}, golden "
                     f"{wm:.4f} (band +-{bands['final_mean_abs']})")
    for key, band_key in (("tokens", "tokens_rel"),
                          ("comm_bytes", "comm_bytes_rel")):
        g, w = got[key], want[key]
        if w and abs(g - w) > bands[band_key] * w:
            fails.append(f"{key}: got {g}, golden {w} "
                         f"(rel band {bands[band_key]})")
    g_tau = float(np.mean([a[3] for a in got["arrivals"]]) if
                  got["arrivals"] else 0.0)
    w_tau = float(np.mean([a[3] for a in want["arrivals"]]) if
                  want["arrivals"] else 0.0)
    if abs(g_tau - w_tau) > bands["staleness_mean_abs"]:
        fails.append(f"mean staleness: got {g_tau:.2f}, golden {w_tau:.2f} "
                     f"(band +-{bands['staleness_mean_abs']})")


def verify(scn: Scenario, golden_dir: str = GOLDEN_DIR, *,
           cross_engine: bool = False,
           transport: Optional[str] = None,
           fresh: Optional[Dict[str, Any]] = None,
           obs: bool = False) -> VerifyResult:
    """Re-run `scn` and compare against its committed golden trace.

    ``cross_engine=True`` (sim scenarios only) replays the scenario on the
    deterministic wall-clock engine instead and demands the identical
    arrival trace + fp32-close numerics versus the *sim-recorded* golden.
    ``transport`` overrides the wallclock backend for the FRESH run only
    (e.g. "socket" replays the committed golden over real worker
    processes) — the golden's recorded spec is compared untouched, which
    is exactly the point: the backend must not change the trace.
    ``fresh`` injects a pre-computed trace document (testing hook).
    ``obs=True`` runs the fresh replay with the FULL observability stack
    on — live-sink telemetry, runtime records, span tracing (and, over
    the socket transport, cross-process collection) — and demands the
    same golden plus a well-formed Chrome trace: observation must never
    perturb the run (docs/observability.md byte-identity contract).
    """
    path = golden_path(scn.name, golden_dir)
    tags = ("[cross-engine wallclock]" if cross_engine else "",
            f"[transport={transport}]" if transport else "",
            "[obs]" if obs else "")
    res = VerifyResult(name=" ".join(x for x in (scn.name,) + tags if x),
                       ok=True)

    def _run(run_scn: Scenario) -> Dict[str, Any]:
        if not obs:
            return run_trace(run_scn)
        import tempfile
        from repro.obs.spans import SpanTracer, validate_chrome_trace
        from repro.telemetry import TelemetryRecorder
        tr = SpanTracer()
        with tempfile.TemporaryDirectory() as td:
            rec = TelemetryRecorder(sink=os.path.join(td, "live.jsonl"))
            try:
                got = run_trace(run_scn, telemetry=rec, tracer=tr)
            finally:
                rec.close()
        for p in validate_chrome_trace(tr.to_chrome())[:4]:
            res.failures.append(f"obs trace invalid: {p}")
        if len(tr) == 0:
            res.failures.append("obs stack produced no trace spans")
        return got
    if not os.path.exists(path):
        res.ok = False
        res.failures.append(f"missing golden trace {path} "
                            f"(run: python -m repro.scenarios.run record "
                            f"{scn.name})")
        return res
    with open(path) as f:
        want = json.load(f)
    if want.get("schema") != SCHEMA_VERSION:
        res.failures.append(f"golden schema {want.get('schema')} != "
                            f"{SCHEMA_VERSION}; re-record")
    spec_now = json.loads(json.dumps(scn.to_dict()))
    if want.get("scenario") != spec_now:
        res.failures.append("registered scenario spec changed since the "
                            "golden was recorded; re-record the golden")
    if res.failures:
        res.ok = False
        return res

    if cross_engine:
        if scn.engine != "sim":
            res.ok = False
            res.failures.append("cross-engine verify only applies to sim "
                                "scenarios")
            return res
        replay = scn.overridden(engine="wallclock", mode="deterministic",
                                transport=transport or scn.transport)
        got = fresh or _run(replay)
        _cmp_arrivals(res.failures, got["arrivals"], want["arrivals"])
        _cmp_evals(res.failures, got["evals"], want["evals"], _close_f32)
        for key in ("tokens", "comm_bytes"):
            if got[key] != want[key]:
                res.failures.append(f"{key}: got {got[key]}, "
                                    f"golden {want[key]}")
        _cmp_fingerprint(res.failures, got["param_fingerprint"],
                         want["param_fingerprint"])
    else:
        run_scn = scn
        if transport and transport != scn.transport:
            if scn.engine != "wallclock":
                res.ok = False
                res.failures.append(
                    "transport override on a sim scenario needs "
                    "cross_engine=True (the socket backend is a wallclock "
                    "runtime feature)")
                return res
            run_scn = scn.overridden(transport=transport)
        got = fresh or _run(run_scn)
        if scn.exact:
            _verify_exact(res.failures, got, want)
        else:
            _verify_banded(res.failures, got, want, FREE_BANDS)
    res.ok = not res.failures
    res.details = {"golden": path,
                   "got_digest": got.get("param_digest"),
                   "want_digest": want.get("param_digest")}
    return res


def write_diff(res: VerifyResult, diff_dir: str) -> str:
    """Persist a machine-readable failure report (the CI artifact)."""
    os.makedirs(diff_dir, exist_ok=True)
    slug = re.sub(r"[^\w.-]+", "_", res.name).strip("_")
    path = os.path.join(diff_dir, f"{slug}.diff.json")
    with open(path, "w") as f:
        json.dump({"name": res.name, "ok": res.ok,
                   "failures": res.failures, "details": res.details},
                  f, indent=1)
    return path
