import sys

from repro.scenarios.run import main

sys.exit(main())
