"""Trace-span profiling for the async runtime: Chrome trace-event JSON.

A ``SpanTracer`` instruments the hot paths of both engines — worker
round compute, transport send/retry/backoff, server commit, eval — and
exports the spans as Chrome trace-event JSON (the ``traceEvents``
format), directly loadable in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``. Each span records the thread it ran on, so the
viewer shows the compute/commit overlap the wall-clock runtime claims:
worker rounds on ``heloco-worker-*`` rows overlapping server commits on
the main row.

Overhead discipline
-------------------

Tracing must never perturb the run it observes:

  - disabled (the default — engines hold the shared ``NULL_TRACER``
    singleton), a span is one attribute lookup + one call returning a
    shared no-op context manager: no allocation, no clock read;
  - enabled, a span is two ``perf_counter`` reads and one list append
    (GIL-atomic, so worker threads record without taking a lock); the
    JSON encode cost is paid once at ``write``, never during the run.

Nothing here touches jax — telemetry+tracing-on runs stay byte-identical
to the committed golden traces (asserted in tests/test_obs.py).

    tracer = SpanTracer()
    with tracer.span("worker_round", cat="compute", wid=3):
        ...
    tracer.write("results/obs/run.trace.json")
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = ["SpanTracer", "NullTracer", "NULL_TRACER",
           "validate_chrome_trace"]


class _Span:
    """One live span; created by ``SpanTracer.span`` and finished by the
    ``with`` exit. Re-entrant use is not supported (make a new one)."""
    __slots__ = ("_tr", "_name", "_cat", "_args", "_t0")

    def __init__(self, tr: "SpanTracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]]):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        tr = self._tr
        ident = threading.get_ident()
        if ident not in tr._names:               # first span on this thread
            tr._names[ident] = threading.current_thread().name
        tr._events.append((self._name, self._cat, "X",
                           self._t0 - tr._epoch, t1 - self._t0,
                           ident, self._args))
        return None


class _NullSpan:
    """Shared no-op context manager (the disabled-tracer fast path)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every call is a no-op. Engines default to the
    shared ``NULL_TRACER`` so instrumentation sites stay unconditional."""
    enabled = False

    def span(self, name: str, cat: str = "engine", **args) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "engine", **args) -> None:
        return None

    def write(self, path: str) -> str:          # pragma: no cover - guard
        raise RuntimeError("NULL_TRACER records nothing; build a "
                           "SpanTracer to export a trace")

    def __len__(self) -> int:
        return 0


NULL_TRACER = NullTracer()


class SpanTracer:
    """Collects spans from any thread; exports Chrome trace-event JSON."""
    enabled = True

    def __init__(self):
        self._epoch = time.perf_counter()
        # (name, cat, ph, start_s, dur_s, tid, args) tuples; list.append
        # is GIL-atomic so worker threads record lock-free
        self._events: List[tuple] = []
        # thread ident -> name, captured at record time (worker threads
        # are usually joined before export)
        self._names: Dict[int, str] = {}
        # high-water mark for export_new (cross-process shipping)
        self._exported = 0
        # pid -> {"name", "epoch_offset", "events", "names"} merged rows
        # from child processes (ingest_remote)
        self._foreign: Dict[int, Dict[str, Any]] = {}

    # ------------------------------------------------------------ recording
    def span(self, name: str, cat: str = "engine", **args) -> _Span:
        """Context manager timing one span (ph="X" complete event)."""
        return _Span(self, name, cat, args or None)

    def instant(self, name: str, cat: str = "engine", **args) -> None:
        """Zero-duration marker (ph="i"): retries, drops, state flips."""
        ident = threading.get_ident()
        if ident not in self._names:
            self._names[ident] = threading.current_thread().name
        self._events.append((name, cat, "i",
                             time.perf_counter() - self._epoch, 0.0,
                             ident, args or None))

    def __len__(self) -> int:
        return len(self._events) + sum(len(f["events"])
                                       for f in self._foreign.values())

    # --------------------------------------------- cross-process shipping
    def export_new(self) -> Dict[str, Any]:
        """Child side: the events recorded since the last export, as a
        picklable payload (list-of-lists + the thread-name map). Times
        stay in the child's clock — the parent re-bases them at ingest
        via the rendezvous ``epoch_offset`` (docs/observability.md,
        "Cross-process collection"). Incremental: each call ships only
        the new tail, so low-rate periodic frames stay small."""
        n = len(self._events)
        evs = [list(e) for e in self._events[self._exported:n]]
        self._exported = n
        return {"events": evs, "names": dict(self._names)}

    def ingest_remote(self, *, pid: int, epoch_offset: float,
                      events: List[list], names: Dict[int, str],
                      process_name: Optional[str] = None) -> None:
        """Parent side: merge a child's exported span batch as a
        distinct process row. ``epoch_offset`` maps a child-relative
        start time into the parent's ``perf_counter`` clock
        (``child_epoch + clock_offset``, both estimated at rendezvous);
        ``to_chrome`` then renders every process against the one parent
        epoch so the Perfetto timeline lines up."""
        entry = self._foreign.setdefault(
            int(pid), {"name": process_name or f"heloco-proc-{pid}",
                       "epoch_offset": float(epoch_offset),
                       "events": [], "names": {}})
        if process_name:
            entry["name"] = process_name
        entry["epoch_offset"] = float(epoch_offset)
        entry["events"].extend(tuple(e) for e in events)
        entry["names"].update({int(k): str(v) for k, v in names.items()})

    @property
    def pids(self) -> List[int]:
        """Process rows the merged trace will contain (0 = this one)."""
        return [0] + sorted(self._foreign)

    # -------------------------------------------------------------- export
    def to_chrome(self) -> Dict[str, Any]:
        """The trace-event JSON object format: ``{"traceEvents": [...]}``
        with per-thread ``thread_name`` metadata. Timestamps are
        microseconds since the tracer's creation."""
        # map python thread idents to small stable tids + their names
        # (record-time capture first; live threads fill any gaps)
        tids: Dict[int, int] = {}
        names: Dict[int, str] = dict(self._names)
        for th in threading.enumerate():
            names.setdefault(th.ident, th.name)
        events: List[Dict[str, Any]] = []
        for name, cat, ph, start, dur, ident, args in list(self._events):
            tid = tids.setdefault(ident, len(tids))
            ev: Dict[str, Any] = {
                "name": name, "cat": cat or "engine", "ph": ph,
                "ts": round(start * 1e6, 3), "pid": 0, "tid": tid,
            }
            if ph == "X":
                ev["dur"] = round(dur * 1e6, 3)
            if ph == "i":
                ev["s"] = "t"                    # thread-scoped instant
            if args:
                ev["args"] = args
            events.append(ev)
        meta = [{"name": "process_name", "ph": "M", "pid": 0,
                 "args": {"name": "heloco-runtime"}}]
        for ident, tid in sorted(tids.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid,
                         "args": {"name": names.get(ident,
                                                    f"thread-{tid}")}})
        # child-process rows: timestamps re-based into the parent epoch
        # via each child's rendezvous-estimated epoch_offset; clamped at
        # 0 so clock-estimate jitter can't render a negative ts
        for pid in sorted(self._foreign):
            entry = self._foreign[pid]
            base = entry["epoch_offset"] - self._epoch
            ctids: Dict[int, int] = {}
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "args": {"name": entry["name"]}})
            for name, cat, ph, start, dur, ident, args in entry["events"]:
                tid = ctids.setdefault(ident, len(ctids))
                ev = {"name": name, "cat": cat or "engine", "ph": ph,
                      "ts": round(max(0.0, start + base) * 1e6, 3),
                      "pid": pid, "tid": tid}
                if ph == "X":
                    ev["dur"] = round(dur * 1e6, 3)
                if ph == "i":
                    ev["s"] = "t"
                if args:
                    ev["args"] = args
                events.append(ev)
            for ident, tid in sorted(ctids.items(), key=lambda kv: kv[1]):
                meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                             "tid": tid,
                             "args": {"name": entry["names"].get(
                                 ident, f"thread-{tid}")}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_chrome(), f)
        os.replace(tmp, path)
        return path


# ---------------------------------------------------------------------------
# Validation (the `python -m repro.obs trace --validate` / CI gate)
# ---------------------------------------------------------------------------

_REQUIRED = {"name", "ph", "ts", "pid", "tid"}


def validate_chrome_trace(doc: Any) -> List[str]:
    """Structural well-formedness of a trace-event JSON document (what
    Perfetto's legacy JSON importer requires). Returns a list of
    problems; empty means loadable."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["not a trace-event JSON object (missing 'traceEvents')"]
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' must be a non-empty list"]
    n_spans = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event[{i}] is not an object")
            continue
        if ev.get("ph") == "M":
            continue                             # metadata: name/args only
        missing = _REQUIRED - set(ev)
        if missing:
            problems.append(f"event[{i}] missing keys {sorted(missing)}")
            continue
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            problems.append(f"event[{i}] bad ts {ev['ts']!r}")
        if ev["ph"] == "X":
            n_spans += 1
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                problems.append(f"event[{i}] complete event without a "
                                f"non-negative 'dur'")
        if len(problems) > 20:
            problems.append("... (truncated)")
            break
    if n_spans == 0:
        problems.append("no complete ('X') span events recorded")
    return problems
