"""Live terminal operator console over a telemetry JSONL stream.

``python -m repro.obs console <stream.jsonl>`` tails a stream that a
training run is writing *right now* (``TelemetryRecorder`` with a live
sink; ``--telemetry`` on the launcher) — or a recorded one — and renders
the paper's Section-5 quantities as they evolve:

  - arrival rate + totals (commits, drops, tokens, outer step);
  - the staleness histogram;
  - cos(D, m) and corrected-mass sparklines (the staleness→alignment
    story, live);
  - per-language validation loss (the data-heterogeneity fairness view);
  - per-worker liveness (arrivals seen, liveness/quarantine state from
    the fault records);
  - the runtime health panel (occupancy, compute parallelism, queue
    depth — the ``runtime`` record kind) and the chaos/delivery counters
    of docs/faults.md;
  - per-worker-process transport counters (frames/bytes each way,
    serialize/deserialize time, credit-wait stall, per-round compute —
    the ``transport`` record kind shipped over the socket control
    channel) and commit-buffer flush stats (depth, reason,
    fused-vs-sequential — the ``flush`` record kind).

Aggregation lives in ``repro.obs.metrics.MetricsAggregator`` — the web
dashboard (``repro.obs.web``) and headless snapshots read the exact same
rollup; this module only renders it as ANSI text.

Rendering is plain ANSI (sparklines are unicode blocks, colors optional
and off for non-TTYs), so it works over ssh and in CI logs. ``--once``
renders a single headless snapshot and exits — the CI smoke
(``make console-smoke``) and the golden-stream render test use it.

Follow mode rides ``repro.obs.tail.TailReader`` (partial-line,
truncation, and rotation robust) and decodes through
``repro.telemetry.schema.StreamDecoder`` — a stream written by a newer
schema keeps rendering, with the skipped-unknown tally surfaced in the
footer instead of silently thinning the dashboard.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Optional

from repro.obs.metrics import MetricsAggregator
from repro.obs.tail import TailReader, read_complete_lines

SPARK_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(vals, width: int = 48) -> str:
    vals = [float(v) for v in list(vals)[-width:]]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    top = len(SPARK_BLOCKS) - 1
    return "".join(SPARK_BLOCKS[int(round((v - lo) / span * top))]
                   for v in vals)


def hbar(n: float, n_max: float, width: int = 28) -> str:
    if n_max <= 0:
        return ""
    full = int(round(n / n_max * width))
    return "█" * max(full, 1 if n > 0 else 0)


class ConsoleState(MetricsAggregator):
    """Streaming aggregator: feed lines (or records), read panels.

    All aggregation lives in ``repro.obs.metrics.MetricsAggregator`` —
    the console, the web dashboard, and the headless JSON snapshot all
    read the same numbers; this subclass only keeps the historical
    console-facing name."""


# ---------------------------------------------------------------------------
# Rendering
# ---------------------------------------------------------------------------

class _C:
    """ANSI palette; every accessor collapses to "" when color is off."""
    def __init__(self, on: bool):
        self.on = on

    def _c(self, code: str) -> str:
        return f"\x1b[{code}m" if self.on else ""

    @property
    def dim(self): return self._c("2")
    @property
    def bold(self): return self._c("1")
    @property
    def green(self): return self._c("32")
    @property
    def red(self): return self._c("31")
    @property
    def yellow(self): return self._c("33")
    @property
    def cyan(self): return self._c("36")
    @property
    def off(self): return self._c("0")


def _rule(title: str, width: int, c: _C) -> str:
    pad = max(width - len(title) - 4, 0)
    return f"{c.dim}── {title} {'─' * pad}{c.off}"


def render(state: ConsoleState, width: int = 78, color: bool = False
           ) -> str:
    c = _C(color)
    L: List[str] = []
    m = state.meta
    if m is not None:
        target = f"/{m.outer_steps}" if m.outer_steps else ""
        L.append(f"{c.bold}HeLoCo operator console{c.off} — "
                 f"{m.scenario or 'ad-hoc run'} | method={m.method} "
                 f"engine={m.engine} | {m.n_workers} workers | "
                 f"seed {m.seed} | stream schema v{m.schema_version}")
    else:
        target = ""
        L.append(f"{c.bold}HeLoCo operator console{c.off} — "
                 f"(no meta record yet)")

    # ------------------------------------------------------------- arrivals
    L.append(_rule("arrivals", width, c))
    L.append(f"commits {state.n_arrivals} ({state.n_dropped} dropped) | "
             f"outer step {state.outer_step}{target} | "
             f"tokens {state.tokens_total:,} | "
             f"rate {state.arrival_rate():.2f}/s | "
             f"t={state.last_wall:.1f}s")
    if state.staleness:
        L.append(f"{c.dim}staleness histogram{c.off}")
        n_max = max(state.staleness.values())
        taus = sorted(state.staleness)
        for tau in taus[:8]:
            n = state.staleness[tau]
            L.append(f"  tau={tau:<3d} {hbar(n, n_max):<28} {n}")
        if len(taus) > 8:
            rest = sum(state.staleness[t] for t in taus[8:])
            L.append(f"  tau>{taus[7]:<3d} {hbar(rest, n_max):<28} {rest}")

    # ------------------------------------------------- update quality
    if state.cos:
        L.append(_rule("update quality (recent window)", width, c))
        cw = min(width - 30, 48)
        L.append(f"cos(D,m)   {sparkline(state.cos, cw)}  "
                 f"last={state.cos[-1]:+.3f} "
                 f"mean={sum(state.cos) / len(state.cos):+.3f}")
        L.append(f"corr mass  {sparkline(state.corr, cw)}  "
                 f"last={state.corr[-1]:.3f} "
                 f"mean={sum(state.corr) / len(state.corr):.3f}")

    # ------------------------------------------------------------- eval
    if state.last_eval is not None:
        ev = state.last_eval
        L.append(_rule("per-language loss", width, c))
        L.append(f"eval @step {ev.outer_step}: mean "
                 f"{c.bold}{ev.mean_loss:.4f}{c.off}")
        if ev.per_lang:
            losses = ev.per_lang
            lo, hi = min(losses.values()), max(losses.values())
            for lang in sorted(losses):
                v = losses[lang]
                # bar spans the min..max spread so fairness gaps pop
                frac = (v - lo) / (hi - lo) if hi > lo else 1.0
                L.append(f"  {lang:<10} {v:7.4f} "
                         f"{hbar(0.15 + 0.85 * frac, 1.0, 24)}")
            L.append(f"  {c.dim}spread (max-min): {hi - lo:.4f}{c.off}")

    # ------------------------------------------------------------ workers
    if state.workers:
        L.append(_rule("workers", width, c))
        for wid in sorted(state.workers):
            w = state.workers[wid]
            glyph, col = {"alive": ("●", c.green),
                          "dead": ("✖", c.red),
                          "quarantined": ("⛔", c.yellow)}.get(
                              w["state"], ("?", c.yellow))
            ago = ("" if w["last_wall"] is None else
                   f"  ({max(state.last_wall - w['last_wall'], 0.0):.1f}s "
                   f"since last)")
            last = ("-" if w["last_step"] is None
                    else str(w["last_step"]))
            L.append(f"  w{wid:<3d} {col}{glyph} {w['state']:<12}{c.off} "
                     f"arrivals={w['arrivals']:<5d} last step {last}{ago}")

    # ------------------------------------------------------------ runtime
    rt = state.last_runtime
    if rt is not None:
        L.append(_rule("runtime health", width, c))
        L.append(f"occupancy {rt.server_occupancy:.2f} | "
                 f"parallelism {rt.compute_parallelism:.2f} | "
                 f"queue depth {rt.queue_depth} | "
                 f"in-flight {rt.in_flight} | "
                 f"alive {rt.workers_alive}/{rt.workers_total}")
        if rt.liveness:
            live = " ".join(f"{k}={v}" for k, v
                            in sorted(rt.liveness.items()))
            L.append(f"{c.dim}liveness: {live}{c.off}")

    # --------------------------------------------- cross-process transport
    if state.transport:
        L.append(_rule("transport (per worker process)", width, c))
        for (wid, pid), t in sorted(state.transport.items()):
            mark = "" if t.final else f" {c.yellow}(live){c.off}"
            L.append(f"  w{wid:<3d} pid {pid:<7d} "
                     f"tx {t.frames_sent}f/{t.bytes_sent:,}B "
                     f"rx {t.frames_recv}f/{t.bytes_recv:,}B | "
                     f"ser {t.ser_s * 1e3:.1f}ms "
                     f"deser {t.deser_s * 1e3:.1f}ms | "
                     f"stall {t.credit_wait_s * 1e3:.1f}ms | "
                     f"rounds {t.rounds} "
                     f"compute {t.compute_s:.2f}s{mark}")
            if t.crc_rejects or t.retries:
                L.append(f"       {c.yellow}crc_rejects={t.crc_rejects} "
                         f"retries={t.retries}{c.off}")
        tot = state.transport_totals()
        L.append(f"{c.dim}total: tx {int(tot.get('frames_sent', 0))}f/"
                 f"{int(tot.get('bytes_sent', 0)):,}B "
                 f"rx {int(tot.get('frames_recv', 0))}f/"
                 f"{int(tot.get('bytes_recv', 0)):,}B "
                 f"compute {tot.get('compute_s', 0.0):.2f}s{c.off}")

    # ------------------------------------------------- commit-buffer flush
    if state.n_flushes:
        L.append(_rule("commit-buffer flushes", width, c))
        depths = list(state.flush_depths)
        reasons = " ".join(f"{k}={v}" for k, v
                           in sorted(state.flush_reasons.items()))
        L.append(f"flushes {state.n_flushes} | depth mean "
                 f"{sum(depths) / len(depths):.1f} max "
                 f"{state.flush_depth_max} | fused {state.flush_fused} "
                 f"sequential {state.flush_sequential}")
        L.append(f"{c.dim}reasons: {reasons}{c.off}")
        cw = min(width - 30, 48)
        if len(depths) >= 2:
            L.append(f"depth      {sparkline(depths, cw)}")

    # ---------------------------------------------------- chaos / delivery
    hot = {k: v for k, v in sorted(state.delivery.items()) if v}
    events = {k: v for k, v in sorted(state.fault_counts.items())
              if k != "summary"}
    if hot or events:
        L.append(_rule("delivery / chaos", width, c))
        if hot:
            L.append("counters: " + " ".join(f"{k}={int(v)}"
                                             for k, v in hot.items()))
        if events:
            L.append("events:   " + " ".join(f"{k}={v}"
                                             for k, v in events.items()))

    # ------------------------------------------------------------ drift
    drift = state.decoder.drift_report()
    if drift:
        L.append(_rule("schema drift", width, c))
        for d in drift:
            L.append(f"{c.yellow}! {d}{c.off}")
    return "\n".join(L)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs console",
        description="Terminal operator console over a telemetry JSONL "
                    "stream (live or recorded).")
    ap.add_argument("stream", help="telemetry JSONL path (may not exist "
                                   "yet in follow mode)")
    ap.add_argument("--once", action="store_true",
                    help="render one headless snapshot of the complete "
                         "lines currently in the file, then exit (CI)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="follow-mode refresh seconds (default 1.0)")
    ap.add_argument("--duration", type=float, default=0.0,
                    help="follow for N seconds then exit (0 = until ^C)")
    ap.add_argument("--window", type=int, default=256,
                    help="recent-window size for rate/sparklines")
    ap.add_argument("--width", type=int, default=78)
    ap.add_argument("--color", choices=["auto", "always", "never"],
                    default="auto")
    ap.add_argument("--strict", action="store_true",
                    help="fail loudly on schema drift instead of "
                         "counting/reporting it (same-version streams)")
    args = ap.parse_args(argv)
    use_color = (args.color == "always"
                 or (args.color == "auto" and not args.once
                     and sys.stdout.isatty()))
    state = ConsoleState(window=args.window, strict=args.strict)

    if args.once:
        for line in read_complete_lines(args.stream):
            state.add_line(line)
        try:
            print(render(state, width=args.width, color=use_color))
        except BrokenPipeError:                  # e.g. piped into `head`
            os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0

    reader = TailReader(args.stream, poll=min(args.interval, 0.25))
    t_end = (time.monotonic() + args.duration) if args.duration else None
    try:
        while True:
            for line in reader.read_available():
                state.add_line(line)
            frame = render(state, width=args.width, color=use_color)
            sys.stdout.write("\x1b[2J\x1b[H" + frame + "\n")
            sys.stdout.flush()
            if t_end is not None and time.monotonic() >= t_end:
                return 0
            time.sleep(args.interval)
    except KeyboardInterrupt:
        return 0
    except BrokenPipeError:
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        return 0
    finally:
        reader.close()


if __name__ == "__main__":
    raise SystemExit(main())
