"""``python -m repro.obs`` — observability command line.

Subcommands:

  console <stream.jsonl> [--once|--interval S]   live operator console
  web <stream.jsonl> [--port N|--snapshot]       web dashboard (stdlib
                                                 http.server + SSE) or
                                                 headless panels JSON
  trace --validate <trace.json>                  trace-event JSON check
  record <scenario> --out <stream.jsonl>         run a scenario with a
                                                 live telemetry sink
                                                 (regenerates the
                                                 committed golden
                                                 streams)

``console``, ``web``, and ``trace`` are pure-Python (no jax import);
``record`` lazily pulls in the engine stack.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import List, Optional

USAGE = __doc__


def _trace_main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs trace",
        description="Validate/summarize a Chrome trace-event JSON file.")
    ap.add_argument("path", help="trace JSON (from --trace / SpanTracer)")
    ap.add_argument("--validate", action="store_true",
                    help="exit non-zero if the file is not a well-formed "
                         "trace-event document")
    args = ap.parse_args(argv)
    from repro.obs.spans import validate_chrome_trace
    with open(args.path) as f:
        doc = json.load(f)
    problems = validate_chrome_trace(doc)
    events = doc.get("traceEvents", []) if isinstance(doc, dict) else []
    spans = [e for e in events
             if isinstance(e, dict) and e.get("ph") == "X"]
    by_name = defaultdict(lambda: [0, 0.0])
    for e in spans:
        agg = by_name[e.get("name", "?")]
        agg[0] += 1
        agg[1] += float(e.get("dur", 0.0))
    threads = {e.get("tid") for e in events
               if isinstance(e, dict) and e.get("ph") != "M"}
    print(f"{args.path}: {len(events)} events, {len(spans)} spans, "
          f"{len(threads)} threads")
    for name, (n, total_us) in sorted(by_name.items(),
                                      key=lambda kv: -kv[1][1]):
        print(f"  {name:<24} x{n:<6d} total {total_us / 1e3:9.2f} ms")
    if problems:
        print(f"INVALID: {len(problems)} problem(s)", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print("trace OK (loadable in Perfetto / chrome://tracing)")
    return 0


def _record_main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs record",
        description="Run a registered scenario with a live telemetry "
                    "sink (and optionally a span trace). This is how "
                    "the committed golden streams under "
                    "results/golden/streams/ are regenerated.")
    ap.add_argument("scenario", help="registry name, e.g. chaos_partition")
    ap.add_argument("--out", required=True, help="telemetry JSONL sink")
    ap.add_argument("--runtime-every", type=int, default=1,
                    help="runtime-health record cadence in commits "
                         "(default 1; 0 = off)")
    ap.add_argument("--trace", default=None,
                    help="also export a Chrome trace to this path")
    ap.add_argument("--transport", default=None,
                    help="override the scenario's wallclock backend "
                         "(e.g. socket: exercises the cross-process "
                         "collection path, so the stream gains "
                         "'transport' records)")
    ap.add_argument("--commit-batch", type=int, default=None,
                    help="override the scenario's commit-buffer size "
                         "(>1 makes the stream carry 'flush' records)")
    args = ap.parse_args(argv)

    # heavy imports only on this path
    import os
    from repro.async_engine.engine import make_engine, make_eval_fn
    from repro.obs.spans import SpanTracer
    from repro.scenarios import get_scenario
    from repro.telemetry import TelemetryRecorder

    scn = get_scenario(args.scenario)
    over = {}
    if args.transport is not None:
        over["transport"] = args.transport
    if args.commit_batch is not None:
        over["commit_batch"] = args.commit_batch
    if over:
        scn = scn.overridden(**over)
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    rec = TelemetryRecorder(sink=args.out)
    tracer = SpanTracer() if args.trace else None
    eng = make_engine(scn, telemetry=rec, tracer=tracer,
                      runtime_record_every=args.runtime_every)
    eng.run(eval_every=scn.eval_cadence,
            eval_fn=make_eval_fn(eng, batch=scn.eval_batch))
    # socket transport: fail loudly if any child never reported in over
    # the obs control channel (the collection path must not rot quietly)
    if hasattr(eng, "assert_child_reports"):
        eng.assert_child_reports()
    rec.close()
    print(f"wrote {args.out} ({len(rec)} records in final window)")
    if tracer is not None:
        tracer.write(args.trace)
        print(f"wrote {args.trace} ({len(tracer)} events)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(USAGE)
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "console":
        from repro.obs.console import main as console_main
        return console_main(rest)
    if cmd == "web":
        from repro.obs.web import main as web_main
        return web_main(rest)
    if cmd == "trace":
        return _trace_main(rest)
    if cmd == "record":
        return _record_main(rest)
    print(f"unknown subcommand {cmd!r}\n{USAGE}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
