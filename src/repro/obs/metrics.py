"""Shared rollup layer for every observability frontend.

One streaming aggregator — ``MetricsAggregator`` — feeds three views of
the same telemetry JSONL stream:

  - the terminal console (``repro.obs.console`` renders its panels);
  - the web dashboard + SSE feed (``repro.obs.web``);
  - headless JSON snapshots (``repro.obs web --snapshot`` and the
    launcher's ``--stats-json``-adjacent CI checks).

The aggregator ingests decoded ``repro.telemetry.schema`` records (any
drift is handled by the embedded ``StreamDecoder``) and exposes
``panels()``: a plain-JSON dict of named panels (arrival rate, staleness
histogram, update-quality window, per-language loss, worker liveness,
runtime health, delivery/chaos counters, cross-process transport
counters, commit-buffer flush stats, schema drift). Frontends format;
this module aggregates — there is exactly one code path computing the
numbers all three display (docs/observability.md, "Aggregation").
"""
from __future__ import annotations

from collections import Counter, deque
from typing import Any, Dict, List, Optional, Tuple

from repro.telemetry import schema

__all__ = ["MetricsAggregator"]


class MetricsAggregator:
    """Streaming aggregator: feed lines (or records), read ``panels()``.

    Windowed quantities (arrival rate, cos/corrected-mass sparklines)
    keep the last ``window`` samples; counters and histograms are
    whole-stream. Transport records are CUMULATIVE per (wid, pid) — the
    latest snapshot wins, and totals sum the latest snapshot of every
    incarnation seen.
    """

    def __init__(self, window: int = 256, strict: bool = False):
        self.decoder = schema.StreamDecoder(strict=strict)
        self.window = window
        self.meta: Optional[schema.RunMeta] = None
        # arrivals
        self.n_arrivals = 0
        self.n_dropped = 0
        self.tokens_total = 0
        self.outer_step = 0
        self.last_wall = 0.0
        self.staleness: Counter = Counter()
        self.cos = deque(maxlen=window)
        self.corr = deque(maxlen=window)
        self.recent_wall = deque(maxlen=window)   # commit stamps, for rate
        # per-worker view
        self.workers: Dict[int, Dict] = {}
        # evals / faults / runtime
        self.last_eval: Optional[schema.EvalMetrics] = None
        self.fault_counts: Counter = Counter()
        self.delivery: Dict[str, float] = {}
        self.last_runtime: Optional[schema.RuntimeMetrics] = None
        # cross-process transport: (wid, pid) -> latest cumulative record
        self.transport: Dict[Tuple[int, int], schema.TransportMetrics] = {}
        # commit-buffer flushes
        self.n_flushes = 0
        self.flush_reasons: Counter = Counter()
        self.flush_depths = deque(maxlen=window)
        self.flush_depth_max = 0
        self.flush_fused = 0
        self.flush_sequential = 0

    # ------------------------------------------------------------ ingestion
    def add_line(self, line: str) -> None:
        rec = self.decoder.decode(line)
        if rec is not None:
            self.add(rec)

    def _worker(self, wid: int) -> Dict:
        return self.workers.setdefault(
            wid, {"arrivals": 0, "last_step": None, "last_wall": None,
                  "state": "alive"})

    def add(self, rec: schema.Record) -> None:
        if isinstance(rec, schema.RunMeta):
            self.meta = rec
        elif isinstance(rec, schema.ArrivalMetrics):
            self.n_arrivals += 1
            self.n_dropped += bool(rec.dropped)
            self.tokens_total = max(self.tokens_total, rec.tokens_total)
            self.outer_step = max(self.outer_step, rec.outer_step)
            self.last_wall = max(self.last_wall, rec.wall_time)
            self.staleness[rec.staleness] += 1
            if rec.cos_align is not None and not rec.dropped:
                self.cos.append(rec.cos_align)
                self.corr.append(rec.corrected_frac or 0.0)
            self.recent_wall.append(rec.wall_time)
            w = self._worker(rec.worker_id)
            w["arrivals"] += 1
            w["last_step"] = rec.outer_step
            w["last_wall"] = rec.wall_time
            if w["state"] == "dead":          # an arrival proves liveness
                w["state"] = "alive"
        elif isinstance(rec, schema.EvalMetrics):
            self.last_eval = rec
            self.last_wall = max(self.last_wall, rec.wall_time)
        elif isinstance(rec, schema.FaultMetrics):
            self.fault_counts[rec.event] += 1
            self.last_wall = max(self.last_wall, rec.wall_time)
            if rec.event == "liveness_dead" and rec.wid >= 0:
                self._worker(rec.wid)["state"] = "dead"
            elif rec.event == "liveness_revive" and rec.wid >= 0:
                self._worker(rec.wid)["state"] = "alive"
            elif rec.event == "quarantine" and rec.wid >= 0:
                self._worker(rec.wid)["state"] = "quarantined"
            elif rec.event == "summary" and rec.detail:
                for k, v in rec.detail.items():
                    self.delivery[k] = max(self.delivery.get(k, 0.0), v)
        elif isinstance(rec, schema.RuntimeMetrics):
            self.last_runtime = rec
            self.last_wall = max(self.last_wall, rec.wall_time)
            for k, v in rec.delivery.items():
                self.delivery[k] = max(self.delivery.get(k, 0.0), v)
        elif isinstance(rec, schema.TransportMetrics):
            # cumulative per incarnation: latest snapshot wins
            self.transport[(rec.wid, rec.pid)] = rec
            self.last_wall = max(self.last_wall, rec.wall_time)
        elif isinstance(rec, schema.FlushMetrics):
            self.n_flushes += 1
            self.flush_reasons[rec.reason] += 1
            self.flush_depths.append(rec.depth)
            self.flush_depth_max = max(self.flush_depth_max, rec.depth)
            self.flush_fused += rec.fused
            self.flush_sequential += rec.sequential
            self.last_wall = max(self.last_wall, rec.wall_time)

    # -------------------------------------------------------------- derived
    def arrival_rate(self) -> float:
        """Commits/sec over the recent window (stream wall-time stamps,
        so replaying a recorded stream shows the recorded rate)."""
        w = list(self.recent_wall)
        if len(w) < 2 or w[-1] <= w[0]:
            return 0.0
        return (len(w) - 1) / (w[-1] - w[0])

    def transport_totals(self) -> Dict[str, float]:
        """Sum the latest cumulative snapshot of every (wid, pid)."""
        tot: Dict[str, float] = {}
        for rec in self.transport.values():
            for k in ("frames_sent", "frames_recv", "bytes_sent",
                      "bytes_recv", "ser_s", "deser_s", "crc_rejects",
                      "retries", "credit_wait_s", "rounds", "compute_s"):
                tot[k] = tot.get(k, 0) + getattr(rec, k)
        return tot

    # --------------------------------------------------------------- panels
    def panels(self) -> Dict[str, Any]:
        """Everything the frontends display, as one plain-JSON dict.
        Panels with nothing to show are present but empty — frontends
        decide whether to hide them."""
        meta = None
        if self.meta is not None:
            m = self.meta
            meta = {"scenario": m.scenario, "method": m.method,
                    "engine": m.engine, "n_workers": m.n_workers,
                    "seed": m.seed, "outer_steps": m.outer_steps,
                    "schema_version": m.schema_version}
        arrivals = {
            "commits": self.n_arrivals, "dropped": self.n_dropped,
            "outer_step": self.outer_step,
            "tokens_total": self.tokens_total,
            "rate_per_sec": self.arrival_rate(),
            "last_wall": self.last_wall,
        }
        staleness = {str(tau): int(n)
                     for tau, n in sorted(self.staleness.items())}
        quality = {}
        if self.cos:
            cos, corr = list(self.cos), list(self.corr)
            quality = {
                "cos": cos, "corr": corr,
                "cos_last": cos[-1], "cos_mean": sum(cos) / len(cos),
                "corr_last": corr[-1], "corr_mean": sum(corr) / len(corr),
            }
        per_language = {}
        if self.last_eval is not None:
            ev = self.last_eval
            per_language = {"outer_step": ev.outer_step,
                            "mean_loss": ev.mean_loss,
                            "per_lang": dict(ev.per_lang or {})}
            if ev.per_lang:
                losses = list(ev.per_lang.values())
                per_language["spread"] = max(losses) - min(losses)
        workers = {
            str(wid): {"arrivals": w["arrivals"], "state": w["state"],
                       "last_step": w["last_step"],
                       "last_wall": w["last_wall"]}
            for wid, w in sorted(self.workers.items())}
        runtime = {}
        if self.last_runtime is not None:
            rt = self.last_runtime
            runtime = {
                "server_occupancy": rt.server_occupancy,
                "compute_parallelism": rt.compute_parallelism,
                "queue_depth": rt.queue_depth,
                "in_flight": rt.in_flight,
                "workers_alive": rt.workers_alive,
                "workers_total": rt.workers_total,
                "liveness": dict(rt.liveness or {}),
            }
        delivery = {
            "counters": {k: v for k, v in sorted(self.delivery.items())
                         if v},
            "events": {k: int(v)
                       for k, v in sorted(self.fault_counts.items())
                       if k != "summary"},
        }
        transport = {}
        if self.transport:
            transport = {
                "workers": {
                    f"{wid}/{pid}": {
                        "frames_sent": rec.frames_sent,
                        "frames_recv": rec.frames_recv,
                        "bytes_sent": rec.bytes_sent,
                        "bytes_recv": rec.bytes_recv,
                        "ser_s": rec.ser_s, "deser_s": rec.deser_s,
                        "crc_rejects": rec.crc_rejects,
                        "retries": rec.retries,
                        "credit_wait_s": rec.credit_wait_s,
                        "rounds": rec.rounds, "compute_s": rec.compute_s,
                        "clock_offset_s": rec.clock_offset_s,
                        "final": rec.final,
                    }
                    for (wid, pid), rec in sorted(self.transport.items())},
                "totals": self.transport_totals(),
            }
        flush = {}
        if self.n_flushes:
            depths = list(self.flush_depths)
            flush = {
                "flushes": self.n_flushes,
                "reasons": {k: int(v)
                            for k, v in sorted(self.flush_reasons.items())},
                "depth_mean": sum(depths) / len(depths),
                "depth_max": self.flush_depth_max,
                "fused": self.flush_fused,
                "sequential": self.flush_sequential,
            }
        return {
            "meta": meta,
            "arrivals": arrivals,
            "staleness": staleness,
            "quality": quality,
            "per_language": per_language,
            "workers": workers,
            "runtime": runtime,
            "delivery": delivery,
            "transport": transport,
            "flush": flush,
            "drift": list(self.decoder.drift_report()),
        }
