"""Robust tail/follow reader for live JSONL telemetry streams.

The operator console must read a stream that is being *written right
now* by a training run (``TelemetryRecorder`` with a live sink flushes
one line per record), so the reader has to survive everything a live
file does:

  - **partial trailing lines** — a record flushed halfway stays in the
    buffer until its newline arrives; nothing half-parsed is ever
    yielded;
  - **truncation** — the file shrinking below the read position (a rerun
    over the same path) restarts the reader from offset 0;
  - **rotation** — the path pointing at a new inode (rename + recreate)
    reopens the new file from the start;
  - **the file not existing yet** — follow mode waits for it to appear.

No dependencies beyond the standard library; decoding into telemetry
records is the ``repro.telemetry.schema.StreamDecoder``'s job (which is
where unknown-kind / newer-schema tolerance lives).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Iterator, List, Optional


class TailReader:
    """Incremental line reader over one path. ``read_available()`` returns
    every complete line that appeared since the last call; ``follow()``
    polls forever (until ``stop`` fires). Bytes after the last newline
    are buffered, not yielded."""

    def __init__(self, path: str, poll: float = 0.2):
        self.path = path
        self.poll = poll
        self._f = None
        self._ino: Optional[int] = None
        self._pos = 0
        self._buf = b""

    # ------------------------------------------------------------ plumbing
    def _close(self):
        if self._f is not None:
            self._f.close()
            self._f = None
        self._ino = None
        self._pos = 0
        self._buf = b""

    def _reopen(self) -> bool:
        self._close()
        try:
            self._f = open(self.path, "rb")
        except FileNotFoundError:
            return False
        self._ino = os.fstat(self._f.fileno()).st_ino
        return True

    def _check_rotation(self):
        """Reopen on inode change (rotation) or shrink (truncation)."""
        try:
            st = os.stat(self.path)
        except FileNotFoundError:
            self._close()                        # wait for it to reappear
            return
        if self._f is None or st.st_ino != self._ino:
            self._reopen()
        elif st.st_size < self._pos:
            self._f.seek(0)
            self._pos = 0
            self._buf = b""

    # ------------------------------------------------------------- reading
    def read_available(self) -> List[str]:
        """Every complete line that is new since the last call."""
        self._check_rotation()
        if self._f is None:
            return []
        chunk = self._f.read()
        if not chunk:
            return []
        self._pos += len(chunk)
        self._buf += chunk
        if b"\n" not in self._buf:
            return []
        complete, self._buf = self._buf.rsplit(b"\n", 1)
        return [ln.decode("utf-8", errors="replace")
                for ln in complete.split(b"\n")]

    def follow(self, stop: Optional[Callable[[], bool]] = None
               ) -> Iterator[str]:
        """Yield lines forever, polling every ``poll`` seconds. ``stop``
        is checked between polls; one final drain runs after it fires so
        a writer that finished just before is fully consumed."""
        while True:
            lines = self.read_available()
            for ln in lines:
                yield ln
            if stop is not None and stop():
                for ln in self.read_available():
                    yield ln
                return
            if not lines:
                time.sleep(self.poll)

    def close(self):
        self._close()


def read_complete_lines(path: str) -> List[str]:
    """One-shot read of every complete line (``--once`` mode); a partial
    trailing line is dropped, exactly like the follow reader would hold
    it back."""
    r = TailReader(path)
    try:
        return r.read_available()
    finally:
        r.close()
