"""Observability for the async runtime: streaming telemetry, a live
terminal operator console, and trace-span profiling.

Three pieces (docs/observability.md):

  - ``repro.obs.spans``   — near-zero-overhead span tracer exporting
    Chrome trace-event JSON (Perfetto-loadable);
  - ``repro.obs.tail``    — rotation/truncation-robust JSONL tail reader;
  - ``repro.obs.console`` — the ``python -m repro.obs console`` dashboard
    over a live or recorded telemetry stream.

This ``__init__`` stays light on purpose: the engines import
``repro.obs.spans`` for the shared ``NULL_TRACER``, so nothing here may
drag in the console (argparse/rendering) or anything heavier.
"""
from repro.obs.spans import (                    # noqa: F401
    NULL_TRACER, NullTracer, SpanTracer, validate_chrome_trace,
)
from repro.obs.tail import (                     # noqa: F401
    TailReader, read_complete_lines,
)
