"""Zero-dependency web dashboard over a telemetry JSONL stream.

``python -m repro.obs web <stream.jsonl>`` serves a single
self-contained HTML page (no external assets, no JS frameworks — plain
``http.server`` + EventSource) that renders the same panels as the
terminal console: arrival rate and totals, the staleness histogram,
cos(D, m) / corrected-mass sparklines, per-language validation loss,
worker liveness, runtime health, delivery/chaos counters — plus the
cross-process transport panel (per worker-process frames/bytes,
serialize time, credit-wait stall, compute) and the commit-buffer flush
panel (depth, reason, fused-vs-sequential) this PR's collection layer
feeds.

Three routes:

  ``/``               the dashboard page (inline CSS + JS, one file);
  ``/events``         Server-Sent Events: one ``panels`` JSON object per
                      refresh interval while the stream grows (follow
                      mode rides ``TailReader``, so rotation/truncation/
                      not-yet-existing files all behave);
  ``/snapshot.json``  the current aggregated panels, one shot.

Aggregation is ``repro.obs.metrics.MetricsAggregator`` — the exact
rollup the terminal console renders; this module only formats it as
HTML/JSON (docs/observability.md, "Web dashboard").

``--snapshot`` skips the server entirely: read the complete lines
currently in the file, print the aggregated panels JSON to stdout, exit.
CI uses it to assert a recorded (or live) stream aggregates non-empty
without opening a port.
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional

from repro.obs.metrics import MetricsAggregator
from repro.obs.tail import TailReader, read_complete_lines

__all__ = ["main", "snapshot_panels", "PAGE"]


def snapshot_panels(stream: str, window: int = 256,
                    strict: bool = False) -> dict:
    """One-shot aggregation of every complete line in ``stream``."""
    agg = MetricsAggregator(window=window, strict=strict)
    for line in read_complete_lines(stream):
        agg.add_line(line)
    return agg.panels()


# ---------------------------------------------------------------------------
# The page. One self-contained document: inline CSS, inline JS, no
# external requests. The JS opens /events and re-renders every panel
# from the pushed JSON; if SSE drops it falls back to polling
# /snapshot.json.
# ---------------------------------------------------------------------------

PAGE = """<!doctype html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>HeLoCo dashboard</title>
<style>
  body { background: #101418; color: #d8dee6; margin: 0;
         font: 13px/1.5 ui-monospace, SFMono-Regular, Menlo, monospace; }
  header { padding: 10px 16px; background: #161b22;
           border-bottom: 1px solid #2a3138; }
  header h1 { font-size: 14px; margin: 0; display: inline; }
  #meta { color: #8b949e; margin-left: 12px; }
  #grid { display: grid; gap: 12px; padding: 12px 16px;
          grid-template-columns: repeat(auto-fit, minmax(340px, 1fr)); }
  .panel { background: #161b22; border: 1px solid #2a3138;
           border-radius: 6px; padding: 10px 12px; min-height: 40px; }
  .panel h2 { font-size: 12px; margin: 0 0 6px; color: #79c0ff;
              text-transform: lowercase; letter-spacing: .04em; }
  .kv { color: #d8dee6; } .kv b { color: #f0f6fc; }
  .dim { color: #8b949e; } .warn { color: #e3b341; }
  .bad { color: #f85149; } .ok { color: #56d364; }
  table { border-collapse: collapse; width: 100%; }
  td, th { padding: 1px 8px 1px 0; text-align: left;
           font-weight: normal; white-space: nowrap; }
  th { color: #8b949e; }
  .bar { display: inline-block; background: #2f81f7; height: 9px;
         vertical-align: baseline; }
  .spark { color: #56d364; letter-spacing: -1px; }
  #status { float: right; color: #8b949e; }
  .hidden { display: none; }
</style>
</head>
<body>
<header>
  <h1>HeLoCo dashboard</h1><span id="meta"></span>
  <span id="status">connecting&hellip;</span>
</header>
<div id="grid">
  <div class="panel" id="p-arrivals"><h2>arrivals</h2><div></div></div>
  <div class="panel" id="p-staleness"><h2>staleness</h2><div></div></div>
  <div class="panel" id="p-quality"><h2>update quality</h2><div></div></div>
  <div class="panel" id="p-lang"><h2>per-language loss</h2><div></div></div>
  <div class="panel" id="p-workers"><h2>workers</h2><div></div></div>
  <div class="panel" id="p-runtime"><h2>runtime health</h2><div></div></div>
  <div class="panel" id="p-transport"><h2>transport</h2><div></div></div>
  <div class="panel" id="p-flush"><h2>commit-buffer flushes</h2>
    <div></div></div>
  <div class="panel" id="p-delivery"><h2>delivery / chaos</h2>
    <div></div></div>
  <div class="panel" id="p-drift"><h2>schema drift</h2><div></div></div>
</div>
<script>
"use strict";
const BLOCKS = "\\u2581\\u2582\\u2583\\u2584\\u2585\\u2586\\u2587\\u2588";
function esc(s) {
  return String(s).replace(/[&<>"]/g,
    ch => ({"&":"&amp;","<":"&lt;",">":"&gt;",'"':"&quot;"}[ch]));
}
function spark(vals, width) {
  vals = vals.slice(-(width || 48));
  if (!vals.length) return "";
  const lo = Math.min(...vals), hi = Math.max(...vals);
  const span = (hi - lo) || 1;
  return vals.map(v =>
    BLOCKS[Math.round((v - lo) / span * (BLOCKS.length - 1))]).join("");
}
function bar(n, nMax, w) {
  if (nMax <= 0) return "";
  const px = Math.max(Math.round(n / nMax * (w || 120)), n > 0 ? 2 : 0);
  return '<span class="bar" style="width:' + px + 'px"></span>';
}
function fill(id, html) {
  const p = document.getElementById(id);
  p.classList.toggle("hidden", !html);
  p.querySelector("div").innerHTML = html || "";
}
function fmtBytes(b) {
  if (b > 1048576) return (b / 1048576).toFixed(1) + " MiB";
  if (b > 1024) return (b / 1024).toFixed(1) + " KiB";
  return b + " B";
}
function render(p) {
  const m = p.meta;
  document.getElementById("meta").textContent = m
    ? (m.scenario || "ad-hoc run") + " | method=" + m.method
      + " engine=" + m.engine + " | " + m.n_workers + " workers | seed "
      + m.seed + " | schema v" + m.schema_version
    : "(no meta record yet)";
  const a = p.arrivals;
  const target = m && m.outer_steps ? "/" + m.outer_steps : "";
  fill("p-arrivals",
    '<div class="kv">commits <b>' + a.commits + "</b> (" + a.dropped
    + " dropped) | outer step <b>" + a.outer_step + esc(target)
    + "</b><br>tokens " + a.tokens_total.toLocaleString() + " | rate "
    + a.rate_per_sec.toFixed(2) + "/s | t=" + a.last_wall.toFixed(1)
    + "s</div>");
  const taus = Object.keys(p.staleness);
  if (taus.length) {
    const nMax = Math.max(...Object.values(p.staleness));
    fill("p-staleness", "<table>" + taus.map(t =>
      "<tr><td>tau=" + esc(t) + "</td><td>"
      + bar(p.staleness[t], nMax, 140) + "</td><td>" + p.staleness[t]
      + "</td></tr>").join("") + "</table>");
  } else fill("p-staleness", "");
  const q = p.quality;
  fill("p-quality", q.cos ?
    '<div class="kv">cos(D,m) <span class="spark">' + spark(q.cos)
    + "</span> last=" + q.cos_last.toFixed(3) + " mean="
    + q.cos_mean.toFixed(3) + '<br>corr mass <span class="spark">'
    + spark(q.corr) + "</span> last=" + q.corr_last.toFixed(3)
    + " mean=" + q.corr_mean.toFixed(3) + "</div>" : "");
  const lg = p.per_language;
  if (lg.per_lang && Object.keys(lg.per_lang).length) {
    const vals = Object.values(lg.per_lang);
    const lo = Math.min(...vals), hi = Math.max(...vals);
    fill("p-lang",
      '<div class="kv">eval @step ' + lg.outer_step + ": mean <b>"
      + lg.mean_loss.toFixed(4) + "</b></div><table>"
      + Object.keys(lg.per_lang).sort().map(l => {
          const v = lg.per_lang[l];
          const frac = hi > lo ? (v - lo) / (hi - lo) : 1;
          return "<tr><td>" + esc(l) + "</td><td>" + v.toFixed(4)
            + "</td><td>" + bar(0.15 + 0.85 * frac, 1, 110)
            + "</td></tr>";
        }).join("") + "</table>"
      + '<div class="dim">spread (max-min): '
      + (lg.spread || 0).toFixed(4) + "</div>");
  } else fill("p-lang", "");
  const wids = Object.keys(p.workers);
  fill("p-workers", wids.length ? "<table>" + wids.map(w => {
      const d = p.workers[w];
      const cls = {alive: "ok", dead: "bad",
                   quarantined: "warn"}[d.state] || "warn";
      return "<tr><td>w" + esc(w) + '</td><td class="' + cls + '">'
        + esc(d.state) + "</td><td>arrivals=" + d.arrivals
        + "</td><td>last step " + (d.last_step == null ? "-"
        : d.last_step) + "</td></tr>";
    }).join("") + "</table>" : "");
  const rt = p.runtime;
  fill("p-runtime", rt.workers_total !== undefined ?
    '<div class="kv">occupancy ' + rt.server_occupancy.toFixed(2)
    + " | parallelism " + rt.compute_parallelism.toFixed(2)
    + " | queue depth " + rt.queue_depth + "<br>in-flight "
    + rt.in_flight + " | alive " + rt.workers_alive + "/"
    + rt.workers_total + "</div>" : "");
  const tp = p.transport;
  if (tp.workers && Object.keys(tp.workers).length) {
    const tot = tp.totals;
    fill("p-transport", "<table><tr><th>w/pid</th><th>tx</th><th>rx</th>"
      + "<th>ser</th><th>stall</th><th>rounds</th><th>compute</th></tr>"
      + Object.keys(tp.workers).map(k => {
          const t = tp.workers[k];
          const warn = (t.crc_rejects || t.retries)
            ? ' <span class="warn">crc=' + t.crc_rejects + " retry="
              + t.retries + "</span>" : "";
          return "<tr><td>" + esc(k) + (t.final ? "" :
              ' <span class="warn">live</span>')
            + "</td><td>" + t.frames_sent + "f/" + fmtBytes(t.bytes_sent)
            + "</td><td>" + t.frames_recv + "f/" + fmtBytes(t.bytes_recv)
            + "</td><td>" + (t.ser_s * 1e3).toFixed(1) + "ms</td><td>"
            + (t.credit_wait_s * 1e3).toFixed(1) + "ms</td><td>"
            + t.rounds + "</td><td>" + t.compute_s.toFixed(2) + "s"
            + warn + "</td></tr>";
        }).join("") + "</table>"
      + '<div class="dim">total: tx ' + (tot.frames_sent || 0) + "f/"
      + fmtBytes(tot.bytes_sent || 0) + " rx " + (tot.frames_recv || 0)
      + "f/" + fmtBytes(tot.bytes_recv || 0) + " compute "
      + (tot.compute_s || 0).toFixed(2) + "s</div>");
  } else fill("p-transport", "");
  const fl = p.flush;
  fill("p-flush", fl.flushes ?
    '<div class="kv">flushes <b>' + fl.flushes + "</b> | depth mean "
    + fl.depth_mean.toFixed(1) + " max " + fl.depth_max + " | fused "
    + fl.fused + " sequential " + fl.sequential
    + '</div><div class="dim">reasons: '
    + Object.keys(fl.reasons).sort().map(r => esc(r) + "="
      + fl.reasons[r]).join(" ") + "</div>" : "");
  const dc = p.delivery.counters, de = p.delivery.events;
  const hasD = Object.keys(dc).length || Object.keys(de).length;
  fill("p-delivery", hasD ?
    '<div class="kv">' + (Object.keys(dc).length ? "counters: "
      + Object.keys(dc).map(k => esc(k) + "=" + Math.round(dc[k]))
        .join(" ") + "<br>" : "")
    + (Object.keys(de).length ? "events: "
      + Object.keys(de).map(k => esc(k) + "=" + de[k]).join(" ") : "")
    + "</div>" : "");
  fill("p-drift", p.drift.length ? p.drift.map(d =>
    '<div class="warn">! ' + esc(d) + "</div>").join("") : "");
}
function setStatus(s, cls) {
  const el = document.getElementById("status");
  el.textContent = s;
  el.className = cls || "";
}
let es = null, pollTimer = null;
function poll() {
  fetch("/snapshot.json").then(r => r.json()).then(p => {
    render(p); setStatus("polling", "warn");
  }).catch(() => setStatus("disconnected", "bad"));
}
function connect() {
  es = new EventSource("/events");
  es.onmessage = ev => {
    if (pollTimer) { clearInterval(pollTimer); pollTimer = null; }
    setStatus("live", "ok");
    render(JSON.parse(ev.data));
  };
  es.onerror = () => {
    setStatus("sse lost; polling", "warn");
    if (!pollTimer) pollTimer = setInterval(poll, 2000);
  };
}
poll();
connect();
</script>
</body>
</html>
"""


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------

class _Hub:
    """One tail-following aggregation shared by every request: a
    background thread drains the TailReader into the MetricsAggregator;
    handlers snapshot ``panels()`` under the lock. The aggregate is
    monotone (counters and latest-wins records), so concurrent SSE
    clients all see the same stream state."""

    def __init__(self, stream: str, window: int = 256,
                 strict: bool = False, poll: float = 0.25):
        self.agg = MetricsAggregator(window=window, strict=strict)
        self.reader = TailReader(stream, poll=poll)
        self.poll = poll
        self.version = 0                 # bumped per batch of new lines
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._pump,
                                        name="obs-web-tail", daemon=True)

    def start(self):
        self._thread.start()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
        self.reader.close()

    def _pump(self):
        while not self._stop.wait(self.poll):
            lines = self.reader.read_available()
            if not lines:
                continue
            with self._lock:
                for ln in lines:
                    self.agg.add_line(ln)
                self.version += 1

    def panels(self) -> dict:
        with self._lock:
            return self.agg.panels()


class _Handler(BaseHTTPRequestHandler):
    hub: _Hub                            # injected by serve()
    sse_interval = 1.0
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):   # quiet by default
        pass

    def _send(self, code: int, ctype: str, body: bytes,
              extra: Optional[dict] = None):
        self.send_response(code)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.send_header("Cache-Control", "no-store")
        for k, v in (extra or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):                    # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path == "/":
            self._send(200, "text/html; charset=utf-8",
                       PAGE.encode("utf-8"))
        elif path == "/snapshot.json":
            body = json.dumps(self.hub.panels()).encode("utf-8")
            self._send(200, "application/json", body)
        elif path == "/events":
            self._sse()
        else:
            self._send(404, "text/plain; charset=utf-8", b"not found\n")

    def _sse(self):
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-store")
        self.end_headers()
        last_version = -1
        try:
            while True:
                version = self.hub.version
                if version != last_version:
                    last_version = version
                    data = json.dumps(self.hub.panels())
                    self.wfile.write(b"data: " + data.encode("utf-8")
                                     + b"\n\n")
                    self.wfile.flush()
                else:
                    # comment frame keeps the connection alive through
                    # quiet stretches (and surfaces a dead client)
                    self.wfile.write(b": keepalive\n\n")
                    self.wfile.flush()
                time.sleep(self.sse_interval)
        except (BrokenPipeError, ConnectionResetError, OSError):
            return                       # client went away


def serve(stream: str, host: str, port: int, *, window: int = 256,
          strict: bool = False, interval: float = 1.0,
          duration: float = 0.0, quiet: bool = False) -> int:
    hub = _Hub(stream, window=window, strict=strict)
    hub.start()
    handler = type("Handler", (_Handler,),
                   {"hub": hub, "sse_interval": interval})
    httpd = ThreadingHTTPServer((host, port), handler)
    httpd.daemon_threads = True
    if not quiet:
        print(f"dashboard: http://{host}:{httpd.server_address[1]}/ "
              f"(stream: {stream})", file=sys.stderr)
    try:
        if duration > 0:
            t = threading.Timer(duration, httpd.shutdown)
            t.daemon = True
            t.start()
        httpd.serve_forever(poll_interval=0.25)
    except KeyboardInterrupt:
        pass
    finally:
        httpd.server_close()
        hub.stop()
    return 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs web",
        description="Web dashboard over a telemetry JSONL stream "
                    "(live or recorded); stdlib only.")
    ap.add_argument("stream", help="telemetry JSONL path (may not exist "
                                   "yet; the tail reader waits)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8377,
                    help="0 picks a free port (printed on stderr)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="SSE push interval seconds (default 1.0)")
    ap.add_argument("--duration", type=float, default=0.0,
                    help="serve for N seconds then exit (0 = until ^C)")
    ap.add_argument("--window", type=int, default=256,
                    help="recent-window size for rate/sparklines")
    ap.add_argument("--strict", action="store_true",
                    help="fail loudly on same-version schema drift")
    ap.add_argument("--snapshot", action="store_true",
                    help="no server: aggregate the complete lines now "
                         "in the file, print panels JSON, exit (CI)")
    args = ap.parse_args(argv)
    if args.snapshot:
        panels = snapshot_panels(args.stream, window=args.window,
                                 strict=args.strict)
        json.dump(panels, sys.stdout, indent=2, sort_keys=True)
        sys.stdout.write("\n")
        return 0
    return serve(args.stream, args.host, args.port, window=args.window,
                 strict=args.strict, interval=args.interval,
                 duration=args.duration)


if __name__ == "__main__":
    raise SystemExit(main())
