"""int8 pseudo-gradient quantization kernels (pod-axis compression).

Two passes: tiled absmax reduction, then fused quantize. Dequantize is one
fused pass. Used by the compression path to cut outer-exchange bytes 4x.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANES, row_tile


def _absmax_kernel(x_ref, out_ref):
    out_ref[0, 0] = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))


def absmax(x2d: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    r = x2d.shape[0]
    rows = row_tile(r, interpret)
    grid = (r // rows,)
    parts = pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(x2d)
    return jnp.max(parts)


def _quant_kernel(x_ref, s_ref, out_ref):
    scale = s_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def _dequant_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]
                    ).astype(out_ref.dtype)


def quantize_2d(x2d: jnp.ndarray, interpret: bool = True):
    """Returns (q (R,128) int8, scale scalar fp32)."""
    scale = jnp.maximum(absmax(x2d, interpret), 1e-12) / 127.0
    r = x2d.shape[0]
    rows = row_tile(r, interpret)
    grid = (r // rows,)
    q = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.int8),
        interpret=interpret,
    )(x2d, scale.reshape(1, 1))
    return q, scale


def dequantize_2d(q2d: jnp.ndarray, scale: jnp.ndarray,
                  out_dtype=jnp.float32, interpret: bool = True):
    r = q2d.shape[0]
    rows = row_tile(r, interpret)
    grid = (r // rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q2d.shape, out_dtype),
        interpret=interpret,
    )(q2d, scale.reshape(1, 1))
