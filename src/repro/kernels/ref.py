"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are validated against in tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig
from repro.core.heloco import correct_block as _correct_block


def ref_heloco_correct(delta: jnp.ndarray, mom: jnp.ndarray,
                       h: HeLoCoConfig) -> jnp.ndarray:
    """The paper-equation implementation from repro.core (Alg. 2)."""
    return _correct_block(delta, mom, h)


def ref_outer_update(p: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray,
                     eta: float, mu: float, rho: float):
    gf = rho * g.astype(jnp.float32)
    m_new = mu * m.astype(jnp.float32) + (1.0 - mu) * gf
    p_new = p.astype(jnp.float32) - eta * (gf + mu * m_new)
    return p_new.astype(p.dtype), m_new


def ref_quantize(x: jnp.ndarray):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ref_dequantize(q: jnp.ndarray, scale: jnp.ndarray):
    return q.astype(jnp.float32) * scale
