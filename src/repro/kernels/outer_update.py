"""Fused Nesterov outer update (paper Eqs. 17-19) as a Pallas TPU kernel.

Per arrival the synchronizer updates momentum and parameters:
    m' = mu*m + (1-mu)*rho*g
    p' = p - eta*(rho*g + mu*m')
Unfused this is two O(d) passes with an extra momentum round-trip; the
kernel reads (p, m, g) once and writes (p', m') once — the minimal HBM
traffic (3 reads + 2 writes of d floats).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANES, row_tile


def _outer_kernel(p_ref, m_ref, g_ref, hp_ref, p_out, m_out):
    eta = hp_ref[0, 0]
    mu = hp_ref[0, 1]
    rho = hp_ref[0, 2]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32) * rho
    m_new = mu * m + (1.0 - mu) * g
    p_new = p - eta * (g + mu * m_new)
    m_out[...] = m_new
    p_out[...] = p_new.astype(p_out.dtype)


def outer_update_2d(p2d: jnp.ndarray, m2d: jnp.ndarray, g2d: jnp.ndarray,
                    eta: float, mu: float, rho,
                    interpret: bool = True, rows: int | None = None):
    """p2d/m2d/g2d: (R, 128). Returns (p', m'). m is fp32."""
    r = p2d.shape[0]
    rows = row_tile(r, interpret, rows)
    grid = (r // rows,)
    hp = jnp.stack([jnp.asarray(eta, jnp.float32),
                    jnp.asarray(mu, jnp.float32),
                    jnp.asarray(rho, jnp.float32)]).reshape(1, 3)
    return pl.pallas_call(
        _outer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
            jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
        ],
        interpret=interpret,
    )(p2d, m2d, g2d, hp)
