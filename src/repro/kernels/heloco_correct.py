"""Pallas TPU kernels for the HeLoCo per-block correction (paper Alg. 2).

The correction is memory-bound: per arriving block it needs one reduction
pass (dot(u,v), ||u||^2, ||v||^2) and one elementwise pass
(out = cu*u + cv*v, where cu/cv encode the keep/damp/rotate branch).
A naive jnp implementation materialises u_hat, v_hat, u_tilde -> 3 extra
HBM round-trips over d floats. These kernels do exactly two passes:

  block_stats   : tiled VMEM reduction -> per-tile partial (dot, uu, vv)
  correct_apply : fused out = cu*u + cv*v in one read of (u, v)

Tiling (shared rules in ``repro.kernels.tiling``): the flattened block is
zero-padded to an (R, 128) view with R tile-aligned; on TPU the grid walks
row-tiles of up to ROWS rows so each step's working set
(2 x ROWS x 128 x 4B = 256 KiB at ROWS=256) sits comfortably in VMEM, and
the 128-lane minor dimension matches the TPU vector registers. The CPU
interpreter runs one grid step (see ``tiling.row_tile``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import LANES, ROWS, row_tile


def _stats_kernel(u_ref, v_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(u * v)
    out_ref[0, 1] = jnp.sum(u * u)
    out_ref[0, 2] = jnp.sum(v * v)


def block_stats(u2d: jnp.ndarray, v2d: jnp.ndarray,
                interpret: bool = True, rows: int | None = None
                ) -> jnp.ndarray:
    """u2d, v2d: (R, 128). Returns (n_tiles, 3) partial sums fp32."""
    r = u2d.shape[0]
    rows = row_tile(r, interpret, rows)
    grid = (r // rows,)
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 3), jnp.float32),
        interpret=interpret,
    )(u2d, v2d)


def _apply_kernel(u_ref, v_ref, cu_ref, cv_ref, out_ref):
    cu = cu_ref[0, 0]
    cv = cv_ref[0, 0]
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    out_ref[...] = (cu * u + cv * v).astype(out_ref.dtype)


def correct_apply(u2d: jnp.ndarray, v2d: jnp.ndarray, cu: jnp.ndarray,
                  cv: jnp.ndarray, interpret: bool = True,
                  rows: int | None = None) -> jnp.ndarray:
    """out = cu*u + cv*v, fused single pass. cu/cv: scalar arrays."""
    r = u2d.shape[0]
    rows = row_tile(r, interpret, rows)
    grid = (r // rows,)
    return pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(u2d.shape, u2d.dtype),
        interpret=interpret,
    )(u2d, v2d, cu.reshape(1, 1), cv.reshape(1, 1))
