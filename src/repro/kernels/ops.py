"""jit'd public wrappers around the PER-LEAF Pallas kernels:
padding/reshaping to the (R, 128) tiled view, branch-scalar computation,
and pytree-level entry points that mirror the pure-jnp references in
``repro.kernels.ref``.

``interpret=None`` auto-selects: interpreter on CPU (validation), compiled
Mosaic on TPU.

The arrival hot loop does not go through these per-block wrappers any
more: ``repro.kernels.packed`` + ``repro.core.packing`` process the whole
pytree as one flat buffer with O(1) launches (docs/packed_layout.md).
These wrappers remain the correctness reference and the entry point for
single-tensor use.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import HeLoCoConfig
from repro.kernels import heloco_correct as hk
from repro.kernels import outer_update as ok
from repro.kernels import quantize as qk
from repro.kernels.tiling import LANES, padded_rows

PyTree = Any


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _to_2d(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    """Flatten + zero-pad to (R, 128), R tile-aligned (see kernels.tiling).

    Over-padding is bounded by one sublane tile (7 rows) — the old rule
    padded awkward sizes like 128*256 + 1 to 2x their footprint.
    """
    flat = x.reshape(-1)
    n = flat.size
    r = padded_rows(n)
    flat = jnp.pad(flat, (0, r * LANES - n))
    return flat.reshape(r, LANES), n


def _from_2d(x2d: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return x2d.reshape(-1)[:n].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# HeLoCo block correction (paper Alg. 2) — kernel path
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("h", "interpret"))
def heloco_correct_block(delta: jnp.ndarray, mom: jnp.ndarray,
                         h: HeLoCoConfig, interpret: bool | None = None
                         ) -> jnp.ndarray:
    interpret = _auto_interpret(interpret)
    u2d, n = _to_2d(delta.astype(jnp.float32))
    v2d, _ = _to_2d(mom.astype(jnp.float32))
    parts = hk.block_stats(u2d, v2d, interpret=interpret)  # (tiles, 3)
    dot, uu, vv = parts.sum(0)
    nu = jnp.sqrt(uu)
    nv = jnp.sqrt(vv)
    c = dot / jnp.maximum(nu * nv, h.eps * h.eps)
    conf = nu / (nu + h.kappa * nv + h.eps)

    # branch scalars: out = cu*u + cv*v
    # keep: (1, 0)
    # anti: u - beta*c*nu*v_hat  -> (1, -beta*c*nu/nv)
    beta = jnp.minimum(h.k_s * (-c) * conf, h.beta_max)
    anti_cv = -beta * c * nu / jnp.maximum(nv, h.eps)
    # weak: (nu/max(||u_tilde||, eps)) * ((1-lam)/nu * u + lam/nv * v)
    lam = jnp.minimum(h.k_d * (1.0 - c) * conf, 1.0)
    # ||u_tilde||^2 = (1-lam)^2 + lam^2 + 2 lam (1-lam) c
    nt = jnp.sqrt((1 - lam) ** 2 + lam ** 2 + 2 * lam * (1 - lam) * c)
    wscale = nu / jnp.maximum(nt, h.eps)
    weak_cu = wscale * (1 - lam) / jnp.maximum(nu, h.eps)
    weak_cv = wscale * lam / jnp.maximum(nv, h.eps)

    keep = c >= h.c_ok
    antib = c < 0.0
    degen = (nu < h.eps) | (nv < h.eps)
    cu = jnp.where(degen | keep, 1.0, jnp.where(antib, 1.0, weak_cu))
    cv = jnp.where(degen | keep, 0.0, jnp.where(antib, anti_cv, weak_cv))

    out2d = hk.correct_apply(u2d, v2d, cu, cv, interpret=interpret)
    return _from_2d(out2d, n, delta.shape, delta.dtype)


# ---------------------------------------------------------------------------
# Fused outer Nesterov update (paper Eqs. 17-19) — kernel path
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("eta", "mu", "interpret"))
def outer_update_block(p: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray,
                       eta: float, mu: float, rho,
                       interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    p2d, n = _to_2d(p.astype(jnp.float32))
    m2d, _ = _to_2d(m.astype(jnp.float32))
    g2d, _ = _to_2d(g.astype(jnp.float32))
    p_new, m_new = ok.outer_update_2d(p2d, m2d, g2d, eta, mu,
                                      jnp.asarray(rho, jnp.float32),
                                      interpret=interpret)
    return (_from_2d(p_new, n, p.shape, p.dtype),
            _from_2d(m_new, n, m.shape, jnp.float32))


# ---------------------------------------------------------------------------
# int8 quantization — kernel path
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_block(x: jnp.ndarray, interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    x2d, n = _to_2d(x.astype(jnp.float32))
    q2d, scale = qk.quantize_2d(x2d, interpret=interpret)
    return q2d, scale, jnp.asarray([n], jnp.int32)


@functools.partial(jax.jit, static_argnames=("shape", "dtype", "interpret"))
def dequantize_block(q2d: jnp.ndarray, scale: jnp.ndarray, shape,
                     dtype=jnp.float32, interpret: bool | None = None):
    interpret = _auto_interpret(interpret)
    x2d = qk.dequantize_2d(q2d, scale, out_dtype=jnp.float32,
                           interpret=interpret)
    n = 1
    for s in shape:
        n *= s
    return _from_2d(x2d, n, shape, dtype)
