"""Pallas TPU flash-attention FORWARD kernel.

Why it exists (roofline-driven): the XLA lowering of the jnp flash path
materialises each (q_chunk x S) fp32 score block in HBM (measured in
EXPERIMENTS.md SPerf — it turns attention memory-bound at 4k+ context).
This kernel tiles q and kv into VMEM blocks and carries the online-softmax
state (m, l, acc) in VMEM scratch across the kv grid axis, so per-step HBM
traffic is O(q + k + v + out) instead of O(S^2) score blocks.

Grid: (BH, n_q, n_kv) — on TPU the minor-most grid axis iterates
sequentially per core, which is what makes scratch accumulation across kv
blocks legal. Causal masking is applied per-tile from absolute indices.
The backward continues to use the jnp custom_vjp path (see
models/attention.py); fusing the backward is listed as future work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      causal: bool, scale: float, q_chunk: int, kv_chunk: int,
                      n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (qc, d)
    k = k_ref[0].astype(jnp.float32)            # (kc, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_idx = qi * q_chunk + jax.lax.broadcasted_iota(jnp.int32,
                                                        s.shape, 0)
        kv_idx = ki * kv_chunk + jax.lax.broadcasted_iota(jnp.int32,
                                                          s.shape, 1)
        s = jnp.where(kv_idx <= q_idx, s, NEG_INF)

    m_prev = m_scr[...]                          # (qc, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                       # (qc, kc)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + p.sum(axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, q_chunk: int = 128,
                        kv_chunk: int = 128,
                        interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BH, Skv, D) — GQA callers broadcast kv heads
    and flatten (batch, heads) into BH. Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0
    n_q = sq // q_chunk
    n_kv = skv // kv_chunk
    grid = (bh, n_q, n_kv)
    kern = functools.partial(
        _flash_fwd_kernel, causal=causal, scale=d ** -0.5, q_chunk=q_chunk,
        kv_chunk=kv_chunk, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_chunk, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_chunk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_chunk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_chunk, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_chunk, 1), jnp.float32),
            pltpu.VMEM((q_chunk, 1), jnp.float32),
            pltpu.VMEM((q_chunk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
