"""Shared (R, 128) tiling rules for every Pallas kernel in this package.

All kernels view their operands as a 2-D ``(R, LANES)`` buffer whose minor
dimension matches the TPU vector lanes. The grid walks row-tiles of
``row_tile(R)`` rows; that tile size must divide R exactly, so the padding
rule and the tile rule are defined together here:

  * R <= ROWS          : a single grid step covers the whole buffer, so any
                         R works (tile = R, no row padding needed).
  * R > ROWS           : R is padded up to a multiple of ROW_ALIGN (the fp32
                         sublane tile) and the row-tile is ``gcd(R, ROWS)``
                         — at least ROW_ALIGN rows, at most ROWS, and always
                         an exact divisor of R.

Compared to the old rule (pad R to a multiple of min(ROWS, R)) this bounds
the over-padding at ROW_ALIGN - 1 rows instead of ROWS - 1: a buffer of
128*256 + 1 elements used to be padded to 2x its size, now to +1023
elements.
"""
from __future__ import annotations

import math

LANES = 128      # TPU vector lanes: minor dim of every tiled view
ROWS = 256       # max rows per grid step: 3 operands * 256*128*4B < VMEM
ROW_ALIGN = 8    # fp32 sublane tile: row counts are padded to this


def padded_rows(n: int) -> int:
    """Number of rows of the (R, LANES) view holding ``n`` elements."""
    r = max(1, -(-n // LANES))
    if r <= ROWS:
        return r
    return -(-r // ROW_ALIGN) * ROW_ALIGN


def row_tile(r: int, interpret: bool = False, rows: int | None = None) -> int:
    """Rows per grid step for an R-row buffer; always divides ``r``.

    interpret: the interpreter (CPU correctness path) has no VMEM limit,
    and its per-grid-step cost scales with the FULL operand size — a
    multi-step grid is quadratic there — so interpret mode runs the whole
    buffer as one grid step.
    rows: explicit override (must divide r); used by tests to exercise
    multi-step grids under the interpreter.
    """
    if rows is not None:
        assert r % rows == 0, (r, rows)
        return rows
    if interpret or r <= ROWS:
        return r
    return math.gcd(r, ROWS)
