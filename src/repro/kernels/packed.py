"""Pallas kernels over the packed (R, 128) arrival buffer.

These collapse the per-leaf arrival pipeline (2 ``pallas_call`` per block
for the correction + a second full tree sweep for the outer update —
O(#leaves) launches and ~2x the minimal HBM traffic) into exactly TWO
launches per pseudo-gradient, independent of how many tensors the model
has:

  packed_row_stats     one sweep reading (delta, momentum) -> per-row
                       partial (dot, uu, vv); a tiny O(R) segment-sum over
                       the static row->block map turns that into per-block
                       statistics (R = d/128, so the segment reduction is
                       negligible next to the O(d) sweep).
  packed_correct_outer one fused sweep reading (p, m, delta) tiles plus a
                       per-row (cu, cv) scalar table, writing (p', m') —
                       Alg. 2 correction and the Eq. 17-19 Nesterov outer
                       update in a single pass: 3 reads + 2 writes of d
                       floats, the roofline minimum for this update.

Plus per-row-scale int8 quantization (``packed_rowabs`` / ``packed_quant``
/ ``packed_dequant``) so compression round-trips are also one launch per
sweep instead of per-leaf.

Branch-scalar computation (``branch_scalars``) is vectorised over all B
blocks at once — O(B) elementwise work on tiny arrays.

Padding contract: zero rows contribute zero to every statistic and map to
zero under the fused update (p=m=delta=0 stays 0), so the packed buffer's
padding never needs re-zeroing between arrivals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.configs.base import HeLoCoConfig
from repro.kernels.tiling import LANES, row_tile


def _grid(r: int, interpret: bool, rows: int | None = None):
    rows = row_tile(r, interpret, rows)
    return rows, (r // rows,)


# ---------------------------------------------------------------------------
# Sweep 1: per-row correction statistics (segment-reduction friendly)
# ---------------------------------------------------------------------------

def _rowstats_kernel(u_ref, v_ref, out_ref):
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.stack(
        [jnp.sum(u * v, axis=1), jnp.sum(u * u, axis=1),
         jnp.sum(v * v, axis=1)], axis=1)


def packed_row_stats(u2d: jnp.ndarray, v2d: jnp.ndarray,
                     interpret: bool = True,
                     rows: int | None = None) -> jnp.ndarray:
    """u2d, v2d: (R, 128). One read of each; returns (R, 3) row partials."""
    r = u2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    return pl.pallas_call(
        _rowstats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))] * 2,
        out_specs=pl.BlockSpec((rows, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 3), jnp.float32),
        interpret=interpret,
    )(u2d, v2d)


def packed_stats(u2d: jnp.ndarray, v2d: jnp.ndarray, row_block: jnp.ndarray,
                 n_blocks: int, interpret: bool = True,
                 ranges=None) -> jnp.ndarray:
    """Per-block (dot, uu, vv): one O(d) sweep + an O(R) segment reduction.

    ranges: optional static ((start_row, end_row), ...) per block (see
    ``BlockLayout.block_row_ranges``) — blocks are contiguous row spans,
    so the reduction lowers to static slices, ~6x cheaper than the
    scatter-based segment sum used when only ``row_block`` is available.
    """
    parts = packed_row_stats(u2d, v2d, interpret=interpret)
    if ranges is not None:
        return jnp.stack([parts[s:e].sum(axis=0) for s, e in ranges])
    return jax.ops.segment_sum(parts, jnp.asarray(row_block),
                               num_segments=n_blocks,
                               indices_are_sorted=True)


# ---------------------------------------------------------------------------
# Branch scalars, vectorised over blocks (paper Alg. 2 / Eqs. 7-16)
# ---------------------------------------------------------------------------

def branch_scalars(stats: jnp.ndarray, h: HeLoCoConfig):
    """(B, 3) per-block (dot, uu, vv) -> per-block (cu, cv), each (B,).

    The corrected pseudo-gradient of every block is ``cu*u + cv*v``; cu/cv
    encode the keep / anti-aligned-damp / weak-aligned-rotate branch
    exactly as in ``ops.heloco_correct_block``, but for all blocks at once.
    """
    dot, uu, vv = stats[:, 0], stats[:, 1], stats[:, 2]
    nu = jnp.sqrt(uu)
    nv = jnp.sqrt(vv)
    c = dot / jnp.maximum(nu * nv, h.eps * h.eps)
    conf = nu / (nu + h.kappa * nv + h.eps)

    beta = jnp.minimum(h.k_s * (-c) * conf, h.beta_max)
    anti_cv = -beta * c * nu / jnp.maximum(nv, h.eps)

    lam = jnp.minimum(h.k_d * (1.0 - c) * conf, 1.0)
    nt = jnp.sqrt((1 - lam) ** 2 + lam ** 2 + 2 * lam * (1 - lam) * c)
    wscale = nu / jnp.maximum(nt, h.eps)
    weak_cu = wscale * (1 - lam) / jnp.maximum(nu, h.eps)
    weak_cv = wscale * lam / jnp.maximum(nv, h.eps)

    keep = c >= h.c_ok
    antib = c < 0.0
    degen = (nu < h.eps) | (nv < h.eps)
    cu = jnp.where(degen | keep, 1.0, jnp.where(antib, 1.0, weak_cu))
    cv = jnp.where(degen | keep, 0.0, jnp.where(antib, anti_cv, weak_cv))
    return cu, cv


# ---------------------------------------------------------------------------
# Sweep 2: fused correct + Nesterov outer update
# ---------------------------------------------------------------------------

# Per-row telemetry moments (see repro.telemetry.stats): each fused sweep
# already reads (delta, momentum) tiles, so update-quality diagnostics are
# emitted as ONE extra per-row output of the SAME launch — [d.m, d.d, m.m,
# |g_unweighted - d|^2] partials, reduced outside the kernel. The p'/m'
# arithmetic of the stats variants is op-for-op identical to the plain
# kernels, so enabling telemetry cannot move a single output bit.
N_MOMENTS = 4


def _row_moments(d, m, corr):
    return jnp.stack([jnp.sum(d * m, axis=1), jnp.sum(d * d, axis=1),
                      jnp.sum(m * m, axis=1),
                      jnp.sum((corr - d) * (corr - d), axis=1)], axis=1)


def _correct_outer_kernel(p_ref, m_ref, d_ref, cu_ref, cv_ref, hp_ref,
                          p_out, m_out):
    eta = hp_ref[0, 0]
    mu = hp_ref[0, 1]
    rho = hp_ref[0, 2]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    g = (cu_ref[...] * d + cv_ref[...] * m) * rho    # corrected, weighted
    m_new = mu * m + (1.0 - mu) * g
    p_out[...] = (p - eta * (g + mu * m_new)).astype(p_out.dtype)
    m_out[...] = m_new


def _correct_outer_stats_kernel(p_ref, m_ref, d_ref, cu_ref, cv_ref, hp_ref,
                                p_out, m_out, s_out):
    eta = hp_ref[0, 0]
    mu = hp_ref[0, 1]
    rho = hp_ref[0, 2]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    g = (cu_ref[...] * d + cv_ref[...] * m) * rho    # corrected, weighted
    m_new = mu * m + (1.0 - mu) * g
    p_out[...] = (p - eta * (g + mu * m_new)).astype(p_out.dtype)
    m_out[...] = m_new
    s_out[...] = _row_moments(d, m, cu_ref[...] * d + cv_ref[...] * m)


def packed_correct_outer(p2d: jnp.ndarray, m2d: jnp.ndarray,
                         d2d: jnp.ndarray, cu_rows: jnp.ndarray,
                         cv_rows: jnp.ndarray, eta: float, mu: float, rho,
                         interpret: bool = True, rows: int | None = None,
                         with_stats: bool = False):
    """One fused sweep: g = cu*delta + cv*m per row, then Eqs. 17-19.

    p2d/m2d/d2d: (R, 128); cu_rows/cv_rows: (R, 1) per-row branch scalars
    (each block's scalar replicated over its rows). Returns (p', m'), plus
    an (R, 4) per-row telemetry-moment output when ``with_stats`` — same
    single launch, identical p'/m' arithmetic.
    """
    r = p2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    hp = jnp.stack([jnp.asarray(eta, jnp.float32),
                    jnp.asarray(mu, jnp.float32),
                    jnp.asarray(rho, jnp.float32)]).reshape(1, 3)
    out_specs = [
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
    ]
    if with_stats:
        out_specs.append(pl.BlockSpec((rows, N_MOMENTS), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((r, N_MOMENTS), jnp.float32))
    return pl.pallas_call(
        _correct_outer_stats_kernel if with_stats else _correct_outer_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(p2d, m2d, d2d, cu_rows, cv_rows, hp)


# ---------------------------------------------------------------------------
# Sweep 2 variants for the generalized method layer (repro.core.methods).
# Same contract as packed_correct_outer — ONE fused launch, one read of
# each input tile, one write of each output tile — but with the extra
# per-method terms: a quadratic delay-compensation coefficient (cq) and/or
# a gradient-accumulator buffer with schedule scalars (am, bm, ab, cg, cm).
# Methods pick their variant through their packed hook; this module never
# branches on method names.
# ---------------------------------------------------------------------------

def _correct_outer_quad_kernel(p_ref, m_ref, d_ref, cu_ref, cv_ref, cq_ref,
                               hp_ref, p_out, m_out):
    eta = hp_ref[0, 0]
    mu = hp_ref[0, 1]
    rho = hp_ref[0, 2]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    g = (cu_ref[...] * d + cv_ref[...] * m
         + cq_ref[...] * d * d * m) * rho       # Taylor-compensated, weighted
    m_new = mu * m + (1.0 - mu) * g
    p_out[...] = (p - eta * (g + mu * m_new)).astype(p_out.dtype)
    m_out[...] = m_new


def _correct_outer_quad_stats_kernel(p_ref, m_ref, d_ref, cu_ref, cv_ref,
                                     cq_ref, hp_ref, p_out, m_out, s_out):
    eta = hp_ref[0, 0]
    mu = hp_ref[0, 1]
    rho = hp_ref[0, 2]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    g = (cu_ref[...] * d + cv_ref[...] * m
         + cq_ref[...] * d * d * m) * rho       # Taylor-compensated, weighted
    m_new = mu * m + (1.0 - mu) * g
    p_out[...] = (p - eta * (g + mu * m_new)).astype(p_out.dtype)
    m_out[...] = m_new
    s_out[...] = _row_moments(
        d, m, cu_ref[...] * d + cv_ref[...] * m + cq_ref[...] * d * d * m)


def packed_correct_outer_quad(p2d: jnp.ndarray, m2d: jnp.ndarray,
                              d2d: jnp.ndarray, cu_rows: jnp.ndarray,
                              cv_rows: jnp.ndarray, cq_rows: jnp.ndarray,
                              eta: float, mu: float, rho,
                              interpret: bool = True,
                              rows: int | None = None,
                              with_stats: bool = False):
    """One fused sweep with a quadratic compensation term per row:
    g = cu*delta + cv*m + cq*delta^2*m, then Eqs. 17-19. Returns (p', m')
    (+ (R, 4) telemetry moments when ``with_stats``, same launch)."""
    r = p2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    hp = jnp.stack([jnp.asarray(eta, jnp.float32),
                    jnp.asarray(mu, jnp.float32),
                    jnp.asarray(rho, jnp.float32)]).reshape(1, 3)
    out_specs = [
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
    ]
    if with_stats:
        out_specs.append(pl.BlockSpec((rows, N_MOMENTS), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((r, N_MOMENTS), jnp.float32))
    return pl.pallas_call(
        (_correct_outer_quad_stats_kernel if with_stats
         else _correct_outer_quad_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(p2d, m2d, d2d, cu_rows, cv_rows, cq_rows, hp)


def _correct_outer_acc_kernel(p_ref, m_ref, b_ref, d_ref, cu_ref, cv_ref,
                              hp_ref, p_out, m_out, b_out):
    eta = hp_ref[0, 0]
    rho = hp_ref[0, 1]
    am = hp_ref[0, 2]
    bm = hp_ref[0, 3]
    ab = hp_ref[0, 4]
    cg = hp_ref[0, 5]
    cm = hp_ref[0, 6]
    ca = hp_ref[0, 7]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    g = (cu_ref[...] * d + cv_ref[...] * m) * rho
    acc = b + g
    m_new = am * m + bm * acc
    p_out[...] = (p - eta * (cg * g + ca * acc + cm * m_new)
                  ).astype(p_out.dtype)
    m_out[...] = m_new
    b_out[...] = ab * acc


def _correct_outer_acc_stats_kernel(p_ref, m_ref, b_ref, d_ref, cu_ref,
                                    cv_ref, hp_ref, p_out, m_out, b_out,
                                    s_out):
    eta = hp_ref[0, 0]
    rho = hp_ref[0, 1]
    am = hp_ref[0, 2]
    bm = hp_ref[0, 3]
    ab = hp_ref[0, 4]
    cg = hp_ref[0, 5]
    cm = hp_ref[0, 6]
    ca = hp_ref[0, 7]
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    g = (cu_ref[...] * d + cv_ref[...] * m) * rho
    acc = b + g
    m_new = am * m + bm * acc
    p_out[...] = (p - eta * (cg * g + ca * acc + cm * m_new)
                  ).astype(p_out.dtype)
    m_out[...] = m_new
    b_out[...] = ab * acc
    s_out[...] = _row_moments(d, m, cu_ref[...] * d + cv_ref[...] * m)


def packed_correct_outer_acc(p2d: jnp.ndarray, m2d: jnp.ndarray,
                             b2d: jnp.ndarray, d2d: jnp.ndarray,
                             cu_rows: jnp.ndarray, cv_rows: jnp.ndarray,
                             eta: float, rho, am, bm, ab, cg, cm, ca=0.0,
                             interpret: bool = True,
                             rows: int | None = None,
                             with_stats: bool = False):
    """One fused sweep of the generalized schedule with a gradient
    accumulator (delayed-Nesterov / FedBuff family):

      g = (cu*delta + cv*m)*rho;  acc = b + g
      m' = am*m + bm*acc;  b' = ab*acc
      p' = p - eta*(cg*g + ca*acc + cm*m')

    Schedule scalars may be traced (boundary arrivals toggle them).
    Returns (p', m', b') (+ (R, 4) telemetry moments when ``with_stats``,
    same launch)."""
    r = p2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    hp = jnp.stack([jnp.asarray(eta, jnp.float32),
                    jnp.asarray(rho, jnp.float32),
                    jnp.asarray(am, jnp.float32),
                    jnp.asarray(bm, jnp.float32),
                    jnp.asarray(ab, jnp.float32),
                    jnp.asarray(cg, jnp.float32),
                    jnp.asarray(cm, jnp.float32),
                    jnp.asarray(ca, jnp.float32)]).reshape(1, 8)
    out_specs = [
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
        jax.ShapeDtypeStruct(b2d.shape, jnp.float32),
    ]
    if with_stats:
        out_specs.append(pl.BlockSpec((rows, N_MOMENTS), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((r, N_MOMENTS), jnp.float32))
    return pl.pallas_call(
        (_correct_outer_acc_stats_kernel if with_stats
         else _correct_outer_acc_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(p2d, m2d, b2d, d2d, cu_rows, cv_rows, hp)


# ---------------------------------------------------------------------------
# Batched multi-arrival sweeps (K coalesced deltas, ONE launch).
#
# The server's commit buffer coalesces up to K pending arrivals and flushes
# them through these kernels: a (K, R, 128) delta stack plus per-delta
# (K, R, 1) coefficient rows and a (K, n_hp) scalar table. The kernel
# unrolls the K applications in registers — p and m round-trip through
# fp32 registers instead of fp32 HBM between applications, which is the
# identity, so the result is op-order-IDENTICAL to K sequential launches
# of the single-arrival kernels whenever the per-delta coefficients match
# what the sequential path would have computed. HBM traffic drops from
# K*(3R+2W) to (K+2)R+2W of d floats; launches from K (or 2K) to 1.
#
# Telemetry moments ride the same sweep as a (K, R, 4) extra output,
# each slice computed against the momentum as of THAT application — the
# same values K sequential with_stats launches would emit.
# ---------------------------------------------------------------------------


def _multi_hp(k: int, *cols) -> jnp.ndarray:
    """Per-delta scalar table: each col is a scalar or (K,) -> (K, #cols)."""
    cols = [jnp.broadcast_to(jnp.asarray(c, jnp.float32), (k,)) for c in cols]
    return jnp.stack(cols, axis=1)


def _multi_correct_outer_kernel(k: int, with_stats: bool):
    def kern(p_ref, m_ref, d_ref, cu_ref, cv_ref, hp_ref, p_out, m_out,
             *s_out):
        p = p_ref[...].astype(jnp.float32)
        m = m_ref[...].astype(jnp.float32)
        for j in range(k):
            eta = hp_ref[j, 0]
            mu = hp_ref[j, 1]
            rho = hp_ref[j, 2]
            d = d_ref[j].astype(jnp.float32)
            corr = cu_ref[j] * d + cv_ref[j] * m
            if with_stats:
                s_out[0][j] = _row_moments(d, m, corr)
            g = corr * rho
            m_new = mu * m + (1.0 - mu) * g
            p = p - eta * (g + mu * m_new)
            m = m_new
        p_out[...] = p.astype(p_out.dtype)
        m_out[...] = m
    return kern


def packed_multi_correct_outer(p2d: jnp.ndarray, m2d: jnp.ndarray,
                               d3d: jnp.ndarray, cu_rows: jnp.ndarray,
                               cv_rows: jnp.ndarray, eta, mu, rho,
                               interpret: bool = True,
                               rows: int | None = None,
                               with_stats: bool = False):
    """K fused correct+outer applications in ONE launch.

    d3d: (K, R, 128) delta stack; cu_rows/cv_rows: (K, R, 1) per-delta
    coefficient rows; eta/mu/rho: scalar or (K,) per-delta. Returns
    (p', m') after all K applications (+ (K, R, 4) per-row telemetry
    moments when ``with_stats``, one slice per delta, same launch).
    """
    k, r = d3d.shape[0], p2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    hp = _multi_hp(k, eta, mu, rho)
    out_specs = [
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
    ]
    if with_stats:
        out_specs.append(pl.BlockSpec((k, rows, N_MOMENTS),
                                      lambda i: (0, i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((k, r, N_MOMENTS),
                                              jnp.float32))
    return pl.pallas_call(
        _multi_correct_outer_kernel(k, with_stats),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((k, rows, LANES), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rows, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rows, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, 3), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(p2d, m2d, d3d, cu_rows, cv_rows, hp)


def _multi_correct_outer_quad_kernel(k: int, with_stats: bool):
    def kern(p_ref, m_ref, d_ref, cu_ref, cv_ref, cq_ref, hp_ref, p_out,
             m_out, *s_out):
        p = p_ref[...].astype(jnp.float32)
        m = m_ref[...].astype(jnp.float32)
        for j in range(k):
            eta = hp_ref[j, 0]
            mu = hp_ref[j, 1]
            rho = hp_ref[j, 2]
            d = d_ref[j].astype(jnp.float32)
            corr = cu_ref[j] * d + cv_ref[j] * m + cq_ref[j] * d * d * m
            if with_stats:
                s_out[0][j] = _row_moments(d, m, corr)
            g = corr * rho
            m_new = mu * m + (1.0 - mu) * g
            p = p - eta * (g + mu * m_new)
            m = m_new
        p_out[...] = p.astype(p_out.dtype)
        m_out[...] = m
    return kern


def packed_multi_correct_outer_quad(p2d: jnp.ndarray, m2d: jnp.ndarray,
                                    d3d: jnp.ndarray, cu_rows: jnp.ndarray,
                                    cv_rows: jnp.ndarray,
                                    cq_rows: jnp.ndarray, eta, mu, rho,
                                    interpret: bool = True,
                                    rows: int | None = None,
                                    with_stats: bool = False):
    """K quadratic-compensated applications in one launch (multi variant
    of :func:`packed_correct_outer_quad`); cq_rows: (K, R, 1)."""
    k, r = d3d.shape[0], p2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    hp = _multi_hp(k, eta, mu, rho)
    out_specs = [
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
    ]
    if with_stats:
        out_specs.append(pl.BlockSpec((k, rows, N_MOMENTS),
                                      lambda i: (0, i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((k, r, N_MOMENTS),
                                              jnp.float32))
    return pl.pallas_call(
        _multi_correct_outer_quad_kernel(k, with_stats),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((k, rows, LANES), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rows, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rows, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rows, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, 3), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(p2d, m2d, d3d, cu_rows, cv_rows, cq_rows, hp)


def _multi_correct_outer_acc_kernel(k: int, with_stats: bool):
    def kern(p_ref, m_ref, b_ref, d_ref, cu_ref, cv_ref, hp_ref, p_out,
             m_out, b_out, *s_out):
        p = p_ref[...].astype(jnp.float32)
        m = m_ref[...].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        for j in range(k):
            eta = hp_ref[j, 0]
            rho = hp_ref[j, 1]
            am = hp_ref[j, 2]
            bm = hp_ref[j, 3]
            ab = hp_ref[j, 4]
            cg = hp_ref[j, 5]
            cm = hp_ref[j, 6]
            ca = hp_ref[j, 7]
            d = d_ref[j].astype(jnp.float32)
            corr = cu_ref[j] * d + cv_ref[j] * m
            if with_stats:
                s_out[0][j] = _row_moments(d, m, corr)
            g = corr * rho
            acc = b + g
            m_new = am * m + bm * acc
            p = p - eta * (cg * g + ca * acc + cm * m_new)
            m = m_new
            b = ab * acc
        p_out[...] = p.astype(p_out.dtype)
        m_out[...] = m
        b_out[...] = b
    return kern


def packed_multi_correct_outer_acc(p2d: jnp.ndarray, m2d: jnp.ndarray,
                                   b2d: jnp.ndarray, d3d: jnp.ndarray,
                                   cu_rows: jnp.ndarray,
                                   cv_rows: jnp.ndarray,
                                   eta, rho, am, bm, ab, cg, cm, ca=0.0,
                                   interpret: bool = True,
                                   rows: int | None = None,
                                   with_stats: bool = False):
    """K accumulator-schedule applications in one launch (multi variant of
    :func:`packed_correct_outer_acc`); every schedule scalar may be a
    per-delta (K,) vector — boundary arrivals inside the batch toggle
    their own slot. Returns (p', m', b')."""
    k, r = d3d.shape[0], p2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    hp = _multi_hp(k, eta, rho, am, bm, ab, cg, cm, ca)
    out_specs = [
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
        jax.ShapeDtypeStruct(b2d.shape, jnp.float32),
    ]
    if with_stats:
        out_specs.append(pl.BlockSpec((k, rows, N_MOMENTS),
                                      lambda i: (0, i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((k, r, N_MOMENTS),
                                              jnp.float32))
    return pl.pallas_call(
        _multi_correct_outer_acc_kernel(k, with_stats),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((k, rows, LANES), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rows, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, rows, 1), lambda i: (0, i, 0)),
            pl.BlockSpec((k, 8), lambda i: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(p2d, m2d, b2d, d3d, cu_rows, cv_rows, hp)


def _multi_gram_kernel(k: int):
    t = k + 1
    def kern(m_ref, d_ref, out_ref):
        vecs = [m_ref[...].astype(jnp.float32)]
        vecs += [d_ref[j].astype(jnp.float32) for j in range(k)]
        cols = []
        for a in range(t):
            for b in range(a, t):
                cols.append(jnp.sum(vecs[a] * vecs[b], axis=1))
        out_ref[...] = jnp.stack(cols, axis=1)
    return kern


def packed_multi_gram(m2d: jnp.ndarray, d3d: jnp.ndarray, ranges,
                      interpret: bool = True,
                      rows: int | None = None) -> jnp.ndarray:
    """Per-block Gram matrix of the batch basis [m0, d_1..d_K].

    One sweep reading (m, d-stack) emits per-row pairwise products of the
    K+1 basis vectors; the static ``ranges`` slices (see
    ``BlockLayout.block_row_ranges``) reduce them to per-block sums.
    Returns (B, K+1, K+1) symmetric Gram matrices. Every inner product a
    sequential flush would measure — between any delta and the EVOLVING
    momentum — is a linear functional of this Gram (the momentum after j
    applications stays inside span[m0, d_1..d_j]), so one launch replaces
    the K stats sweeps of the sequential path.
    """
    k, r = d3d.shape[0], m2d.shape[0]
    t = k + 1
    p_cols = t * (t + 1) // 2
    rows, grid = _grid(r, interpret, rows)
    parts = pl.pallas_call(
        _multi_gram_kernel(k),
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((k, rows, LANES), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((rows, p_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, p_cols), jnp.float32),
        interpret=interpret,
    )(m2d, d3d)
    blocks = jnp.stack([parts[s:e].sum(axis=0) for s, e in ranges])
    idx = np.zeros((t, t), np.int32)
    c = 0
    for a in range(t):
        for b in range(a, t):
            idx[a, b] = idx[b, a] = c
            c += 1
    return blocks[:, idx]


# ---------------------------------------------------------------------------
# Per-row-scale int8 quantization (packed compression path)
# ---------------------------------------------------------------------------

def _rowabs_kernel(x_ref, out_ref):
    out_ref[...] = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)),
                           axis=1, keepdims=True)


def packed_rowabs(x2d: jnp.ndarray, interpret: bool = True,
                  rows: int | None = None) -> jnp.ndarray:
    """(R, 128) -> (R, 1) per-row absmax in one sweep."""
    r = x2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    return pl.pallas_call(
        _rowabs_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, 1), jnp.float32),
        interpret=interpret,
    )(x2d)


def _quant_kernel(x_ref, s_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.clip(jnp.round(x / s_ref[...]), -127, 127
                            ).astype(jnp.int8)


def packed_quant(x2d: jnp.ndarray, scale_rows: jnp.ndarray,
                 interpret: bool = True,
                 rows: int | None = None) -> jnp.ndarray:
    """Quantize with a per-row scale table; scale_rows: (R, 1), > 0."""
    r = x2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, jnp.int8),
        interpret=interpret,
    )(x2d, scale_rows)


def _dequant_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]
                    ).astype(out_ref.dtype)


def packed_dequant(q2d: jnp.ndarray, scale_rows: jnp.ndarray,
                   out_dtype=jnp.float32, interpret: bool = True,
                   rows: int | None = None):
    r = q2d.shape[0]
    rows, grid = _grid(r, interpret, rows)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q2d.shape, out_dtype),
        interpret=interpret,
    )(q2d, scale_rows)
