"""Typed schema of the telemetry stream.

A stream is a JSONL file: one ``{"kind": ..., ...}`` object per line.
Seven record kinds:

  meta      one per stream (first line): what produced it;
  arrival   one per committed outer step: scheduling facts (worker,
            staleness, rho, sim/wall time, language/mixture, dropped)
            plus the update-quality stats of ``repro.telemetry.stats``;
  eval      one per evaluation: mean + per-language validation loss;
  fault     one per delivery-protocol event on the wall-clock runtime
            (checksum reject, dedup, quarantine, liveness transition) and
            one end-of-run "summary" carrying the delivery counters;
  runtime   one periodic runtime-health snapshot (engine-driven cadence):
            occupancy, parallelism, queue depth, worker liveness, and the
            delivery/fault counters — the live operator console's
            (``python -m repro.obs console``) health panel;
  transport one per child-worker observability report under the socket
            transport (low-rate ``("ctrl","obs",...)`` frames, see
            docs/observability.md): per-worker wire counters (frames and
            bytes each way, serialize/deserialize time, CRC rejects,
            retries, credit-wait stall) + per-round compute wall time,
            pid-stamped so the panels can tell incarnations apart;
  flush     one per server commit-buffer flush (PR 9's ``Synchronizer``):
            buffered depth at flush, the reason the buffer flushed
            (batch-full / eval / ckpt / close), and how many commits went
            through the fused multi-arrival kernel vs the sequential
            fallback.

Records are frozen dataclasses; ``to_json_line``/``from_json_line``
round-trip them. Unknown keys in a line are rejected loudly (schema
drift should fail, not silently drop fields); bump SCHEMA_VERSION on
breaking changes. Live readers that must survive streams written by a
NEWER schema (the console tailing a file from a newer build) go through
``StreamDecoder``, which tolerates unknown kinds/fields but *counts and
reports* everything it skipped instead of silently thinning the stream.
"""
from __future__ import annotations

import dataclasses
import json
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

# v2: added the "fault" record kind (delivery-robustness events)
# v3: added the "runtime" record kind (periodic runtime-health snapshots)
# v4: added the "transport" record kind (child-worker wire/compute
#     counters shipped over the socket control channel) and the "flush"
#     record kind (commit-buffer depth/reason/fusion metrics)
SCHEMA_VERSION = 4


@dataclass(frozen=True)
class RunMeta:
    """Provenance of one stream."""
    method: str
    engine: str                       # make_engine dialect: "sim"|"wallclock"
    n_workers: int
    outer_steps: int
    seed: int
    non_iid: bool = False
    mixture_alpha: Optional[float] = None
    scenario: str = ""                # scenario / cell name, if any
    schema_version: int = SCHEMA_VERSION


@dataclass(frozen=True)
class ArrivalMetrics:
    """One committed outer step (one pseudo-gradient arrival or one
    synchronous barrier round)."""
    outer_step: int
    worker_id: int
    staleness: int
    rho: float
    sim_time: float
    wall_time: float
    lang: str
    dropped: bool
    # update-quality stats (None when the synchronizer ran stats-free)
    cos_align: Optional[float] = None
    corrected_frac: Optional[float] = None
    delta_norm: Optional[float] = None
    momentum_norm: Optional[float] = None
    # data heterogeneity context
    mixture: Optional[Tuple[float, ...]] = None
    # budget accounting view: cumulative tokens at commit
    tokens_total: int = 0


@dataclass(frozen=True)
class EvalMetrics:
    """One evaluation snapshot (Fig. 2/3 protocol)."""
    outer_step: int
    sim_time: float
    wall_time: float
    mean_loss: float
    per_lang: Dict[str, float] = field(default_factory=dict)


@dataclass(frozen=True)
class FaultMetrics:
    """One delivery-protocol event (wall-clock runtime under an
    unreliable channel — see docs/faults.md). ``event`` vocabulary:
    checksum_reject | dedup | quarantine | liveness_dead |
    liveness_revive | summary. Frame identity fields are -1 when the
    event is not tied to a specific frame; ``detail`` carries the
    delivery counters for the end-of-run "summary" event."""
    event: str
    wall_time: float
    wid: int = -1
    seq: int = -1
    generation: int = -1
    detail: Optional[Dict[str, float]] = None


@dataclass(frozen=True)
class RuntimeMetrics:
    """One periodic runtime-health snapshot (engine-driven cadence — the
    ``runtime_record_every`` knob of ``make_engine`` / the
    ``telemetry_every`` field of a Scenario). The wall-clock runtime
    fills every field from its live counters
    (``ConcurrentRuntime.stats_summary()`` / ``delivery_stats()``); the
    simulator emits only the worker-membership view (rates/occupancy
    stay 0). ``liveness`` holds state tallies (``dead``, ``quarantined``,
    ``threads_alive``); ``delivery`` the cumulative delivery/fault
    counters of docs/faults.md."""
    outer_step: int
    sim_time: float
    wall_time: float
    workers_alive: int
    workers_total: int
    in_flight: int = 0
    arrivals: int = 0
    arrivals_per_sec: float = 0.0
    server_occupancy: float = 0.0
    compute_parallelism: float = 0.0
    queue_depth: int = 0
    liveness: Dict[str, int] = field(default_factory=dict)
    delivery: Dict[str, float] = field(default_factory=dict)


@dataclass(frozen=True)
class TransportMetrics:
    """One child-worker observability report (socket transport only).

    Children ship these as low-rate ``("ctrl","obs",...)`` frames over
    the same length-prefixed socket the data plane uses; the parent
    stamps its own wall clock and re-emits them into the stream. Time
    fields are cumulative seconds since the worker connected; counters
    are cumulative over the same window, so panels difference
    consecutive records per (wid, pid) for rates. ``final`` marks the
    graceful end-of-run report (the launcher's child-report-in check
    keys on it)."""
    wid: int
    pid: int
    wall_time: float
    frames_sent: int = 0
    frames_recv: int = 0
    bytes_sent: int = 0
    bytes_recv: int = 0
    ser_s: float = 0.0                # pickle serialize wall time
    deser_s: float = 0.0              # unpickle wall time
    crc_rejects: int = 0
    retries: int = 0
    credit_wait_s: float = 0.0        # stalled waiting for send credit
    rounds: int = 0
    compute_s: float = 0.0            # execute_round wall time
    clock_offset_s: float = 0.0       # child->parent clock offset estimate
    final: bool = False


@dataclass(frozen=True)
class FlushMetrics:
    """One server commit-buffer flush (docs/scale.md). ``reason``
    vocabulary: batch-full | eval | ckpt | close. ``fused`` counts
    commits applied through the K-stacked multi-arrival kernels,
    ``sequential`` the per-arrival fallback (drops, non-batchable
    methods, singleton runs)."""
    outer_step: int
    sim_time: float
    wall_time: float
    depth: int
    reason: str
    fused: int = 0
    sequential: int = 0


Record = Union[RunMeta, ArrivalMetrics, EvalMetrics, FaultMetrics,
               RuntimeMetrics, TransportMetrics, FlushMetrics]

KINDS: Dict[str, type] = {"meta": RunMeta, "arrival": ArrivalMetrics,
                          "eval": EvalMetrics, "fault": FaultMetrics,
                          "runtime": RuntimeMetrics,
                          "transport": TransportMetrics,
                          "flush": FlushMetrics}
_KIND_OF = {cls: kind for kind, cls in KINDS.items()}


def kind_of(rec: Record) -> str:
    return _KIND_OF[type(rec)]


def to_json_line(rec: Record) -> str:
    return json.dumps({"kind": kind_of(rec), **dataclasses.asdict(rec)},
                      sort_keys=True)


def from_json_line(line: str) -> Record:
    d = json.loads(line)
    kind = d.pop("kind", None)
    cls = KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown telemetry record kind {kind!r}")
    if cls is ArrivalMetrics and d.get("mixture") is not None:
        d["mixture"] = tuple(d["mixture"])
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"telemetry schema drift: {kind} record has "
                         f"unknown fields {sorted(unknown)}")
    return cls(**d)


class StreamDecoder:
    """Forward-compatible stream reader with drift accounting.

    ``from_json_line`` rejects any unknown key/kind loudly — correct for
    same-version tooling, fatal for a live console tailing a stream a
    NEWER build is writing. The decoder closes that gap with an explicit
    version check instead of silent thinning:

      - it learns the stream's declared version from its ``meta`` record;
      - unknown record kinds and unknown fields are skipped but
        **counted** (``unknown_kinds`` / ``unknown_keys``), and
        ``drift_report()`` renders the tally so a v3 reader *surfaces* a
        v4 stream ("stream schema v4 > reader v3: skipped ...") rather
        than quietly showing less data;
      - ``strict=True`` restores the loud behavior for streams at or
        below the reader's version (genuine drift should still fail) —
        a declared-newer stream is tolerated-and-counted even then.

    Undecodable lines (torn writes that still ended in a newline) are
    never raised in lenient mode; they land in ``bad_lines``.
    """

    def __init__(self, strict: bool = False):
        self.strict = strict
        self.meta: Optional[RunMeta] = None
        self.stream_version: Optional[int] = None
        self.lines = 0
        self.bad_lines = 0
        self.unknown_kinds: Counter = Counter()
        self.unknown_keys: Counter = Counter()

    @property
    def newer_stream(self) -> bool:
        """The stream declared a schema version ahead of this reader."""
        return (self.stream_version is not None
                and self.stream_version > SCHEMA_VERSION)

    def decode(self, line: str) -> Optional[Record]:
        line = line.strip()
        if not line:
            return None
        self.lines += 1
        try:
            d = json.loads(line)
            if not isinstance(d, dict):
                raise ValueError("not an object")
        except ValueError:
            if self.strict and not self.newer_stream:
                raise
            self.bad_lines += 1
            return None
        kind = d.pop("kind", None)
        cls = KINDS.get(kind)
        if cls is None:
            if self.strict and not self.newer_stream:
                raise ValueError(f"unknown telemetry record kind {kind!r}")
            self.unknown_kinds[str(kind)] += 1
            return None
        if cls is ArrivalMetrics and d.get("mixture") is not None:
            d["mixture"] = tuple(d["mixture"])
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            if self.strict and not self.newer_stream:
                raise ValueError(f"telemetry schema drift: {kind} record "
                                 f"has unknown fields {sorted(unknown)}")
            for k in unknown:
                self.unknown_keys[f"{kind}.{k}"] += 1
                d.pop(k)
        try:
            rec = cls(**d)
        except TypeError:
            # missing required fields (a truncated-then-completed object)
            self.bad_lines += 1
            return None
        if isinstance(rec, RunMeta):
            self.meta = rec
            self.stream_version = int(rec.schema_version)
        return rec

    def drift_report(self) -> List[str]:
        """Human-readable drift/skip tally; empty means a clean stream."""
        out: List[str] = []
        if self.newer_stream:
            out.append(f"stream schema v{self.stream_version} > reader "
                       f"v{SCHEMA_VERSION}: fields/kinds unknown to this "
                       f"reader are skipped (counted below)")
        if self.unknown_kinds:
            tally = ", ".join(f"{k} x{n}" for k, n
                              in sorted(self.unknown_kinds.items()))
            out.append(f"skipped unknown record kinds: {tally}")
        if self.unknown_keys:
            tally = ", ".join(f"{k} x{n}" for k, n
                              in sorted(self.unknown_keys.items()))
            out.append(f"skipped unknown fields: {tally}")
        if self.bad_lines:
            out.append(f"undecodable lines: {self.bad_lines}")
        return out
