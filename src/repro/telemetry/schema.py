"""Typed schema of the telemetry stream.

A stream is a JSONL file: one ``{"kind": ..., ...}`` object per line.
Four record kinds:

  meta      one per stream (first line): what produced it;
  arrival   one per committed outer step: scheduling facts (worker,
            staleness, rho, sim/wall time, language/mixture, dropped)
            plus the update-quality stats of ``repro.telemetry.stats``;
  eval      one per evaluation: mean + per-language validation loss;
  fault     one per delivery-protocol event on the wall-clock runtime
            (checksum reject, dedup, quarantine, liveness transition) and
            one end-of-run "summary" carrying the delivery counters.

Records are frozen dataclasses; ``to_json_line``/``from_json_line``
round-trip them. Unknown keys in a line are rejected loudly (schema
drift should fail, not silently drop fields); bump SCHEMA_VERSION on
breaking changes.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

# v2: added the "fault" record kind (delivery-robustness events)
SCHEMA_VERSION = 2


@dataclass(frozen=True)
class RunMeta:
    """Provenance of one stream."""
    method: str
    engine: str                       # make_engine dialect: "sim"|"wallclock"
    n_workers: int
    outer_steps: int
    seed: int
    non_iid: bool = False
    mixture_alpha: Optional[float] = None
    scenario: str = ""                # scenario / cell name, if any
    schema_version: int = SCHEMA_VERSION


@dataclass(frozen=True)
class ArrivalMetrics:
    """One committed outer step (one pseudo-gradient arrival or one
    synchronous barrier round)."""
    outer_step: int
    worker_id: int
    staleness: int
    rho: float
    sim_time: float
    wall_time: float
    lang: str
    dropped: bool
    # update-quality stats (None when the synchronizer ran stats-free)
    cos_align: Optional[float] = None
    corrected_frac: Optional[float] = None
    delta_norm: Optional[float] = None
    momentum_norm: Optional[float] = None
    # data heterogeneity context
    mixture: Optional[Tuple[float, ...]] = None
    # budget accounting view: cumulative tokens at commit
    tokens_total: int = 0


@dataclass(frozen=True)
class EvalMetrics:
    """One evaluation snapshot (Fig. 2/3 protocol)."""
    outer_step: int
    sim_time: float
    wall_time: float
    mean_loss: float
    per_lang: Dict[str, float] = field(default_factory=dict)


@dataclass(frozen=True)
class FaultMetrics:
    """One delivery-protocol event (wall-clock runtime under an
    unreliable channel — see docs/faults.md). ``event`` vocabulary:
    checksum_reject | dedup | quarantine | liveness_dead |
    liveness_revive | summary. Frame identity fields are -1 when the
    event is not tied to a specific frame; ``detail`` carries the
    delivery counters for the end-of-run "summary" event."""
    event: str
    wall_time: float
    wid: int = -1
    seq: int = -1
    generation: int = -1
    detail: Optional[Dict[str, float]] = None


Record = Union[RunMeta, ArrivalMetrics, EvalMetrics, FaultMetrics]

KINDS: Dict[str, type] = {"meta": RunMeta, "arrival": ArrivalMetrics,
                          "eval": EvalMetrics, "fault": FaultMetrics}
_KIND_OF = {cls: kind for kind, cls in KINDS.items()}


def kind_of(rec: Record) -> str:
    return _KIND_OF[type(rec)]


def to_json_line(rec: Record) -> str:
    return json.dumps({"kind": kind_of(rec), **dataclasses.asdict(rec)},
                      sort_keys=True)


def from_json_line(line: str) -> Record:
    d = json.loads(line)
    kind = d.pop("kind", None)
    cls = KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown telemetry record kind {kind!r}")
    if cls is ArrivalMetrics and d.get("mixture") is not None:
        d["mixture"] = tuple(d["mixture"])
    known = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"telemetry schema drift: {kind} record has "
                         f"unknown fields {sorted(unknown)}")
    return cls(**d)
