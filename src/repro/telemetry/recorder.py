"""In-memory telemetry sink + JSONL persistence.

A ``TelemetryRecorder`` is handed to an engine (``make_engine(...,
telemetry=rec)``); the engine emits one ``ArrivalMetrics`` per committed
outer step and one ``EvalMetrics`` per evaluation. Wall-time stamps are
relative to the recorder's creation, so the stream is self-contained.

The recorder never influences the run: stats are extra outputs of the
kernels the synchronizer launches anyway, and recording is append-only —
telemetry-on runs are byte-identical to telemetry-off runs (CI-gated via
the golden traces, see tests/test_telemetry.py).
"""
from __future__ import annotations

import os
import time
from typing import Dict, Iterator, List, Optional

from repro.telemetry import schema


class TelemetryRecorder:
    def __init__(self, meta: Optional[schema.RunMeta] = None):
        self.meta = meta
        self.records: List[schema.Record] = []
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------- emission
    def wall(self) -> float:
        return time.perf_counter() - self._t0

    def ensure_meta(self, **kw) -> None:
        """Set the stream provenance once (first engine to run wins)."""
        if self.meta is None:
            self.meta = schema.RunMeta(**kw)

    def record_arrival(self, rec, *, mixture=None,
                       tokens_total: int = 0) -> None:
        """``rec`` duck-types ``repro.async_engine.server.ArrivalRecord``
        (the synchronizer attaches the update-quality stats to it)."""
        def pick(name):
            v = getattr(rec, name, None)
            return None if v is None else float(v)

        self.records.append(schema.ArrivalMetrics(
            outer_step=int(rec.outer_step),
            worker_id=int(rec.worker_id),
            staleness=int(rec.staleness),
            rho=float(rec.rho),
            sim_time=float(rec.sim_time),
            wall_time=self.wall(),
            lang=rec.lang,
            dropped=bool(rec.dropped),
            cos_align=pick("cos_align"),
            corrected_frac=pick("corrected_frac"),
            delta_norm=pick("delta_norm"),
            momentum_norm=pick("momentum_norm"),
            mixture=None if mixture is None else tuple(float(x)
                                                       for x in mixture),
            tokens_total=int(tokens_total)))

    def record_eval(self, ev: Dict) -> None:
        """``ev`` is the ``make_eval_fn`` result dict."""
        self.records.append(schema.EvalMetrics(
            outer_step=int(ev["step"]),
            sim_time=float(ev["time"]),
            wall_time=self.wall(),
            mean_loss=float(ev["mean"]),
            per_lang={k: float(v) for k, v in ev.get("per_lang",
                                                     {}).items()}))

    def record_fault(self, *, event: str, wid: int = -1, seq: int = -1,
                     generation: int = -1, detail=None) -> None:
        """One delivery-protocol event (checksum reject, dedup,
        quarantine, liveness transition, end-of-run counter summary)."""
        self.records.append(schema.FaultMetrics(
            event=event, wall_time=self.wall(), wid=int(wid), seq=int(seq),
            generation=int(generation),
            detail=None if detail is None
            else {k: float(v) for k, v in detail.items()}))

    # -------------------------------------------------------------- queries
    def arrivals(self) -> List[schema.ArrivalMetrics]:
        return [r for r in self.records
                if isinstance(r, schema.ArrivalMetrics)]

    def evals(self) -> List[schema.EvalMetrics]:
        return [r for r in self.records if isinstance(r, schema.EvalMetrics)]

    def faults(self) -> List[schema.FaultMetrics]:
        return [r for r in self.records if isinstance(r, schema.FaultMetrics)]

    def __len__(self) -> int:
        return len(self.records)

    def summary(self) -> Dict:
        from repro.telemetry import analysis
        return analysis.summarize(self.arrivals(), self.evals())

    # ------------------------------------------------------------------ io
    def write_jsonl(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            if self.meta is not None:
                f.write(schema.to_json_line(self.meta) + "\n")
            for rec in self.records:
                f.write(schema.to_json_line(rec) + "\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def read_jsonl(cls, path: str) -> "TelemetryRecorder":
        rec = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                r = schema.from_json_line(line)
                if isinstance(r, schema.RunMeta):
                    rec.meta = r
                else:
                    rec.records.append(r)
        return rec


def iter_jsonl(path: str) -> Iterator[schema.Record]:
    """Streaming reader (large sweeps)."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield schema.from_json_line(line)
