"""In-memory telemetry sink + live JSONL streaming.

A ``TelemetryRecorder`` is handed to an engine (``make_engine(...,
telemetry=rec)``); the engine emits one ``ArrivalMetrics`` per committed
outer step, one ``EvalMetrics`` per evaluation, and (when a cadence is
configured) periodic ``RuntimeMetrics`` health snapshots. Wall-time
stamps are relative to the recorder's creation, so the stream is
self-contained.

Memory contract
---------------

Two retention modes:

  - **no sink** (default): every record is retained in ``self.records``
    (an unbounded list) — fine for the short CI-sized runs the analyses
    consume, and what ``write_jsonl`` serializes at the end.
  - **live sink** (``TelemetryRecorder(sink=path)``): the full stream
    lives on disk — each record is written and flushed as ONE complete
    JSONL line the moment it is recorded, so ``python -m repro.obs
    console <path>`` can tail the run live. ``self.records`` then
    becomes a bounded ring of the most recent ``window`` records
    (default 4096) so in-process analyses (``summary()``,
    ``arrivals()``, ...) see a recent window while memory stays
    O(window) for arbitrarily long runs. ``write_jsonl`` copies the
    complete on-disk stream, never the ring.

The recorder never influences the run: stats are extra outputs of the
kernels the synchronizer launches anyway, and recording is append-only —
telemetry-on runs are byte-identical to telemetry-off runs (CI-gated via
the golden traces, see tests/test_telemetry.py and tests/test_obs.py).
"""
from __future__ import annotations

import os
import shutil
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Union

try:                                  # POSIX advisory locks; absent on
    import fcntl                      # exotic platforms -> no enforcement
except ImportError:                   # pragma: no cover
    fcntl = None  # type: ignore[assignment]

from repro.telemetry import schema

#: ring size once a live sink holds the full stream (memory contract above)
DEFAULT_WINDOW = 4096


def _open_exclusive_sink(path: str):
    """Open a live sink with single-writer enforcement.

    Two processes appending interleaved flushes to one JSONL sink can
    tear each other's lines in ways no tail-side reader can repair, so
    the writer side refuses: the sink fd holds an exclusive advisory
    lock (``flock``) for the recorder's lifetime, and a second recorder
    — same process or another one — fails loudly instead of silently
    corrupting the stream. The lock is taken BEFORE truncation so a
    rejected opener never clobbers the live writer's bytes."""
    fd = os.open(path, os.O_WRONLY | os.O_CREAT, 0o644)
    if fcntl is not None:
        try:
            fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            os.close(fd)
            raise RuntimeError(
                f"telemetry sink {path!r} already has a live writer "
                f"(single-writer contract: one TelemetryRecorder per "
                f"sink — point the second writer at its own file)")
    os.ftruncate(fd, 0)
    return os.fdopen(fd, "w")


class TelemetryRecorder:
    def __init__(self, meta: Optional[schema.RunMeta] = None,
                 sink: Optional[str] = None,
                 window: Optional[int] = None):
        self.meta = meta
        if sink is not None or window:
            self.records: Union[List[schema.Record], deque] = deque(
                maxlen=window or DEFAULT_WINDOW)
        else:
            self.records = []
        self._t0 = time.perf_counter()
        self._sink_path = sink
        self._sink = None
        self._meta_written = False
        if sink is not None:
            os.makedirs(os.path.dirname(sink) or ".", exist_ok=True)
            self._sink = _open_exclusive_sink(sink)
            self._write_meta_line()

    # ------------------------------------------------------------- emission
    def wall(self) -> float:
        return time.perf_counter() - self._t0

    def _write_meta_line(self) -> None:
        if self._sink is not None and self.meta is not None \
                and not self._meta_written:
            self._sink.write(schema.to_json_line(self.meta) + "\n")
            self._sink.flush()
            self._meta_written = True

    def _emit(self, rec: schema.Record) -> None:
        self.records.append(rec)
        if self._sink is not None:
            self._sink.write(schema.to_json_line(rec) + "\n")
            self._sink.flush()               # per-record: tail-able live

    def ensure_meta(self, **kw) -> None:
        """Set the stream provenance once (first engine to run wins)."""
        if self.meta is None:
            self.meta = schema.RunMeta(**kw)
        self._write_meta_line()

    def record_arrival(self, rec, *, mixture=None,
                       tokens_total: int = 0) -> None:
        """``rec`` duck-types ``repro.async_engine.server.ArrivalRecord``
        (the synchronizer attaches the update-quality stats to it)."""
        def pick(name):
            v = getattr(rec, name, None)
            return None if v is None else float(v)

        self._emit(schema.ArrivalMetrics(
            outer_step=int(rec.outer_step),
            worker_id=int(rec.worker_id),
            staleness=int(rec.staleness),
            rho=float(rec.rho),
            sim_time=float(rec.sim_time),
            wall_time=self.wall(),
            lang=rec.lang,
            dropped=bool(rec.dropped),
            cos_align=pick("cos_align"),
            corrected_frac=pick("corrected_frac"),
            delta_norm=pick("delta_norm"),
            momentum_norm=pick("momentum_norm"),
            mixture=None if mixture is None else tuple(float(x)
                                                       for x in mixture),
            tokens_total=int(tokens_total)))

    def record_eval(self, ev: Dict) -> None:
        """``ev`` is the ``make_eval_fn`` result dict."""
        self._emit(schema.EvalMetrics(
            outer_step=int(ev["step"]),
            sim_time=float(ev["time"]),
            wall_time=self.wall(),
            mean_loss=float(ev["mean"]),
            per_lang={k: float(v) for k, v in ev.get("per_lang",
                                                     {}).items()}))

    def record_fault(self, *, event: str, wid: int = -1, seq: int = -1,
                     generation: int = -1, detail=None) -> None:
        """One delivery-protocol event (checksum reject, dedup,
        quarantine, liveness transition, end-of-run counter summary)."""
        self._emit(schema.FaultMetrics(
            event=event, wall_time=self.wall(), wid=int(wid), seq=int(seq),
            generation=int(generation),
            detail=None if detail is None
            else {k: float(v) for k, v in detail.items()}))

    def record_runtime(self, *, outer_step: int, sim_time: float,
                       **kw) -> None:
        """One periodic runtime-health snapshot (engine-driven cadence;
        see ``schema.RuntimeMetrics`` for the field vocabulary)."""
        self._emit(schema.RuntimeMetrics(
            outer_step=int(outer_step), sim_time=float(sim_time),
            wall_time=self.wall(), **kw))

    def record_transport(self, *, wid: int, pid: int, **kw) -> None:
        """One child-worker wire/compute counter report (socket
        transport control channel; see ``schema.TransportMetrics``)."""
        self._emit(schema.TransportMetrics(
            wid=int(wid), pid=int(pid), wall_time=self.wall(), **kw))

    def record_flush(self, *, outer_step: int, sim_time: float,
                     depth: int, reason: str, fused: int = 0,
                     sequential: int = 0) -> None:
        """One commit-buffer flush event (``schema.FlushMetrics``)."""
        self._emit(schema.FlushMetrics(
            outer_step=int(outer_step), sim_time=float(sim_time),
            wall_time=self.wall(), depth=int(depth), reason=str(reason),
            fused=int(fused), sequential=int(sequential)))

    # -------------------------------------------------------------- queries
    def arrivals(self) -> List[schema.ArrivalMetrics]:
        return [r for r in self.records
                if isinstance(r, schema.ArrivalMetrics)]

    def evals(self) -> List[schema.EvalMetrics]:
        return [r for r in self.records if isinstance(r, schema.EvalMetrics)]

    def faults(self) -> List[schema.FaultMetrics]:
        return [r for r in self.records if isinstance(r, schema.FaultMetrics)]

    def runtime_records(self) -> List[schema.RuntimeMetrics]:
        return [r for r in self.records
                if isinstance(r, schema.RuntimeMetrics)]

    def transport_records(self) -> List[schema.TransportMetrics]:
        return [r for r in self.records
                if isinstance(r, schema.TransportMetrics)]

    def flush_records(self) -> List[schema.FlushMetrics]:
        return [r for r in self.records
                if isinstance(r, schema.FlushMetrics)]

    def __len__(self) -> int:
        return len(self.records)

    def summary(self) -> Dict:
        from repro.telemetry import analysis
        return analysis.summarize(self.arrivals(), self.evals())

    # ------------------------------------------------------------------ io
    @property
    def sink_path(self) -> Optional[str]:
        return self._sink_path

    def flush(self) -> None:
        if self._sink is not None:
            self._sink.flush()

    def close(self) -> None:
        """Flush and close the live sink (idempotent; the stream file
        stays valid after every flushed line, so close is a courtesy,
        not a durability requirement)."""
        if self._sink is not None:
            self._sink.close()
            self._sink = None

    def write_jsonl(self, path: str) -> str:
        """Persist the FULL stream to ``path``. With a live sink the
        complete stream is already on disk — it is copied (not the
        bounded in-memory ring); without one, the in-memory records are
        serialized."""
        if self._sink_path is not None:
            self.flush()
            if os.path.abspath(path) != os.path.abspath(self._sink_path):
                os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                shutil.copyfile(self._sink_path, path)
            return path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            if self.meta is not None:
                f.write(schema.to_json_line(self.meta) + "\n")
            for rec in self.records:
                f.write(schema.to_json_line(rec) + "\n")
        os.replace(tmp, path)
        return path

    @classmethod
    def read_jsonl(cls, path: str) -> "TelemetryRecorder":
        rec = cls()
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                r = schema.from_json_line(line)
                if isinstance(r, schema.RunMeta):
                    rec.meta = r
                else:
                    rec.records.append(r)
        return rec


def iter_jsonl(path: str) -> Iterator[schema.Record]:
    """Streaming reader (large sweeps)."""
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield schema.from_json_line(line)
