"""Telemetry: structured per-arrival update-quality diagnostics.

The paper's Section-5 evidence layer — who sent what, how stale it was,
how well it aligned with the outer momentum, how much the method
corrected, and what the per-language losses did — as a typed JSONL
stream emitted by the engines with ZERO extra Pallas launches per
arrival (the stats ride the fused packed sweeps as an extra output; see
``repro.telemetry.stats`` and docs/telemetry.md).

    from repro.telemetry import TelemetryRecorder
    rec = TelemetryRecorder()
    eng = make_engine(run_cfg, telemetry=rec)
    eng.run(...)
    rec.write_jsonl("results/telemetry/run.jsonl")
"""
from repro.telemetry.analysis import (          # noqa: F401
    language_spread, per_language_curves, per_language_final,
    staleness_alignment, summarize,
)
from repro.telemetry.recorder import (          # noqa: F401
    DEFAULT_WINDOW, TelemetryRecorder, iter_jsonl,
)
from repro.telemetry.schema import (            # noqa: F401
    SCHEMA_VERSION, ArrivalMetrics, EvalMetrics, FaultMetrics, FlushMetrics,
    RunMeta, RuntimeMetrics, StreamDecoder, TransportMetrics, from_json_line,
    to_json_line,
)
from repro.telemetry.stats import (             # noqa: F401
    MOMENT_FIELDS, N_MOMENTS, UpdateStats, momentum_only_moments,
    reference_moments, stats_from_moments,
)
