"""Update-quality statistics: the Section-5 diagnostics of one arrival.

The paper's analysis of HOW staleness and heterogeneity shape training
rests on four per-arrival scalars:

  cos_align       cosine(Delta, m) — alignment of the incoming
                  pseudo-gradient with the outer momentum direction;
  corrected_frac  ||g - Delta|| / ||Delta|| — how much mass the method's
                  correction moved (0 for identity methods);
  delta_norm      ||Delta||;
  momentum_norm   ||m||.

All four derive from four global MOMENTS ``[Delta.m, Delta.Delta, m.m,
|g - Delta|^2]`` (``g`` is the method's corrected gradient BEFORE the
arrival weight rho). On the packed fast path these moments come out of
the fused correct+outer sweep as an extra per-row output — ZERO extra
Pallas launches per arrival (see ``repro.kernels.packed._row_moments``
and the ``arrival_launches_packed_telemetry_*`` bench contracts); this
module holds the moment -> stats conversion and the per-leaf reference
implementation the kernel output is property-tested against.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# Moment vector layout (matches kernels.packed._row_moments columns).
MOMENT_FIELDS = ("dot_dm", "delta_sq", "mom_sq", "err_sq")
N_MOMENTS = len(MOMENT_FIELDS)


@dataclass(frozen=True)
class UpdateStats:
    """The derived per-arrival diagnostics (plain floats, JSON-ready)."""
    cos_align: float
    corrected_frac: float
    delta_norm: float
    momentum_norm: float


def stats_from_moments(moments) -> UpdateStats:
    """(4,) moments -> UpdateStats. Degenerate norms (dropped arrivals,
    zero momentum at t=0) yield 0 for the affected ratios."""
    dot, dd, mm, ee = (float(x) for x in np.asarray(moments).reshape(-1))
    dn = math.sqrt(max(dd, 0.0))
    mn = math.sqrt(max(mm, 0.0))
    cos = dot / (dn * mn) if dn > 0.0 and mn > 0.0 else 0.0
    frac = math.sqrt(max(ee, 0.0)) / dn if dn > 0.0 else 0.0
    return UpdateStats(cos_align=max(-1.0, min(1.0, cos)),
                       corrected_frac=frac,
                       delta_norm=dn, momentum_norm=mn)


def reference_moments(delta: PyTree, momentum: PyTree,
                      corrected: PyTree) -> jnp.ndarray:
    """Per-leaf reference for the kernel-side moments: (4,) fp32
    ``[Delta.m, Delta.Delta, m.m, |corrected - Delta|^2]`` summed over
    every leaf (``corrected`` is the method's unweighted g)."""
    def one(d, m, g):
        d = d.astype(jnp.float32).reshape(-1)
        m = m.astype(jnp.float32).reshape(-1)
        g = g.astype(jnp.float32).reshape(-1)
        e = g - d
        return jnp.stack([jnp.dot(d, m), jnp.dot(d, d), jnp.dot(m, m),
                          jnp.dot(e, e)])

    parts = jax.tree.leaves(jax.tree.map(one, delta, momentum, corrected))
    return jnp.sum(jnp.stack(parts), axis=0)


def reference_moments_multi(state, deltas, *, method, outer_lr, mu, h,
                            rhos, taus, phases=None,
                            stacked_axes=None) -> jnp.ndarray:
    """Per-leaf reference for the BATCHED kernel moments: (K, 4) fp32,
    slice j measured against the momentum as of application j (the
    momentum evolves between slices exactly as ``apply_arrivals`` evolves
    it). The multi-kernel with_stats output is property-tested against
    this for every registered method (tests/test_scale.py)."""
    from repro.core import heloco as _heloco
    from repro.core import methods as _methods
    m = _methods.resolve(method)
    k = len(deltas)
    phases = [None] * k if phases is None else list(phases)
    rows = []
    for delta, rho, tau, phase in zip(deltas, rhos, taus, phases):
        ctx = _methods.ArrivalCtx(outer_lr=outer_lr, mu=mu, h=h, rho=rho,
                                  tau=jnp.asarray(tau, jnp.float32),
                                  phase=phase, stacked_axes=stacked_axes)
        corrected = m.correct(m, ctx, delta, state.momentum)
        rows.append(reference_moments(delta, state.momentum, corrected))
        state = _heloco.apply_arrival(state, delta, method=m,
                                      outer_lr=outer_lr, mu=mu, h=h,
                                      rho=rho, tau=tau, phase=phase,
                                      stacked_axes=stacked_axes)
    return jnp.stack(rows)


def momentum_only_moments(momentum_sq) -> jnp.ndarray:
    """Moments of a suppressed (dropped) arrival: Delta = 0, so only the
    momentum norm is defined."""
    z = jnp.zeros((), jnp.float32)
    return jnp.stack([z, z, jnp.asarray(momentum_sq, jnp.float32), z])
