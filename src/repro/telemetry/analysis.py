"""Section-5 analyses over telemetry streams.

Pure functions from record lists (``repro.telemetry.schema``) to plain
dict/list artifacts — the sweep report generator renders these as
markdown, and tests assert on them directly.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

from repro.telemetry.schema import ArrivalMetrics, EvalMetrics


def _mean(xs: Sequence[float]) -> float:
    return sum(xs) / len(xs) if xs else 0.0


def staleness_alignment(arrivals: Sequence[ArrivalMetrics],
                        include_dropped: bool = False) -> List[Dict]:
    """Staleness -> update-quality curve (paper Fig. "alignment decays
    with staleness"): one point per observed staleness value with the
    mean cosine alignment and mean corrected-mass fraction."""
    by_tau: Dict[int, List[ArrivalMetrics]] = defaultdict(list)
    for a in arrivals:
        if a.cos_align is None or (a.dropped and not include_dropped):
            continue
        by_tau[a.staleness].append(a)
    return [{
        "staleness": tau,
        "n": len(group),
        "mean_cos_align": _mean([a.cos_align for a in group]),
        "mean_corrected_frac": _mean([a.corrected_frac for a in group]),
        "mean_delta_norm": _mean([a.delta_norm for a in group]),
    } for tau, group in sorted(by_tau.items())]


def per_language_curves(evals: Sequence[EvalMetrics]
                        ) -> Dict[str, List[Tuple[int, float]]]:
    """lang -> [(outer_step, loss), ...] (Fig. 3 per-language curves)."""
    out: Dict[str, List[Tuple[int, float]]] = defaultdict(list)
    for e in evals:
        for lang, loss in e.per_lang.items():
            out[lang].append((e.outer_step, loss))
    return dict(out)


def per_language_final(evals: Sequence[EvalMetrics]) -> Dict[str, float]:
    return dict(evals[-1].per_lang) if evals else {}


def language_spread(evals: Sequence[EvalMetrics]) -> Optional[float]:
    """max - min final per-language loss: the paper's fairness-under-
    non-IID summary number (lower = more even across languages)."""
    final = per_language_final(evals)
    if not final:
        return None
    return max(final.values()) - min(final.values())


def summarize(arrivals: Sequence[ArrivalMetrics],
              evals: Sequence[EvalMetrics]) -> Dict:
    """One-paragraph view of a stream (used by run_cached + the CLI)."""
    live = [a for a in arrivals if not a.dropped and a.cos_align is not None]
    return {
        "arrivals": len(arrivals),
        "dropped": sum(1 for a in arrivals if a.dropped),
        "mean_staleness": _mean([a.staleness for a in arrivals]),
        "mean_cos_align": _mean([a.cos_align for a in live]),
        "mean_corrected_frac": _mean([a.corrected_frac for a in live]),
        "final_mean_loss": evals[-1].mean_loss if evals else None,
        "language_spread": language_spread(evals),
        "tokens_total": arrivals[-1].tokens_total if arrivals else 0,
    }
