"""LR schedules (optax is unavailable offline; these are self-contained)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Linear warmup then cosine decay to final_frac*base_lr.
    Matches the inner schedule of Liu et al. 2024 (async local-SGD)."""
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac * base_lr + (1 - final_frac) * base_lr * 0.5 * (
        1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)


def constant(step, base_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), base_lr)
