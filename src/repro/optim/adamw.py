"""AdamW inner optimizer with global-norm clipping (pure JAX, pytree-based)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import InnerOptConfig
from repro.optim.schedules import constant, cosine_warmup

PyTree = Any


class AdamState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jnp.ndarray


def init_adam(params: PyTree) -> AdamState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamState(mu=jax.tree.map(z, params), nu=jax.tree.map(z, params),
                     count=jnp.zeros((), jnp.int32))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)


def adamw_update(params: PyTree, grads: PyTree, state: AdamState,
                 cfg: InnerOptConfig):
    """Returns (new_params, new_state)."""
    if cfg.grad_clip > 0:
        grads = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    sched = cosine_warmup if cfg.schedule == "cosine" else constant
    lr = sched(count, cfg.lr, warmup_steps=cfg.warmup_steps,
               total_steps=cfg.total_steps)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    mu2 = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                       state.mu, grads)
    nu2 = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        return (pf - lr * (step + cfg.weight_decay * pf)).astype(p.dtype)

    params2 = jax.tree.map(upd, params, mu2, nu2)
    return params2, AdamState(mu=mu2, nu=nu2, count=count)
