"""Inner (worker-local) training loop: H AdamW steps from a look-ahead
initialization, producing a pseudo-gradient (paper Eq. 2-3)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InnerOptConfig, ModelConfig
from repro.models import Model
from repro.optim.adamw import AdamState, adamw_update, init_adam

PyTree = Any


class InnerResult(NamedTuple):
    params: PyTree
    opt: AdamState
    losses: jnp.ndarray       # (H,)


@functools.lru_cache(maxsize=32)
def _jitted_step(model: Model, inner_cfg: InnerOptConfig) -> Callable:
    def step(params, opt, batch):
        (loss, _aux), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        params, opt = adamw_update(params, grads, opt, inner_cfg)
        return params, opt, loss
    return jax.jit(step, donate_argnums=(0, 1))


def run_inner(model: Model, inner_cfg: InnerOptConfig, params: PyTree,
              opt: AdamState, sampler, h_steps: int,
              step_offset: int = 0) -> InnerResult:
    """H local steps; data drawn from `sampler.sample(step)` per step."""
    step_fn = _jitted_step(model, inner_cfg)
    # the caller keeps theta_bar for the pseudo-gradient; the jitted step
    # donates its params buffer, so work on a copy.
    params = jax.tree.map(jnp.copy, params)
    opt = jax.tree.map(jnp.copy, opt)
    losses = []
    for h in range(h_steps):
        batch = jax.tree.map(jnp.asarray, sampler.sample(step_offset + h))
        params, opt, loss = step_fn(params, opt, batch)
        losses.append(loss)
    return InnerResult(params=params, opt=opt, losses=jnp.stack(losses))


def pseudo_gradient(theta_init: PyTree, theta_final: PyTree) -> PyTree:
    """Delta = theta_bar - theta_H  (descent displacement, Eq. 3)."""
    return jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) - b.astype(jnp.float32)),
        theta_init, theta_final)


def eval_loss(model: Model, params: PyTree, batch: Dict) -> float:
    loss, _ = jax.jit(lambda p, b: model.loss(p, b))(
        params, jax.tree.map(jnp.asarray,
                             {k: v for k, v in batch.items() if k != "lang"}))
    return float(loss)
