"""Abstract input construction for the dry-run: ShapeDtypeStruct stand-ins
for every model input / state (weak-type-correct, shardable, no device
allocation)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model

PyTree = Any
S = jax.ShapeDtypeStruct


def batch_specs_struct(cfg: ModelConfig, batch: int, seq: int,
                       with_labels: bool = True) -> Dict[str, S]:
    """Abstract train/prefill batch for one DiLoCo worker (pod)."""
    emb = jnp.dtype(cfg.compute_dtype)
    out: Dict[str, S] = {}
    if cfg.frontend.kind == "audio":
        out["features"] = S((batch, seq, cfg.d_model), emb)
        if with_labels:
            out["labels"] = S((batch, seq), jnp.int32)
        return out
    if cfg.frontend.kind == "vision":
        npfx = cfg.frontend.n_prefix_tokens
        out["patches"] = S((batch, npfx, cfg.d_model), emb)
        out["tokens"] = S((batch, seq - npfx), jnp.int32)
        if with_labels:
            out["labels"] = S((batch, seq - npfx), jnp.int32)
        return out
    out["tokens"] = S((batch, seq), jnp.int32)
    if with_labels:
        out["labels"] = S((batch, seq), jnp.int32)
    return out


def abstract_params(cfg: ModelConfig) -> PyTree:
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_caches(cfg: ModelConfig, batch: int, cache_len: int) -> PyTree:
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_caches(batch, cache_len))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All abstract inputs for an (arch x shape) dry-run cell."""
    if shape.kind == "train":
        return {"batch": batch_specs_struct(cfg, shape.global_batch,
                                            shape.seq_len)}
    if shape.kind == "prefill":
        return {"batch": batch_specs_struct(cfg, shape.global_batch,
                                            shape.seq_len, with_labels=False)}
    # decode: one new token against a cache of seq_len
    return {
        "token": S((shape.global_batch,), jnp.int32),
        "pos": S((), jnp.int32),
        "caches": abstract_caches(cfg, shape.global_batch, shape.seq_len),
    }
