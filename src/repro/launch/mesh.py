"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256-class).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is the
DiLoCo worker boundary — inner training never communicates across it, the
HeLoCo outer exchange is the only traffic it carries.

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small-device-count variant for unit tests (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)
