"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256-class).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is the
DiLoCo worker boundary — inner training never communicates across it, the
HeLoCo outer exchange is the only traffic it carries.

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

import contextlib

import jax


def _mesh(shape, axes):
    # jax >= 0.6 takes axis_types (Auto lets GSPMD infer intermediate
    # shardings); 0.4.x has neither the kwarg nor the enum — its meshes
    # are implicitly auto.
    axis_type = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type,) * len(axes))
    return jax.make_mesh(shape, axes)


def mesh_context(mesh):
    """Ambient-mesh context across jax versions: ``jax.set_mesh`` (>= 0.6)
    or the ``Mesh`` context manager (0.4.x) — both make bare
    ``PartitionSpec`` constraints resolve against ``mesh``."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh if hasattr(mesh, "__enter__") else contextlib.nullcontext()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small-device-count variant for unit tests (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)
