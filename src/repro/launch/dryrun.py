import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, record memory analysis, cost analysis, and the collective
schedule. This proves the distribution config is coherent without real
hardware; EXPERIMENTS.md reads the JSON artifacts written here.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out results/dryrun] [--probes]
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, ASSIGNED, SHAPES, get_config
from repro.configs.base import (
    HeLoCoConfig, InnerOptConfig, ModelConfig, ShapeConfig, shape_applicable,
)
from repro.optim.adamw import AdamState
from repro.dist import sharding as shd
from repro.dist.steps import (
    init_train_state, make_decode_step, make_multipod_train_step,
    make_outer_exchange, make_prefill_step, make_train_step,
)
from repro.launch.inputs import abstract_params, input_specs
from repro.launch.mesh import make_production_mesh
from repro.utils.hlo import (collective_stats, group_size_histogram,
                             total_wire_bytes)

INNER = InnerOptConfig()


# --------------------------------------------------------------------------
# Per-cell execution plan (baseline; Perf iterations override via --plan)
# --------------------------------------------------------------------------

GRAD_ACCUM = {
    "zamba2-2.7b": 8, "qwen2-7b": 4, "granite-3-8b": 8, "command-r-35b": 8,
    "starcoder2-15b": 8, "granite-moe-1b-a400m": 4, "llama4-scout-17b-a16e": 8,
    "hubert-xlarge": 2, "xlstm-125m": 4, "paligemma-3b": 2,
}
Q_CHUNK = {"train": 512, "prefill": 256, "decode": 0}


def plan_for(arch: str, shape: ShapeConfig, overrides: Optional[Dict] = None
             ) -> Dict[str, Any]:
    plan = {
        "grad_accum": GRAD_ACCUM.get(arch, 4) if shape.kind == "train" else 1,
        "q_chunk": Q_CHUNK[shape.kind] or 128,
    }
    if overrides:
        plan.update(overrides)
    return plan


# --------------------------------------------------------------------------
# Cell lowering
# --------------------------------------------------------------------------

def _state_shardings(pspecs, mesh, *, pod_prefix: bool = False):
    """Sharding tree for TrainState given param PartitionSpecs."""
    rep = NamedSharding(mesh, P(*(("pod",) if pod_prefix else ())))

    def sh(spec):
        entries = ("pod",) + tuple(spec) if pod_prefix else tuple(spec)
        return NamedSharding(mesh, P(*entries))

    psh = jax.tree.map(sh, pspecs, is_leaf=lambda x: isinstance(x, P))
    from repro.dist.steps import TrainState
    return TrainState(params=psh,
                      opt=AdamState(mu=psh, nu=psh, count=rep),
                      step=rep)


def _analyze(lowered, compiled, seconds: float) -> Dict[str, Any]:
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    coll = collective_stats(text)
    return {
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "collective_group_sizes": group_size_histogram(text),
        "wire_bytes_per_device": total_wire_bytes(coll),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
            "code_bytes": ma.generated_code_size_in_bytes,
        },
        "compile_seconds": seconds,
    }


def lower_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool,
               overrides: Optional[Dict] = None, unroll: bool = False,
               cfg: Optional[ModelConfig] = None) -> Dict[str, Any]:
    """Lower + compile one cell on `mesh`. Returns the analysis record."""
    import dataclasses
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    plan = plan_for(arch, shape, overrides)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_axes = ("pod", "data") if multi_pod else ("data",)
    # activation sharding hints: batch dim over data within the pod;
    # plan knobs: remat_group (k-th-layer checkpointing), head_tp
    # (pin attention-head TP on activations).
    cfg = dataclasses.replace(
        cfg, act_batch_axes=("data",),
        act_model_axis=("model" if plan.get("head_tp") else ""),
        seq_parallel=bool(plan.get("seq_parallel")),
        remat_group=int(plan.get("remat_group", 1)))
    if cfg.is_moe and (plan.get("moe_group") or plan.get("moe_dispatch")
                       or plan.get("moe_vmap")):
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe,
                group_size=int(plan.get("moe_group", cfg.moe.group_size)),
                group_mode=("vmap" if plan.get("moe_vmap")
                            else cfg.moe.group_mode),
                dispatch=plan.get("moe_dispatch", cfg.moe.dispatch)))

    params_sds = abstract_params(cfg)
    pspecs = shd.param_specs(
        params_sds, axis_sizes=axis_sizes,
        attn_style=("dp" if plan.get("attn_dp") else "tp"))
    psh = shd.shardings_of(pspecs, mesh)
    ins = input_specs(cfg, shape)
    t0 = time.time()

    with jax.set_mesh(mesh):
        if shape.kind == "train":
            state_sds = jax.eval_shape(init_train_state, params_sds)
            if multi_pod:
                # per-pod replica: leading pod axis on every leaf
                step = make_multipod_train_step(
                    cfg, INNER, mesh, grad_accum=plan["grad_accum"],
                    q_chunk=plan["q_chunk"], unroll=unroll,
                    param_pspecs=pspecs)
                add_pod = lambda t: jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct((2,) + x.shape, x.dtype), t)
                state_sds = add_pod(state_sds)
                batch_sds = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct((2,) + x.shape, x.dtype),
                    ins["batch"])
                state_sh = _state_shardings(pspecs, mesh, pod_prefix=True)
                bspecs = shd.batch_specs(ins["batch"], batch_axes=("data",))
                bsh = jax.tree.map(
                    lambda s: NamedSharding(mesh, P("pod", *tuple(s))),
                    bspecs, is_leaf=lambda x: isinstance(x, P))
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, bsh),
                    out_shardings=(state_sh,
                                   NamedSharding(mesh, P("pod"))),
                    donate_argnums=(0,),
                ).lower(state_sds, batch_sds)
            else:
                state_sh = _state_shardings(pspecs, mesh)
                step = make_train_step(cfg, INNER,
                                       grad_accum=plan["grad_accum"],
                                       q_chunk=plan["q_chunk"], unroll=unroll,
                                       param_pspecs=pspecs)
                bspecs = shd.batch_specs(ins["batch"], batch_axes=data_axes)
                bsh = shd.shardings_of(bspecs, mesh)
                lowered = jax.jit(
                    step,
                    in_shardings=(state_sh, bsh),
                    out_shardings=(state_sh, NamedSharding(mesh, P())),
                    donate_argnums=(0,),
                ).lower(state_sds, ins["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, cache_len=shape.seq_len,
                                     q_chunk=plan["q_chunk"], unroll=unroll)
            bspecs = shd.batch_specs(ins["batch"], batch_axes=data_axes)
            bsh = shd.shardings_of(bspecs, mesh)
            lowered = jax.jit(step, in_shardings=(psh, bsh)).lower(
                params_sds, ins["batch"])
        else:  # decode
            step = make_decode_step(cfg)
            caches = ins["caches"]
            batch_sharded = shape.global_batch >= axis_sizes.get("data", 1)
            data_axis = data_axes if multi_pod else "data"
            cspecs = shd.cache_specs(
                caches, batch_sharded=batch_sharded, axis_sizes=axis_sizes,
                data_axis=data_axis)
            csh = shd.shardings_of(cspecs, mesh)
            tok_spec = (P(data_axes) if batch_sharded else P())
            lowered = jax.jit(
                step,
                in_shardings=(psh, NamedSharding(mesh, tok_spec), csh,
                              NamedSharding(mesh, P())),
            ).lower(params_sds, ins["token"], caches, ins["pos"])
        compiled = lowered.compile()
    rec = _analyze(lowered, compiled, time.time() - t0)
    rec.update(arch=arch, shape=shape_name, kind=shape.kind,
               mesh="multi" if multi_pod else "single", plan=plan,
               n_devices=mesh.devices.size)
    return rec


def lower_outer_exchange(arch: str, mesh, *, compress_int8: bool = False,
                         method: str = "heloco") -> Dict[str, Any]:
    """Lower the HeLoCo outer round (the paper's step) on the multi-pod mesh."""
    cfg = get_config(arch)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    params_sds = abstract_params(cfg)
    stacked = shd.stacked_axes_tree(params_sds)
    pspecs = shd.param_specs(params_sds, axis_sizes=axis_sizes)
    psh = shd.shardings_of(pspecs, mesh)
    pod_sh = jax.tree.map(lambda s: NamedSharding(mesh, P("pod", *tuple(s))),
                          pspecs, is_leaf=lambda x: isinstance(x, P))
    t0 = time.time()
    with jax.set_mesh(mesh):
        fn = make_outer_exchange(
            cfg, mesh, h=HeLoCoConfig(),
            outer_lr=0.7, mu=0.9, method=method, arriving_pod=0,
            stacked_axes=stacked, compress_int8=compress_int8)
        mom_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), params_sds)
        wp_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((2,) + x.shape, x.dtype), params_sds)
        lowered = jax.jit(
            fn, in_shardings=(psh, psh, pod_sh),
            out_shardings=(psh, psh, pod_sh),
        ).lower(params_sds, mom_sds, wp_sds)
        compiled = lowered.compile()
    rec = _analyze(lowered, compiled, time.time() - t0)
    rec.update(arch=arch, shape="outer_exchange", kind="outer",
               mesh="multi", plan={"compress_int8": compress_int8,
                                   "method": method},
               n_devices=mesh.devices.size)
    return rec


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--outer-exchange", action="store_true",
                    help="also lower the HeLoCo outer round per arch (multi)")
    ap.add_argument("--compress-int8", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--plan", default=None,
                    help='JSON plan overrides, e.g. \'{"grad_accum":1,'
                         '"remat_group":4,"head_tp":true}\'')
    ap.add_argument("--tag", default="",
                    help="suffix for output files (perf iterations)")
    args = ap.parse_args()
    overrides = json.loads(args.plan) if args.plan else None

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = shape_applicable(cfg, SHAPES[shape_name])
            for multi in meshes:
                tag = (f"{arch}__{shape_name}__"
                       f"{'multi' if multi else 'single'}"
                       + (f"__{args.tag}" if args.tag else ""))
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    rec = json.load(open(path))
                    if "error" not in rec:
                        print(f"HAVE {tag}", flush=True)
                        continue
                if not ok:
                    rec = {"arch": arch, "shape": shape_name, "skipped": why,
                           "mesh": "multi" if multi else "single"}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"SKIP {tag}: {why}", flush=True)
                    continue
                try:
                    mesh = make_production_mesh(multi_pod=multi)
                    rec = lower_cell(arch, shape_name, mesh, multi_pod=multi,
                                     overrides=overrides)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    mem = rec["memory"]["peak_estimate_bytes"] / 2**30
                    print(f"OK   {tag}: {rec['compile_seconds']:.1f}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"peak/dev={mem:.2f}GiB "
                          f"wire/dev={rec['wire_bytes_per_device']:.3e}B",
                          flush=True)
                except Exception as e:
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape_name,
                                   "mesh": "multi" if multi else "single",
                                   "error": repr(e)}, f, indent=1)
                    print(f"FAIL {tag}: {e!r}", flush=True)
                    traceback.print_exc()
        if args.outer_exchange:
            tag = f"{arch}__outer_exchange__multi"
            try:
                mesh = make_production_mesh(multi_pod=True)
                rec = lower_outer_exchange(arch, mesh,
                                           compress_int8=args.compress_int8)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"OK   {tag}: wire/dev={rec['wire_bytes_per_device']:.3e}B",
                      flush=True)
            except Exception as e:
                print(f"FAIL {tag}: {e!r}", flush=True)
                traceback.print_exc()


if __name__ == "__main__":
    main()
