"""Training launcher.

Two ways to describe a run:

  - ``--scenario NAME``: a registered ``repro.scenarios`` spec — the same
    single source of truth the benchmarks, examples, and golden-trace CI
    gate build from (``--list-scenarios`` enumerates them).
  - ad-hoc flags: compiled into an anonymous ``Scenario`` first, so both
    paths construct the run identically.

Engines (--engine):
  - sim (default): the asynchronous HeLoCo training engine with
    heterogeneous virtual-clock workers — the paper's experiment runtime.
    Any --arch is accepted; pass --smoke to use its reduced config on CPU.
  - wallclock: the threaded concurrent runtime — one thread per worker,
    pseudo-gradients through a bounded transport, genuine compute/update
    overlap. Deterministic (simulator-equivalent) by default; add --free
    for true arrival order with --pace-scale wall-clock throttling.

For the production-mesh lower/compile pass defer to repro.launch.dryrun
(see that module's CLI).

    PYTHONPATH=src python -m repro.launch.train --arch tinygpt-15m --smoke \
        --method heloco --paces 1,1,6,6,6 --outer 50 --inner 10 \
        --engine wallclock --ckpt-dir /tmp/ck --resume
    PYTHONPATH=src python -m repro.launch.train --scenario paper_hetero_severe
"""
from __future__ import annotations

import argparse

from repro.checkpoint import ckpt as ckpt_lib
from repro.core import methods as outer_methods
from repro.async_engine.engine import make_engine, make_eval_fn
from repro.async_engine.faults import FaultSpec
from repro.scenarios import registry
from repro.scenarios.spec import Scenario

# --chaos preset: the docs/faults.md lossy channel (chaos_lossy's fault
# mix) keyed off the run seed — a quick way to smoke any wallclock run
# against an unreliable delivery layer.
def _chaos_faults(seed: int) -> FaultSpec:
    return FaultSpec(drop_p=0.2, dup_p=0.1, reorder_p=0.2,
                     delay_p=0.1, delay_s=0.01, ack_drop_p=0.05,
                     seed=seed + 97)


def scenario_from_args(args) -> Scenario:
    """Compile the launcher's flag dialect into a Scenario."""
    paces = tuple(float(p) for p in args.paces.split(","))
    outer_lr = args.outer_lr
    cap = outer_methods.get(args.method).outer_lr_cap
    if outer_lr is not None and cap is not None:
        outer_lr = min(outer_lr, cap)
    return Scenario(
        name="cli",
        arch=args.arch, smoke=args.smoke,
        engine=args.engine,
        mode="free" if args.free else "deterministic",
        pace_scale=args.pace_scale,
        transport=getattr(args, "transport", "inproc"),
        topology=getattr(args, "topology", "hub"),
        n_workers=args.workers, worker_paces=paces,
        inner_steps=args.inner, outer_steps=args.outer,
        batch_size=args.batch, seq_len=args.seq,
        non_iid=not args.iid, mixture_alpha=args.mixture_alpha,
        shard_assignment=args.shard_assignment, dylu=args.dylu,
        method=args.method, outer_lr=outer_lr, momentum=args.momentum,
        compression=args.compression,
        drop_stale_after=args.drop_stale_after,
        inner_lr=args.inner_lr, seed=args.seed,
        commit_batch=getattr(args, "commit_batch", 1),
        faults=(_chaos_faults(args.seed)
                if getattr(args, "chaos", False) else None))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="",
                    help="run a registered scenario by name (overrides the "
                         "ad-hoc config flags)")
    ap.add_argument("--list-scenarios", action="store_true")
    ap.add_argument("--arch", default="tinygpt-15m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--method", default="heloco",
                    choices=outer_methods.cli_names(),
                    help="any registered repro.core.methods name or "
                         "benchmark-dialect alias")
    ap.add_argument("--workers", type=int, default=5)
    ap.add_argument("--paces", default="1,1,1,1,1")
    ap.add_argument("--outer", type=int, default=50)
    ap.add_argument("--inner", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--mixture-alpha", type=float, default=None,
                    help="per-worker Dirichlet(alpha) language mixtures "
                         "instead of one shard per worker")
    ap.add_argument("--dylu", action="store_true")
    ap.add_argument("--outer-lr", type=float, default=None,
                    help="default: the method's paper value (Table 3)")
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--inner-lr", type=float, default=3e-3)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--drop-stale-after", type=int, default=None)
    ap.add_argument("--shard-assignment", default="fixed",
                    choices=["fixed", "flexible"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--eval-every", type=int, default=None,
                    help="default: 10, or the scenario's golden-trace "
                         "cadence when --scenario is given")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", default="", metavar="PATH",
                    help="stream per-arrival update-quality telemetry "
                         "(repro.telemetry JSONL) to this path, written "
                         "live (per-record flush) so `python -m repro.obs "
                         "console PATH` can tail the run")
    ap.add_argument("--telemetry-every", type=int, default=None,
                    metavar="N",
                    help="emit a runtime-health telemetry record every N "
                         "commits (default 1 when --telemetry is set, "
                         "else the scenario's telemetry_every)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="profile the run with trace spans and export "
                         "Chrome trace-event JSON (Perfetto-loadable) "
                         "to this path")
    ap.add_argument("--stats-json", default="", metavar="PATH",
                    help="dump the runtime stats_summary() as JSON at "
                         "exit (machine-readable CI artifact)")
    ap.add_argument("--engine", default="sim", choices=["sim", "wallclock"])
    ap.add_argument("--transport", default="inproc",
                    choices=["inproc", "socket"],
                    help="wallclock engine backend: threaded workers over "
                         "the in-process queue, or real worker processes "
                         "over the socket transport")
    ap.add_argument("--topology", default="hub",
                    choices=["hub", "ring", "gossip"],
                    help="exchange topology: hub-and-spoke server, or "
                         "decentralized NoLoCo-style ring/gossip peer "
                         "averaging (async methods only)")
    ap.add_argument("--commit-batch", type=int, default=1,
                    help="server commit-buffer size (docs/scale.md): >1 "
                         "coalesces up to K arrivals into one fused "
                         "flush; flush depth/reason telemetry lands in "
                         "the stream's 'flush' records")
    ap.add_argument("--free", action="store_true",
                    help="wallclock engine: free-running arrival order "
                         "instead of the deterministic simulator schedule")
    ap.add_argument("--pace-scale", type=float, default=0.0,
                    help="wallclock+free: wall seconds per virtual second "
                         "of worker pace (0 = no throttling)")
    ap.add_argument("--chaos", action="store_true",
                    help="wallclock engine: inject the docs/faults.md "
                         "lossy-channel preset (20%% drop, 10%% dup, 20%% "
                         "reorder, delays, lost acks); the at-least-once "
                         "delivery layer must absorb it")
    args = ap.parse_args()
    if args.chaos and args.engine != "wallclock":
        ap.error("--chaos needs --engine wallclock (the simulator has no "
                 "transport to inject faults into)")
    if args.transport == "socket" and args.engine != "wallclock":
        ap.error("--transport socket needs --engine wallclock (the "
                 "simulator has no worker processes)")

    if args.list_scenarios:
        for s in registry.all_scenarios():
            print(f"{s.name:24s} engine={s.engine}/{s.mode}  "
                  f"{s.description}")
        return

    if args.scenario:
        scn = registry.get_scenario(args.scenario)
        if args.transport != "inproc" and scn.engine == "wallclock":
            scn = scn.overridden(transport=args.transport)
        if args.commit_batch > 1:
            scn = scn.overridden(commit_batch=args.commit_batch)
        print(f"scenario {scn.name}: {scn.description}")
    else:
        scn = scenario_from_args(args)
    # match the golden-trace eval cadence so a --scenario run is
    # comparable with its committed results/golden/<name>.json artifact
    eval_every = (args.eval_every if args.eval_every is not None
                  else (scn.eval_cadence if args.scenario else 10))
    recorder = None
    if args.telemetry:
        from repro.telemetry import TelemetryRecorder
        recorder = TelemetryRecorder(sink=args.telemetry)
    tracer = None
    if args.trace:
        from repro.obs.spans import SpanTracer
        tracer = SpanTracer()
    # runtime-health cadence: explicit flag > "on" whenever telemetry is
    # streamed > the scenario's own telemetry_every knob
    runtime_every = (args.telemetry_every
                     if args.telemetry_every is not None
                     else (1 if args.telemetry else None))
    eng = make_engine(scn, telemetry=recorder, tracer=tracer,
                      runtime_record_every=runtime_every)
    if args.resume and args.ckpt_dir:
        latest = ckpt_lib.latest(args.ckpt_dir)
        if latest:
            eng.restore(latest)
            print(f"resumed from {latest} (outer step {eng.server.t})")

    eval_fn = make_eval_fn(eng, batch=scn.eval_batch)
    hist = eng.run(eval_every=eval_every, eval_fn=eval_fn,
                   ckpt_every=args.ckpt_every if args.ckpt_dir else 0,
                   ckpt_dir=args.ckpt_dir)
    for e in hist.evals:
        print(f"step {e['step']:5d}  t={e['time']:8.0f}s  "
              f"loss={e['mean']:.4f}")
    taus = [a["staleness"] for a in hist.arrivals] or [0]
    print(f"done: arrivals={len(hist.arrivals)} tokens={hist.tokens} "
          f"mean_staleness={sum(taus) / len(taus):.2f} "
          f"comm={hist.comm_bytes / 1e6:.1f}MB")
    # cross-process collection contract: on the socket transport with any
    # observability output requested, a worker process that never shipped
    # an obs frame means the collection path is broken — fail loudly
    # instead of writing a parent-only trace/stats/stream (satellite of
    # docs/observability.md, "Cross-process collection")
    if ((args.trace or args.stats_json or args.telemetry)
            and hasattr(eng, "assert_child_reports")):
        eng.assert_child_reports()
    if hasattr(eng, "stats_summary"):
        s = eng.stats_summary()
        print(f"runtime[{s['mode']}]: {s['arrivals_per_sec']:.2f} arrivals/s "
              f"occupancy={s['server_occupancy']:.2f} "
              f"parallelism={s['compute_parallelism']:.2f} "
              f"overlap_max={s['overlap_max']}")
        d = s.get("delivery", {})
        if any(d.values()):
            hot = {k: v for k, v in d.items() if v}
            print(f"delivery: {hot}")
    if args.stats_json:
        import json
        import os
        summary = (eng.stats_summary() if hasattr(eng, "stats_summary")
                   else {"arrivals": len(hist.arrivals),
                         "tokens": hist.tokens,
                         "comm_bytes": hist.comm_bytes,
                         "mean_staleness": sum(taus) / len(taus)})
        os.makedirs(os.path.dirname(args.stats_json) or ".",
                    exist_ok=True)
        with open(args.stats_json, "w") as f:
            json.dump(summary, f, indent=2, sort_keys=True, default=str)
        print(f"stats -> {args.stats_json}")
    if recorder is not None:
        recorder.close()       # stream already on disk, live-flushed
        t = recorder.summary()
        print(f"telemetry -> {args.telemetry}: {t['arrivals']} arrivals "
              f"mean_cos={t['mean_cos_align']:.3f} "
              f"mean_corrected_frac={t['mean_corrected_frac']:.3f}")
    if tracer is not None:
        path = tracer.write(args.trace)
        print(f"trace -> {path}: {len(tracer)} events (load in "
              f"https://ui.perfetto.dev or chrome://tracing)")


if __name__ == "__main__":
    main()
