"""Decentralized exchange topologies: NoLoCo-style ring / gossip mixing.

The hub-and-spoke ``Synchronizer`` applies every pseudo-gradient to ONE
shared outer state. NoLoCo (arXiv 2506.10911) removes the hub: each
worker keeps its own model replica, applies its own outer step locally,
and then averages parameters (and outer momentum) with ONE sampled peer
— no all-reduce, no coordinator, communication cost O(1) per round
regardless of the worker count. ``PeerMixer`` implements that exchange
behind the exact ``Synchronizer`` surface the engines consume
(``worker_init`` / ``on_arrival`` / ``state`` / ``t`` /
``set_n_workers``), so *topology* becomes a scenario axis
(``Scenario.topology``: "hub" | "ring" | "gossip") orthogonal to the
engine, the transport, and the outer method grid — one golden-traced
run semantics across the simulator, the threaded runtime, and the
multi-process socket backend.

Peer sampling is deterministic — a pure function of ``(seed, outer_step,
wid)`` over the sorted replica set (the same splitmix64 dice as the
fault injector) — so a gossip run is exactly replayable across engines
and process boundaries:

  ring    each arrival averages with the next live wid in sorted cyclic
          order (a directed ring);
  gossip  each arrival averages with a uniformly-hashed random peer.

Per-replica outer update (Nesterov flavour, matching the repo's
``nesterov`` outer method):

  m_i <- mu * m_i + Delta_i
  p_i <- p_i - eta * (Delta_i + mu * m_i)
  (p_i, m_i), (p_j, m_j) <- pairwise mean with the sampled peer j

The global ``state`` view (evals, checkpoints, golden param digests) is
the mean over replicas, computed on demand and cached between arrivals.
``state``-setter broadcasts (a checkpoint restore resets every replica
to the checkpoint — real-world restore semantics). Stale-drop
(``drop_stale_after``) skips both the local step and the mix for that
arrival. Limitations (asserted in ``Scenario``): async methods only (no
sync barrier), hub-only method machinery (delayed-Nesterov buffers,
DC-ASGD compensation) does not participate — the method's outer_lr /
momentum are reused as the per-replica step.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.async_engine.faults import _unit
from repro.async_engine.server import ArrivalRecord
from repro.configs.base import OuterOptConfig
from repro.core import methods as outer_methods
from repro.core.heloco import OuterState

PyTree = Any

TOPOLOGIES = ("hub", "ring", "gossip")

_S_PEER = 101                        # splitmix64 stream salt for peer dice


class PeerMixer:
    """Hub-less synchronizer: per-worker replicas + pairwise peer
    averaging. Duck-types the ``Synchronizer`` surface the engines use."""

    #: engines read these to pick the commit / block_until_ready path
    packed = False
    layout = None

    def __init__(self, init_params: PyTree, cfg: OuterOptConfig,
                 n_workers: int, *, kind: str = "gossip", seed: int = 0):
        assert kind in ("ring", "gossip"), kind
        self.cfg = cfg
        self.kind = kind
        self.seed = seed
        self.method = outer_methods.resolve(cfg.method)
        assert not self.method.sync, \
            "decentralized topologies have no barrier; use an async method"
        self.n_workers = n_workers
        self.records: List[ArrivalRecord] = []
        self._committed: Dict[Any, ArrivalRecord] = {}
        self._pending_buf: List[Any] = []
        self._init_params = init_params
        self._p: Dict[int, PyTree] = {}          # wid -> replica params
        self._m: Dict[int, PyTree] = {}          # wid -> replica momentum
        self._t = 0
        self._mean_cache: Optional[OuterState] = None
        lr, mu = cfg.outer_lr, cfg.momentum

        def _local(p, m, delta):
            m2 = jax.tree.map(
                lambda mm, dd: mu * mm + dd.astype(jnp.float32), m, delta)
            p2 = jax.tree.map(
                lambda pp, dd, mm: pp - lr * (dd.astype(jnp.float32)
                                              + mu * mm),
                p, delta, m2)
            return p2, m2

        self._local = jax.jit(_local)
        self._mix = jax.jit(
            lambda a, b: jax.tree.map(lambda x, y: (x + y) * 0.5, a, b))

    # -- replica management ---------------------------------------------------
    def _ensure_replica(self, wid: int):
        if wid not in self._p:
            # a replica born mid-run (elastic join) starts from the
            # current global mean — the same semantics as the hub
            self._p[wid] = (self._mean_params() if self._p
                            else self._init_params)
            self._m[wid] = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), self._p[wid])
            self._mean_cache = None

    def worker_init(self, wid: Optional[int] = None) -> PyTree:
        if wid is None:
            return self.state.params
        self._ensure_replica(wid)
        return self._p[wid]

    # -- peer sampling (deterministic in (seed, t, wid)) -----------------------
    def _pick_peer(self, wid: int) -> Optional[int]:
        others = sorted(w for w in self._p if w != wid)
        if not others:
            return None
        if self.kind == "ring":
            nxt = [w for w in others if w > wid]
            return nxt[0] if nxt else others[0]
        idx = int(_unit(self.seed, _S_PEER, self._t, wid) * len(others))
        return others[min(idx, len(others) - 1)]

    # -- state view (mean over replicas) ---------------------------------------
    def _mean_params(self) -> PyTree:
        reps = [self._p[w] for w in sorted(self._p)]
        n = float(len(reps))
        return jax.tree.map(lambda *xs: sum(xs) / n, *reps)

    @property
    def state(self) -> OuterState:
        if self._mean_cache is None:
            if not self._p:
                params = self._init_params
                mom = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
            else:
                params = self._mean_params()
                reps = [self._m[w] for w in sorted(self._m)]
                n = float(len(reps))
                mom = jax.tree.map(lambda *xs: sum(xs) / n, *reps)
            self._mean_cache = OuterState(
                params=params, momentum=mom,
                step=jnp.asarray(self._t, jnp.int32), aux=None)
        return self._mean_cache

    @state.setter
    def state(self, value: OuterState):
        # restore semantics: every replica resets to the checkpoint
        self._init_params = value.params
        for wid in self._p:
            self._p[wid] = value.params
            self._m[wid] = value.momentum
        self._t = int(value.step)
        self._mean_cache = None

    @property
    def t(self) -> int:
        return self._t

    # -- arrival processing -----------------------------------------------------
    def on_arrival(self, delta: PyTree, s_i: int, worker_id: int,
                   sim_time: float = 0.0, lang: str = "",
                   commit_key=None) -> ArrivalRecord:
        if commit_key is not None:
            prior = self._committed.get(commit_key)
            if prior is not None:
                return prior
        self._ensure_replica(worker_id)
        tau = self._t - s_i
        dropped = (self.cfg.drop_stale_after is not None
                   and tau > self.cfg.drop_stale_after)
        if not dropped:
            p2, m2 = self._local(self._p[worker_id], self._m[worker_id],
                                 delta)
            peer = self._pick_peer(worker_id)
            if peer is not None:
                p2 = self._mix(p2, self._p[peer])
                m2 = self._mix(m2, self._m[peer])
                self._p[peer], self._m[peer] = p2, m2
            self._p[worker_id], self._m[worker_id] = p2, m2
        self._t += 1
        self._mean_cache = None
        rec = ArrivalRecord(outer_step=self._t, worker_id=worker_id,
                            staleness=tau, rho=1.0, sim_time=sim_time,
                            lang=lang, dropped=dropped)
        self.records.append(rec)
        if commit_key is not None:
            self._committed[commit_key] = rec
        return rec

    # -- batched arrival surface (docs/scale.md) --------------------------------
    # Peer mixing is order-dependent (each commit rewrites two replicas),
    # so there is no fused multi-apply here: the commit-buffer API is
    # honoured with the exact sequential semantics, keeping the engines'
    # batched loop topology-agnostic.
    @property
    def pending(self) -> int:
        return len(self._pending_buf)

    def buffer_arrival(self, delta: PyTree, s_i: int, worker_id: int,
                       sim_time: float = 0.0, lang: str = "",
                       commit_key=None) -> Optional[List[ArrivalRecord]]:
        self._pending_buf.append((delta, s_i, worker_id, sim_time, lang,
                                  commit_key))
        return None

    def flush(self) -> List[ArrivalRecord]:
        pending, self._pending_buf = self._pending_buf, []
        return [self.on_arrival(*args) for args in pending]

    def on_sync_round(self, deltas, sim_time: float = 0.0):
        raise RuntimeError("decentralized topologies have no sync barrier")

    def set_n_workers(self, n: int):
        self.n_workers = n
