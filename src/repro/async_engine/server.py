"""The synchronizer (outer-optimizer server) for asynchronous
low-communication training.

Owns the outer state (theta, momentum, step counter, optional per-method
auxiliary buffer), hands out worker initializations (Eq. 5 look-ahead for
methods that participate), and processes arriving pseudo-gradients
through the configured ``repro.core.methods`` definition — correction,
staleness bookkeeping, arrival weighting, and optional stale-update
dropping (App. A.6) are all method-agnostic here.

Arrival fast path (default): the outer state lives PACKED — params and
momentum are flattened once at init into fp32 (R, 128) buffers (see
``repro.core.packing``), every arrival donates and rewrites those buffers
through the two fused packed kernels (O(1) launches per arrival instead of
O(#leaves)), and the pytree view is materialised only on demand for
``worker_init`` / eval / checkpointing. Pass ``packed=False`` to keep the
original per-leaf pytree path (the correctness reference); dropped stale
arrivals skip the O(d) correction entirely and take a momentum-decay-only
step on either path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OuterOptConfig
from repro.core import methods as outer_methods
from repro.core import packing
from repro.core.heloco import (
    OuterState, apply_arrival, apply_arrival_packed, init_outer_state,
    lookahead_init, momentum_decay_packed, momentum_decay_update,
)

PyTree = Any


def _mbuf_moments(mbuf: jnp.ndarray):
    """Telemetry moments of a suppressed arrival on the packed path."""
    from repro.telemetry import stats as _ts
    return _ts.momentum_only_moments(jnp.sum(mbuf * mbuf))


@dataclass
class ArrivalRecord:
    outer_step: int
    worker_id: int
    staleness: int
    rho: float
    sim_time: float
    lang: str = ""
    dropped: bool = False
    # update-quality diagnostics (populated only when the synchronizer
    # runs with telemetry=True; see repro.telemetry.stats)
    cos_align: Optional[float] = None
    corrected_frac: Optional[float] = None
    delta_norm: Optional[float] = None
    momentum_norm: Optional[float] = None


class Synchronizer:
    def __init__(self, init_params: PyTree, cfg: OuterOptConfig,
                 n_workers: int, stacked_axes: Optional[PyTree] = None,
                 use_kernel: bool = False, packed: bool = True,
                 telemetry: bool = False):
        self.cfg = cfg
        self.method = outer_methods.resolve(cfg.method)
        self.n_workers = n_workers
        self.stacked_axes = stacked_axes
        self.use_kernel = use_kernel
        self.packed = packed
        self.telemetry = telemetry
        self._last_moments = None      # (4,) device array, telemetry only
        self.records: List[ArrivalRecord] = []
        # idempotent-commit ledger: commit_key -> record already produced.
        # The delivery layer (DeliveryTracker) dedups redelivered frames
        # before they reach the engine; this is the server's own guarantee
        # that a replayed (wid, generation, seq) can never double-step
        # outer state, whatever path it took here.
        self._committed: dict = {}
        buffered = self.method.uses_buffer
        if packed:
            self.layout = packing.build_layout(init_params, stacked_axes)
            self._pbuf = packing.pack(self.layout, init_params)
            self._mbuf = packing.zeros(self.layout)
            self._abuf = packing.zeros(self.layout) if buffered else None
            self._step = 0
            self._state_cache: Optional[OuterState] = None
            # telemetry moments are an extra output of the SAME fused
            # sweep (with_stats) reduced to (4,) in-jit — the p'/m' math
            # and the launch count are untouched.
            if buffered:
                def _apply(p, m, b, delta, rho, tau, phase):
                    out = apply_arrival_packed(
                        p, m, delta, self.layout, method=self.method,
                        outer_lr=cfg.outer_lr, mu=cfg.momentum, h=cfg.heloco,
                        rho=rho, tau=tau, abuf=b, phase=phase,
                        with_stats=telemetry)
                    if telemetry:
                        return (*out[:3], jnp.sum(out[3], axis=0))
                    return out

                def _decay(p, m, b, rho, tau, phase):
                    out = momentum_decay_packed(
                        p, m, cfg.outer_lr, cfg.momentum, method=self.method,
                        rho=rho, tau=tau, abuf=b, phase=phase)
                    if telemetry:
                        return (*out, _mbuf_moments(m))
                    return out

                self._apply_packed = jax.jit(_apply, donate_argnums=(0, 1, 2))
                self._decay_packed = jax.jit(_decay, donate_argnums=(0, 1, 2))
            else:
                def _apply(p, m, delta, rho, tau):
                    out = apply_arrival_packed(
                        p, m, delta, self.layout, method=self.method,
                        outer_lr=cfg.outer_lr, mu=cfg.momentum, h=cfg.heloco,
                        rho=rho, tau=tau, with_stats=telemetry)
                    if telemetry:
                        return out[0], out[1], jnp.sum(out[2], axis=0)
                    return out

                def _decay(p, m, rho, tau):
                    out = momentum_decay_packed(
                        p, m, cfg.outer_lr, cfg.momentum, method=self.method,
                        rho=rho, tau=tau)
                    if telemetry:
                        return (*out, _mbuf_moments(m))
                    return out

                self._apply_packed = jax.jit(_apply, donate_argnums=(0, 1))
                self._decay_packed = jax.jit(_decay, donate_argnums=(0, 1))
            self._unpack_p = jax.jit(
                lambda b: packing.unpack(self.layout, b))
            self._unpack_m = jax.jit(
                lambda b: packing.unpack(self.layout, b, dtype=jnp.float32))
            self._lookahead_packed = jax.jit(
                lambda p, m: packing.unpack(
                    self.layout, p - cfg.outer_lr * cfg.momentum * m))
        else:
            self.layout = None
            self._state = init_outer_state(init_params, with_aux=buffered)
            self._apply = jax.jit(
                lambda state, delta, rho, tau, phase: apply_arrival(
                    state, delta, method=self.method, outer_lr=cfg.outer_lr,
                    mu=cfg.momentum, h=cfg.heloco, rho=rho, tau=tau,
                    stacked_axes=stacked_axes, use_kernel=use_kernel,
                    phase=phase),
                donate_argnums=(0,))
            self._decay = jax.jit(
                lambda state, rho, tau, phase: momentum_decay_update(
                    state, cfg.outer_lr, cfg.momentum, method=self.method,
                    rho=rho, tau=tau, phase=phase),
                donate_argnums=(0,))
            if telemetry:
                # per-leaf path: stats via the reference implementation
                # (this IS the correctness-reference engine)
                def _moments(state, delta, rho, tau, phase):
                    from repro.core import methods as _m
                    from repro.telemetry import stats as _ts
                    ctx = _m.ArrivalCtx(
                        outer_lr=cfg.outer_lr, mu=cfg.momentum,
                        h=cfg.heloco, rho=rho, tau=tau, phase=phase,
                        stacked_axes=stacked_axes, use_kernel=use_kernel)
                    g = self.method.correct(self.method, ctx, delta,
                                            state.momentum)
                    return _ts.reference_moments(delta, state.momentum, g)

                def _decay_moments(state):
                    from repro.telemetry import stats as _ts
                    msq = sum(jnp.sum(x.astype(jnp.float32) ** 2)
                              for x in jax.tree.leaves(state.momentum))
                    return _ts.momentum_only_moments(msq)

                self._moments_ref = jax.jit(_moments)
                self._decay_moments_ref = jax.jit(_decay_moments)

    # -- outer state view -----------------------------------------------------
    @property
    def state(self) -> OuterState:
        """Pytree view of the outer state (unpacked on demand, cached)."""
        if not self.packed:
            return self._state
        if self._state_cache is None:
            self._state_cache = OuterState(
                params=self._unpack_p(self._pbuf),
                momentum=self._unpack_m(self._mbuf),
                step=jnp.asarray(self._step, jnp.int32),
                aux=(self._unpack_m(self._abuf)
                     if self._abuf is not None else None))
        return self._state_cache

    @state.setter
    def state(self, value: OuterState):
        if not self.packed:
            self._state = value
            return
        self._pbuf = packing.pack(self.layout, value.params)
        self._mbuf = packing.pack(self.layout, value.momentum)
        if self.method.uses_buffer:
            self._abuf = (packing.pack(self.layout, value.aux)
                          if value.aux is not None
                          else packing.zeros(self.layout))
        self._step = int(value.step)
        self._state_cache = None

    @property
    def t(self) -> int:
        return self._step if self.packed else int(self._state.step)

    # -- worker initialization ------------------------------------------------
    def worker_init(self, wid: Optional[int] = None) -> PyTree:
        """Model state handed to a newly-available worker (Eq. 5 look-ahead
        for methods that participate in it — ``OuterMethod.lookahead_init``
        — plain theta_t for the Nesterov baselines). The hub server hands
        every worker the same state; ``wid`` exists for the decentralized
        topologies (``repro.async_engine.topology``), where each worker
        continues from its own replica."""
        if self.cfg.lookahead_init and self.method.lookahead_init:
            if self.packed:
                return self._lookahead_packed(self._pbuf, self._mbuf)
            return lookahead_init(self._state, self.cfg.outer_lr,
                                  self.cfg.momentum)
        return self.state.params

    # -- arrival weighting ----------------------------------------------------
    def _rho(self, tau: int) -> float:
        k = max(self.n_workers, 1)
        if self.cfg.weight_factor == "base":
            rho = math.sqrt(k) / k
        elif self.cfg.weight_factor == "average":
            rho = 1.0 / k
        else:
            rho = 1.0
        if self.cfg.delay_weighting:
            rho = rho / math.sqrt(1.0 + tau)
        return rho

    # -- outer-step drivers ---------------------------------------------------
    def _step_update(self, delta: PyTree, rho: float, tau: float):
        if self.packed:
            if self.method.uses_buffer:
                out = self._apply_packed(
                    self._pbuf, self._mbuf, self._abuf, delta,
                    jnp.asarray(rho), jnp.asarray(tau, jnp.float32),
                    jnp.asarray(self._step, jnp.int32))
                self._pbuf, self._mbuf, self._abuf = out[:3]
            else:
                out = self._apply_packed(
                    self._pbuf, self._mbuf, delta, jnp.asarray(rho),
                    jnp.asarray(tau, jnp.float32))
                self._pbuf, self._mbuf = out[:2]
            if self.telemetry:
                self._last_moments = out[-1]
            self._step += 1
            self._state_cache = None
        else:
            if self.telemetry:
                # before _apply donates the state buffers
                self._last_moments = self._moments_ref(
                    self._state, delta, jnp.asarray(rho),
                    jnp.asarray(tau, jnp.float32),
                    jnp.asarray(self.t, jnp.int32))
            self._state = self._apply(self._state, delta, jnp.asarray(rho),
                                      jnp.asarray(tau, jnp.float32),
                                      jnp.asarray(self.t, jnp.int32))

    def _step_decay(self, rho: float, tau: float):
        """Dropped arrival (App. A.6): momentum-decay-only outer step —
        equivalent to the method applied to a zero pseudo-gradient, but no
        zero pytree is materialised and the O(d) correction is skipped."""
        rho = jnp.asarray(rho)
        tau = jnp.asarray(tau, jnp.float32)
        if self.packed:
            if self.method.uses_buffer:
                out = self._decay_packed(
                    self._pbuf, self._mbuf, self._abuf, rho, tau,
                    jnp.asarray(self._step, jnp.int32))
                self._pbuf, self._mbuf, self._abuf = out[:3]
            else:
                out = self._decay_packed(self._pbuf, self._mbuf, rho, tau)
                self._pbuf, self._mbuf = out[:2]
            if self.telemetry:
                self._last_moments = out[-1]
            self._step += 1
            self._state_cache = None
        else:
            if self.telemetry:
                self._last_moments = self._decay_moments_ref(self._state)
            self._state = self._decay(self._state, rho, tau,
                                      jnp.asarray(self.t, jnp.int32))

    def _attach_stats(self, rec: ArrivalRecord) -> ArrivalRecord:
        """Fold the last step's telemetry moments into the record."""
        if self.telemetry and self._last_moments is not None:
            from repro.telemetry import stats as _ts
            s = _ts.stats_from_moments(self._last_moments)
            rec.cos_align = s.cos_align
            rec.corrected_frac = s.corrected_frac
            rec.delta_norm = s.delta_norm
            rec.momentum_norm = s.momentum_norm
        return rec

    # -- arrival processing ---------------------------------------------------
    def on_arrival(self, delta: PyTree, s_i: int, worker_id: int,
                   sim_time: float = 0.0, lang: str = "",
                   commit_key=None) -> ArrivalRecord:
        """Apply one pseudo-gradient arrival. ``commit_key`` (typically the
        delivery frame identity ``(wid, generation, seq)``) makes the call
        idempotent: a key seen before returns the original record and
        leaves outer state untouched."""
        if commit_key is not None:
            prior = self._committed.get(commit_key)
            if prior is not None:
                return prior
        tau = self.t - s_i
        dropped = (self.cfg.drop_stale_after is not None
                   and tau > self.cfg.drop_stale_after)
        rho = self._rho(tau)
        if dropped:
            self._step_decay(rho, tau)
        else:
            self._step_update(delta, rho, tau)
        rec = self._attach_stats(
            ArrivalRecord(outer_step=self.t, worker_id=worker_id,
                          staleness=tau, rho=rho, sim_time=sim_time,
                          lang=lang, dropped=dropped))
        self.records.append(rec)
        if commit_key is not None:
            self._committed[commit_key] = rec
        return rec

    # -- sync round (barrier) -------------------------------------------------
    def on_sync_round(self, deltas: List[PyTree], sim_time: float = 0.0
                      ) -> ArrivalRecord:
        """Synchronous DiLoCo: average worker pseudo-gradients, one outer step."""
        k = len(deltas)
        avg = jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs) / k,
                           *deltas)
        # sync-nesterov in the paper uses average weighting: G = mean(Delta)
        self._step_update(avg, 1.0, 0.0)
        rec = self._attach_stats(
            ArrivalRecord(outer_step=self.t, worker_id=-1, staleness=0,
                          rho=1.0, sim_time=sim_time))
        self.records.append(rec)
        return rec

    def set_n_workers(self, n: int):
        self.n_workers = n
