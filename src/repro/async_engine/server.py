"""The synchronizer (outer-optimizer server) for asynchronous
low-communication training.

Owns the outer state (theta, momentum, step counter), hands out worker
initializations (look-ahead model for HeLoCo/MLA, Eq. 5), and processes
arriving pseudo-gradients through the configured method (HeLoCo per-block
correction / MLA / Nesterov), including staleness bookkeeping, arrival
weighting, and optional stale-update dropping (App. A.6).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import OuterOptConfig
from repro.core.heloco import (
    OuterState, apply_arrival, init_outer_state, lookahead_init,
)

PyTree = Any


@dataclass
class ArrivalRecord:
    outer_step: int
    worker_id: int
    staleness: int
    rho: float
    sim_time: float
    lang: str = ""
    dropped: bool = False


class Synchronizer:
    def __init__(self, init_params: PyTree, cfg: OuterOptConfig,
                 n_workers: int, stacked_axes: Optional[PyTree] = None,
                 use_kernel: bool = False):
        self.state: OuterState = init_outer_state(init_params)
        self.cfg = cfg
        self.n_workers = n_workers
        self.stacked_axes = stacked_axes
        self.use_kernel = use_kernel
        self.records: List[ArrivalRecord] = []
        self._apply = jax.jit(
            lambda state, delta, rho, tau: apply_arrival(
                state, delta, method=cfg.method, outer_lr=cfg.outer_lr,
                mu=cfg.momentum, h=cfg.heloco, rho=rho, tau=tau,
                stacked_axes=stacked_axes, use_kernel=use_kernel),
            donate_argnums=(0,))

    # -- worker initialization ------------------------------------------------
    @property
    def t(self) -> int:
        return int(self.state.step)

    def worker_init(self) -> PyTree:
        """Model state handed to a newly-available worker (Eq. 5 look-ahead
        for HeLoCo/MLA; plain theta_t for the Nesterov baselines)."""
        if self.cfg.lookahead_init and self.cfg.method in ("heloco", "mla"):
            return lookahead_init(self.state, self.cfg.outer_lr,
                                  self.cfg.momentum)
        return self.state.params

    # -- arrival weighting ----------------------------------------------------
    def _rho(self, tau: int) -> float:
        k = max(self.n_workers, 1)
        if self.cfg.weight_factor == "base":
            rho = math.sqrt(k) / k
        elif self.cfg.weight_factor == "average":
            rho = 1.0 / k
        else:
            rho = 1.0
        if self.cfg.delay_weighting:
            rho = rho / math.sqrt(1.0 + tau)
        return rho

    # -- arrival processing ---------------------------------------------------
    def on_arrival(self, delta: PyTree, s_i: int, worker_id: int,
                   sim_time: float = 0.0, lang: str = "") -> ArrivalRecord:
        tau = self.t - s_i
        dropped = (self.cfg.drop_stale_after is not None
                   and tau > self.cfg.drop_stale_after)
        if dropped:
            # App. A.6: suppress the stale update (G_t = 0); the outer step
            # still advances so momentum decays consistently.
            delta = jax.tree.map(lambda x: jnp.zeros_like(x), delta)
        rho = self._rho(tau)
        self.state = self._apply(self.state, delta, jnp.asarray(rho),
                                 jnp.asarray(tau, jnp.float32))
        rec = ArrivalRecord(outer_step=self.t, worker_id=worker_id,
                            staleness=tau, rho=rho, sim_time=sim_time,
                            lang=lang, dropped=dropped)
        self.records.append(rec)
        return rec

    # -- sync round (barrier) -------------------------------------------------
    def on_sync_round(self, deltas: List[PyTree], sim_time: float = 0.0
                      ) -> ArrivalRecord:
        """Synchronous DiLoCo: average worker pseudo-gradients, one outer step."""
        k = len(deltas)
        avg = jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs) / k,
                           *deltas)
        rho = self._rho(0) * k if self.cfg.weight_factor == "average" else 1.0
        # sync-nesterov in the paper uses average weighting: G = mean(Delta)
        self.state = self._apply(self.state, avg, jnp.asarray(1.0),
                                 jnp.asarray(0.0, jnp.float32))
        rec = ArrivalRecord(outer_step=self.t, worker_id=-1, staleness=0,
                            rho=1.0, sim_time=sim_time)
        self.records.append(rec)
        return rec

    def set_n_workers(self, n: int):
        self.n_workers = n
