"""The synchronizer (outer-optimizer server) for asynchronous
low-communication training.

Owns the outer state (theta, momentum, step counter, optional per-method
auxiliary buffer), hands out worker initializations (Eq. 5 look-ahead for
methods that participate), and processes arriving pseudo-gradients
through the configured ``repro.core.methods`` definition — correction,
staleness bookkeeping, arrival weighting, and optional stale-update
dropping (App. A.6) are all method-agnostic here.

Arrival fast path (default): the outer state lives PACKED — params and
momentum are flattened once at init into fp32 (R, 128) buffers (see
``repro.core.packing``), every arrival donates and rewrites those buffers
through the two fused packed kernels (O(1) launches per arrival instead of
O(#leaves)), and the pytree view is materialised only on demand for
``worker_init`` / eval / checkpointing. Pass ``packed=False`` to keep the
original per-leaf pytree path (the correctness reference); dropped stale
arrivals skip the O(d) correction entirely and take a momentum-decay-only
step on either path.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OuterOptConfig
from repro.core import methods as outer_methods
from repro.core import packing
from repro.core.heloco import (
    OuterState, apply_arrival, apply_arrival_packed, apply_arrivals_packed,
    init_outer_state, lookahead_init, momentum_decay_packed,
    momentum_decay_update,
)

PyTree = Any


class _Pending(NamedTuple):
    """One buffered (not-yet-committed) arrival (see ``buffer_arrival``)."""
    delta: Any
    s_i: int
    worker_id: int
    sim_time: float
    lang: str
    commit_key: Any


def _mbuf_moments(mbuf: jnp.ndarray):
    """Telemetry moments of a suppressed arrival on the packed path."""
    from repro.telemetry import stats as _ts
    return _ts.momentum_only_moments(jnp.sum(mbuf * mbuf))


@dataclass
class ArrivalRecord:
    outer_step: int
    worker_id: int
    staleness: int
    rho: float
    sim_time: float
    lang: str = ""
    dropped: bool = False
    # update-quality diagnostics (populated only when the synchronizer
    # runs with telemetry=True; see repro.telemetry.stats)
    cos_align: Optional[float] = None
    corrected_frac: Optional[float] = None
    delta_norm: Optional[float] = None
    momentum_norm: Optional[float] = None


class Synchronizer:
    def __init__(self, init_params: PyTree, cfg: OuterOptConfig,
                 n_workers: int, stacked_axes: Optional[PyTree] = None,
                 use_kernel: bool = False, packed: bool = True,
                 telemetry: bool = False, commit_batch: int = 1):
        self.cfg = cfg
        self.method = outer_methods.resolve(cfg.method)
        self.n_workers = n_workers
        self.stacked_axes = stacked_axes
        self.use_kernel = use_kernel
        self.packed = packed
        self.telemetry = telemetry
        self._last_moments = None      # (4,) device array, telemetry only
        self.records: List[ArrivalRecord] = []
        # idempotent-commit ledger: commit_key -> record already produced.
        # The delivery layer (DeliveryTracker) dedups redelivered frames
        # before they reach the engine; this is the server's own guarantee
        # that a replayed (wid, generation, seq) can never double-step
        # outer state, whatever path it took here.
        self._committed: dict = {}
        # -- batched-arrival commit buffer (docs/scale.md) ---------------
        # Arrivals parked via buffer_arrival() coalesce into one fused
        # multi-apply at flush time; flush fires on batch-full here, the
        # engine forces it at eval/checkpoint boundaries, and methods with
        # batchable=False degrade to the sequential path inside flush().
        self.commit_batch = max(1, int(commit_batch))
        self._pending: List[_Pending] = []
        self._pending_keys: set = set()
        # flush observability (docs/observability.md): one event dict per
        # flush() — buffered depth, why it fired, how many commits went
        # fused vs sequential. The engine drains this into "flush"
        # telemetry records; cumulative totals feed stats_summary.
        self.flush_log: List[dict] = []
        self.flush_totals: dict = {"flushes": 0, "fused": 0,
                                   "sequential": 0, "depth_max": 0}
        self._apply_multi: dict = {}      # K -> jitted batched apply
        # Coefficient-scalar table: each distinct host scalar (rho, tau,
        # phase) is put on device ONCE and re-indexed by value afterwards,
        # so a warmed-up per-arrival commit issues no host->device
        # transfers (asserted by the bench-scale transfer probe). phase is
        # reduced mod buffer_period first — the schedule hooks only ever
        # read (phase + 1) % buffer_period, so the table stays finite.
        self._coef_table: dict = {}
        buffered = self.method.uses_buffer
        self._phase_period = self.method.buffer_period if buffered else 1
        if packed:
            self.layout = packing.build_layout(init_params, stacked_axes)
            self._pbuf = packing.pack(self.layout, init_params)
            self._mbuf = packing.zeros(self.layout)
            self._abuf = packing.zeros(self.layout) if buffered else None
            self._step = 0
            self._state_cache: Optional[OuterState] = None
            # telemetry moments are an extra output of the SAME fused
            # sweep (with_stats) reduced to (4,) in-jit — the p'/m' math
            # and the launch count are untouched.
            if buffered:
                def _apply(p, m, b, delta, rho, tau, phase):
                    out = apply_arrival_packed(
                        p, m, delta, self.layout, method=self.method,
                        outer_lr=cfg.outer_lr, mu=cfg.momentum, h=cfg.heloco,
                        rho=rho, tau=tau, abuf=b, phase=phase,
                        with_stats=telemetry)
                    if telemetry:
                        return (*out[:3], jnp.sum(out[3], axis=0))
                    return out

                def _decay(p, m, b, rho, tau, phase):
                    out = momentum_decay_packed(
                        p, m, cfg.outer_lr, cfg.momentum, method=self.method,
                        rho=rho, tau=tau, abuf=b, phase=phase)
                    if telemetry:
                        return (*out, _mbuf_moments(m))
                    return out

                self._apply_packed = jax.jit(_apply, donate_argnums=(0, 1, 2))
                self._decay_packed = jax.jit(_decay, donate_argnums=(0, 1, 2))
            else:
                def _apply(p, m, delta, rho, tau):
                    out = apply_arrival_packed(
                        p, m, delta, self.layout, method=self.method,
                        outer_lr=cfg.outer_lr, mu=cfg.momentum, h=cfg.heloco,
                        rho=rho, tau=tau, with_stats=telemetry)
                    if telemetry:
                        return out[0], out[1], jnp.sum(out[2], axis=0)
                    return out

                def _decay(p, m, rho, tau):
                    out = momentum_decay_packed(
                        p, m, cfg.outer_lr, cfg.momentum, method=self.method,
                        rho=rho, tau=tau)
                    if telemetry:
                        return (*out, _mbuf_moments(m))
                    return out

                self._apply_packed = jax.jit(_apply, donate_argnums=(0, 1))
                self._decay_packed = jax.jit(_decay, donate_argnums=(0, 1))
            self._unpack_p = jax.jit(
                lambda b: packing.unpack(self.layout, b))
            self._unpack_m = jax.jit(
                lambda b: packing.unpack(self.layout, b, dtype=jnp.float32))
            self._lookahead_packed = jax.jit(
                lambda p, m: packing.unpack(
                    self.layout, p - cfg.outer_lr * cfg.momentum * m))
        else:
            self.layout = None
            self._state = init_outer_state(init_params, with_aux=buffered)
            self._apply = jax.jit(
                lambda state, delta, rho, tau, phase: apply_arrival(
                    state, delta, method=self.method, outer_lr=cfg.outer_lr,
                    mu=cfg.momentum, h=cfg.heloco, rho=rho, tau=tau,
                    stacked_axes=stacked_axes, use_kernel=use_kernel,
                    phase=phase),
                donate_argnums=(0,))
            self._decay = jax.jit(
                lambda state, rho, tau, phase: momentum_decay_update(
                    state, cfg.outer_lr, cfg.momentum, method=self.method,
                    rho=rho, tau=tau, phase=phase),
                donate_argnums=(0,))
            if telemetry:
                # per-leaf path: stats via the reference implementation
                # (this IS the correctness-reference engine)
                def _moments(state, delta, rho, tau, phase):
                    from repro.core import methods as _m
                    from repro.telemetry import stats as _ts
                    ctx = _m.ArrivalCtx(
                        outer_lr=cfg.outer_lr, mu=cfg.momentum,
                        h=cfg.heloco, rho=rho, tau=tau, phase=phase,
                        stacked_axes=stacked_axes, use_kernel=use_kernel)
                    g = self.method.correct(self.method, ctx, delta,
                                            state.momentum)
                    return _ts.reference_moments(delta, state.momentum, g)

                def _decay_moments(state):
                    from repro.telemetry import stats as _ts
                    msq = sum(jnp.sum(x.astype(jnp.float32) ** 2)
                              for x in jax.tree.leaves(state.momentum))
                    return _ts.momentum_only_moments(msq)

                self._moments_ref = jax.jit(_moments)
                self._decay_moments_ref = jax.jit(_decay_moments)

    # -- outer state view -----------------------------------------------------
    @property
    def state(self) -> OuterState:
        """Pytree view of the outer state (unpacked on demand, cached)."""
        if not self.packed:
            return self._state
        if self._state_cache is None:
            self._state_cache = OuterState(
                params=self._unpack_p(self._pbuf),
                momentum=self._unpack_m(self._mbuf),
                step=jnp.asarray(self._step, jnp.int32),
                aux=(self._unpack_m(self._abuf)
                     if self._abuf is not None else None))
        return self._state_cache

    @state.setter
    def state(self, value: OuterState):
        if not self.packed:
            self._state = value
            return
        self._pbuf = packing.pack(self.layout, value.params)
        self._mbuf = packing.pack(self.layout, value.momentum)
        if self.method.uses_buffer:
            self._abuf = (packing.pack(self.layout, value.aux)
                          if value.aux is not None
                          else packing.zeros(self.layout))
        self._step = int(value.step)
        self._state_cache = None

    @property
    def t(self) -> int:
        return self._step if self.packed else int(self._state.step)

    # -- worker initialization ------------------------------------------------
    def worker_init(self, wid: Optional[int] = None) -> PyTree:
        """Model state handed to a newly-available worker (Eq. 5 look-ahead
        for methods that participate in it — ``OuterMethod.lookahead_init``
        — plain theta_t for the Nesterov baselines). The hub server hands
        every worker the same state; ``wid`` exists for the decentralized
        topologies (``repro.async_engine.topology``), where each worker
        continues from its own replica."""
        if self.cfg.lookahead_init and self.method.lookahead_init:
            if self.packed:
                return self._lookahead_packed(self._pbuf, self._mbuf)
            return lookahead_init(self._state, self.cfg.outer_lr,
                                  self.cfg.momentum)
        return self.state.params

    # -- arrival weighting ----------------------------------------------------
    def _rho(self, tau: int) -> float:
        k = max(self.n_workers, 1)
        if self.cfg.weight_factor == "base":
            rho = math.sqrt(k) / k
        elif self.cfg.weight_factor == "average":
            rho = 1.0 / k
        else:
            rho = 1.0
        if self.cfg.delay_weighting:
            rho = rho / math.sqrt(1.0 + tau)
        return rho

    # -- outer-step drivers ---------------------------------------------------
    def _coef(self, value, dtype=None):
        """Host scalar -> device scalar, materialised once per distinct
        value (the per-method coefficient table; see __init__)."""
        key = (value, None if dtype is None else jnp.dtype(dtype).name)
        dev = self._coef_table.get(key)
        if dev is None:
            dev = (jnp.asarray(value) if dtype is None
                   else jnp.asarray(value, dtype))
            self._coef_table[key] = dev
        return dev

    def _phase_coef(self):
        """Device int32 phase, reduced mod buffer_period (the only part of
        the outer-step index the schedule hooks observe)."""
        return self._coef(self.t % self._phase_period, jnp.int32)

    def _step_update(self, delta: PyTree, rho: float, tau: float):
        if self.packed:
            if self.method.uses_buffer:
                out = self._apply_packed(
                    self._pbuf, self._mbuf, self._abuf, delta,
                    self._coef(rho), self._coef(tau, jnp.float32),
                    self._phase_coef())
                self._pbuf, self._mbuf, self._abuf = out[:3]
            else:
                out = self._apply_packed(
                    self._pbuf, self._mbuf, delta, self._coef(rho),
                    self._coef(tau, jnp.float32))
                self._pbuf, self._mbuf = out[:2]
            if self.telemetry:
                self._last_moments = out[-1]
            self._step += 1
            self._state_cache = None
        else:
            if self.telemetry:
                # before _apply donates the state buffers
                self._last_moments = self._moments_ref(
                    self._state, delta, self._coef(rho),
                    self._coef(tau, jnp.float32), self._phase_coef())
            self._state = self._apply(self._state, delta, self._coef(rho),
                                      self._coef(tau, jnp.float32),
                                      self._phase_coef())

    def _step_decay(self, rho: float, tau: float):
        """Dropped arrival (App. A.6): momentum-decay-only outer step —
        equivalent to the method applied to a zero pseudo-gradient, but no
        zero pytree is materialised and the O(d) correction is skipped."""
        rho = self._coef(rho)
        tau = self._coef(tau, jnp.float32)
        if self.packed:
            if self.method.uses_buffer:
                out = self._decay_packed(
                    self._pbuf, self._mbuf, self._abuf, rho, tau,
                    self._phase_coef())
                self._pbuf, self._mbuf, self._abuf = out[:3]
            else:
                out = self._decay_packed(self._pbuf, self._mbuf, rho, tau)
                self._pbuf, self._mbuf = out[:2]
            if self.telemetry:
                self._last_moments = out[-1]
            self._step += 1
            self._state_cache = None
        else:
            if self.telemetry:
                self._last_moments = self._decay_moments_ref(self._state)
            self._state = self._decay(self._state, rho, tau,
                                      self._phase_coef())

    # -- batched commit path (docs/scale.md) ----------------------------------
    def _make_apply_multi(self, k: int):
        """Build the jitted K-stacked apply: one fused multi-kernel sweep
        (<= 2 Pallas launches for every registered method) replacing K
        sequential _step_update calls. Telemetry moments ride the same
        sweep as a (K, 4) extra output."""
        cfg = self.cfg
        telemetry = self.telemetry
        if self.method.uses_buffer:
            def _apply(p, m, b, deltas, rho_vec, tau_vec, phase_vec):
                out = apply_arrivals_packed(
                    p, m, list(deltas), self.layout, method=self.method,
                    outer_lr=cfg.outer_lr, mu=cfg.momentum, h=cfg.heloco,
                    rhos=[rho_vec[j] for j in range(k)],
                    taus=[tau_vec[j] for j in range(k)], abuf=b,
                    phases=[phase_vec[j] for j in range(k)],
                    with_stats=telemetry)
                if telemetry:
                    return (*out[:3], jnp.sum(out[3], axis=1))
                return out

            return jax.jit(_apply, donate_argnums=(0, 1, 2))

        def _apply(p, m, deltas, rho_vec, tau_vec):
            out = apply_arrivals_packed(
                p, m, list(deltas), self.layout, method=self.method,
                outer_lr=cfg.outer_lr, mu=cfg.momentum, h=cfg.heloco,
                rhos=[rho_vec[j] for j in range(k)],
                taus=[tau_vec[j] for j in range(k)],
                with_stats=telemetry)
            if telemetry:
                return out[0], out[1], jnp.sum(out[2], axis=1)
            return out

        return jax.jit(_apply, donate_argnums=(0, 1))

    def _step_update_multi(self, deltas: List[PyTree], rhos: List[float],
                           taus: List[float]):
        """Commit K arrivals in one fused launch. Returns the per-arrival
        (K, 4) telemetry moments (None without telemetry)."""
        k = len(deltas)
        fn = self._apply_multi.get(k)
        if fn is None:
            fn = self._make_apply_multi(k)
            self._apply_multi[k] = fn
        # one host->device transfer per flush for ALL per-arrival scalars
        rho_vec = jnp.asarray(np.asarray(rhos, np.float32))
        tau_vec = jnp.asarray(np.asarray(taus, np.float32))
        if self.method.uses_buffer:
            period = self._phase_period
            phase_vec = jnp.asarray(np.asarray(
                [(self._step + j) % period for j in range(k)], np.int32))
            out = fn(self._pbuf, self._mbuf, self._abuf, tuple(deltas),
                     rho_vec, tau_vec, phase_vec)
            self._pbuf, self._mbuf, self._abuf = out[:3]
        else:
            out = fn(self._pbuf, self._mbuf, tuple(deltas), rho_vec, tau_vec)
            self._pbuf, self._mbuf = out[:2]
        moments = out[-1] if self.telemetry else None
        self._step += k
        self._state_cache = None
        return moments

    def _attach_stats(self, rec: ArrivalRecord) -> ArrivalRecord:
        """Fold the last step's telemetry moments into the record."""
        if self.telemetry and self._last_moments is not None:
            from repro.telemetry import stats as _ts
            s = _ts.stats_from_moments(self._last_moments)
            rec.cos_align = s.cos_align
            rec.corrected_frac = s.corrected_frac
            rec.delta_norm = s.delta_norm
            rec.momentum_norm = s.momentum_norm
        return rec

    # -- arrival processing ---------------------------------------------------
    def on_arrival(self, delta: PyTree, s_i: int, worker_id: int,
                   sim_time: float = 0.0, lang: str = "",
                   commit_key=None) -> ArrivalRecord:
        """Apply one pseudo-gradient arrival. ``commit_key`` (typically the
        delivery frame identity ``(wid, generation, seq)``) makes the call
        idempotent: a key seen before returns the original record and
        leaves outer state untouched."""
        if commit_key is not None:
            prior = self._committed.get(commit_key)
            if prior is not None:
                return prior
        tau = self.t - s_i
        dropped = (self.cfg.drop_stale_after is not None
                   and tau > self.cfg.drop_stale_after)
        rho = self._rho(tau)
        if dropped:
            self._step_decay(rho, tau)
        else:
            self._step_update(delta, rho, tau)
        rec = self._attach_stats(
            ArrivalRecord(outer_step=self.t, worker_id=worker_id,
                          staleness=tau, rho=rho, sim_time=sim_time,
                          lang=lang, dropped=dropped))
        self.records.append(rec)
        if commit_key is not None:
            self._committed[commit_key] = rec
        return rec

    # -- batched arrival processing (docs/scale.md) ---------------------------
    @property
    def pending(self) -> int:
        """Arrivals parked in the commit buffer, awaiting flush()."""
        return len(self._pending)

    def buffer_arrival(self, delta: PyTree, s_i: int, worker_id: int,
                       sim_time: float = 0.0, lang: str = "",
                       commit_key=None) -> Optional[List[ArrivalRecord]]:
        """Park one arrival in the commit buffer. Returns the flushed
        records when this arrival filled the batch (len == commit_batch),
        None while the buffer is still coalescing. Arrivals whose
        commit_key is already in the ledger (or already buffered) are
        dropped here — the idempotent-commit guarantee of on_arrival,
        extended to buffered redelivery."""
        if commit_key is not None:
            if commit_key in self._committed or commit_key in self._pending_keys:
                return None
            self._pending_keys.add(commit_key)
        self._pending.append(_Pending(delta, s_i, worker_id, sim_time,
                                      lang, commit_key))
        if len(self._pending) >= self.commit_batch:
            return self.flush("batch-full")
        return None

    def flush(self, reason: str = "batch-full") -> List[ArrivalRecord]:
        """Commit every buffered arrival, in buffering order, and return
        their records. Runs of consecutive batchable non-dropped arrivals
        commit through ONE fused multi-apply; dropped arrivals (App. A.6),
        singletons, non-batchable methods, and the per-leaf reference path
        all fall back to the exact sequential on_arrival — so a batch of
        size 1 is byte-identical to the unbatched server. ``reason``
        records why the buffer emptied (batch-full | eval | ckpt | close)
        in the flush event log — observation only."""
        pending, self._pending = self._pending, []
        self._pending_keys = set()
        if not pending:
            return []
        n = len(pending)
        n_fused = 0
        batchable = self.packed and self.method.batchable
        # Staleness at commit time is knowable up front: every commit
        # (applied or dropped) advances t by exactly one, so arrival j
        # sees tau_j = (t0 + j) - s_i_j whatever path it takes.
        t0 = self.t
        drop_after = self.cfg.drop_stale_after
        drops = [drop_after is not None and (t0 + j) - a.s_i > drop_after
                 for j, a in enumerate(pending)]
        recs: List[ArrivalRecord] = []
        i = 0
        while i < n:
            j = i
            if batchable and not drops[i]:
                while j < n and not drops[j]:
                    j += 1
            if j - i < 2:
                a = pending[i]
                recs.append(self.on_arrival(a.delta, a.s_i, a.worker_id,
                                            a.sim_time, a.lang, a.commit_key))
                i += 1
                continue
            run = pending[i:j]
            t_run = self.t
            taus = [t_run + idx - a.s_i for idx, a in enumerate(run)]
            rhos = [self._rho(tau) for tau in taus]
            moments = self._step_update_multi([a.delta for a in run],
                                              rhos, taus)
            if moments is not None:
                # ONE device->host pull for the whole flush; per-record
                # slicing below is then pure numpy (an eager device slice
                # per record would issue a h2d index transfer each time —
                # the bench-scale transfer probe guards this path)
                moments = np.asarray(moments)
            for idx, a in enumerate(run):
                rec = ArrivalRecord(outer_step=t_run + idx + 1,
                                    worker_id=a.worker_id,
                                    staleness=taus[idx], rho=rhos[idx],
                                    sim_time=a.sim_time, lang=a.lang)
                if moments is not None:
                    self._last_moments = moments[idx]
                rec = self._attach_stats(rec)
                self.records.append(rec)
                if a.commit_key is not None:
                    self._committed[a.commit_key] = rec
                recs.append(rec)
            n_fused += len(run)
            i = j
        ev = {"depth": n, "reason": str(reason), "fused": n_fused,
              "sequential": n - n_fused}
        self.flush_log.append(ev)
        self.flush_totals["flushes"] += 1
        self.flush_totals["fused"] += n_fused
        self.flush_totals["sequential"] += n - n_fused
        self.flush_totals["depth_max"] = max(self.flush_totals["depth_max"],
                                             n)
        return recs

    # -- sync round (barrier) -------------------------------------------------
    def on_sync_round(self, deltas: List[PyTree], sim_time: float = 0.0
                      ) -> ArrivalRecord:
        """Synchronous DiLoCo: average worker pseudo-gradients, one outer step."""
        k = len(deltas)
        avg = jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs) / k,
                           *deltas)
        # sync-nesterov in the paper uses average weighting: G = mean(Delta)
        self._step_update(avg, 1.0, 0.0)
        rec = self._attach_stats(
            ArrivalRecord(outer_step=self.t, worker_id=-1, staleness=0,
                          rho=1.0, sim_time=sim_time))
        self.records.append(rec)
        return rec

    def set_n_workers(self, n: int):
        self.n_workers = n
