"""Shared Engine contract for the asynchronous training runtimes.

Two engines implement it:

  - ``AsyncSimulator`` (``repro.async_engine.simulator``): event-driven
    virtual clock — the paper's reference runtime. Inner rounds execute
    serially at event-pop time; only *time* is simulated.
  - ``ConcurrentRuntime`` (``repro.async_engine.runtime``): wall-clock
    concurrency — one thread per worker (optionally pinned to its own
    ``jax.devices()`` entry), pseudo-gradients travel through a
    ``Transport``, and the server applies the packed fused update while
    other workers keep computing.

The contract is enforced structurally: everything that must behave
identically across engines lives here —

  - worker bookkeeping (``Worker``), dispatch capture (``_make_task``),
    the functional inner round (``_execute``: reads only its ``RoundTask``
    snapshot, so it is safe on any thread and a lost round leaves no
    trace), and the server-side commit (``_commit``: optimizer state,
    token/byte accounting, ``Synchronizer.on_arrival``);
  - the virtual-clock event loop (``_run_async``) with failure injection,
    elastic membership, and checkpoint cadence. The deterministic
    wall-clock mode reuses this loop verbatim — arrivals are committed in
    virtual-deadline order regardless of which thread finished first,
    which is the determinism contract (see docs/runtime.md): a
    FIFO-forced ``ConcurrentRuntime`` reproduces the simulator's arrival
    sequence ``(wid, s_i, staleness, lang)`` exactly.

Subclasses provide two hooks: ``_submit`` (where a captured round goes —
nowhere for the simulator, a worker inbox for the runtime) and
``_obtain`` (how the result comes back — computed in-line vs. received
through the transport).
"""
from __future__ import annotations

import heapq
import os
import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import RunConfig
from repro.core.compression import roundtrip_with_error_feedback
from repro.obs.spans import NULL_TRACER
from repro.async_engine.server import Synchronizer
from repro.data.synthetic import (
    ShardSampler, eval_batches, make_language_specs, mixture_weights,
)
from repro.models import build_model
from repro.optim.adamw import init_adam
from repro.train.inner import pseudo_gradient, run_inner

PyTree = Any


# ---------------------------------------------------------------------------
# Shared datatypes
# ---------------------------------------------------------------------------

@dataclass
class Worker:
    wid: int
    pace: float                      # seconds per inner step (virtual)
    lang: Optional[int]              # shard index (None = IID mixture)
    mixture: Optional[Tuple[float, ...]] = None  # Dirichlet language weights
    params: PyTree = None            # in-flight initialization (captured)
    opt: Any = None                  # persistent AdamW state
    ef: PyTree = None                # compression error-feedback buffer
    s_i: int = 0                     # outer step at dispatch
    h_steps: int = 0                 # local steps this round
    cur_lang: Optional[int] = None   # shard chosen for the current round
    inner_step_count: int = 0        # lifetime inner steps (for LR schedule)
    alive: bool = True
    dispatch_time: float = 0.0
    generation: int = 0              # incremented on crash: stale rounds dropped
    round_seq: int = 0               # monotonically increasing dispatch counter
    in_flight: bool = False          # a dispatched round has not committed yet
    pending_task_id: Optional[int] = None  # engine-unique id of that round
    device: Any = None               # optional pinned jax device


@dataclass
class FailureEvent:
    time: float
    wid: int
    restart_delay: float = 60.0      # simulated seconds until rejoin


@dataclass
class ElasticEvent:
    time: float
    action: str                      # "join" | "leave"
    wid: int
    pace: float = 1.0
    lang: Optional[int] = None


@dataclass
class History:
    arrivals: List[Dict] = field(default_factory=list)
    evals: List[Dict] = field(default_factory=list)
    tokens: int = 0
    comm_bytes: int = 0
    final_time: float = 0.0

    def summary(self) -> Dict:
        return {
            "outer_steps": len(self.arrivals),
            "tokens": self.tokens,
            "comm_bytes": self.comm_bytes,
            "final_time": self.final_time,
            "final_eval": self.evals[-1] if self.evals else None,
        }


@dataclass(frozen=True)
class Budget:
    """Stopping rule for budgeted comparisons (paper Table 2): train to a
    fixed token count or a fixed (virtual/wall) clock horizon instead of a
    fixed number of outer steps. Both engines honour it within ONE outer
    round of the target:

      fixed_tokens     stop at the first commit whose cumulative token
                       count reaches ``amount``;
      fixed_wallclock  never commit an arrival past ``amount`` seconds of
                       engine time (sim: virtual; free-running: scaled
                       wall clock) — the run stops at the last arrival
                       inside the horizon.

    The configured ``outer_steps`` remains a hard cap on top.
    """
    kind: str                        # "fixed_tokens" | "fixed_wallclock"
    amount: float

    KINDS = ("fixed_tokens", "fixed_wallclock")

    def __post_init__(self):
        assert self.kind in self.KINDS, self.kind
        assert self.amount > 0, self.amount

    def over_time(self, t: float) -> bool:
        return self.kind == "fixed_wallclock" and t > self.amount + 1e-9

    def over_tokens(self, tokens: int) -> bool:
        return self.kind == "fixed_tokens" and tokens >= self.amount


@dataclass
class RoundTask:
    """Snapshot of one dispatched inner round. Captured on the server
    thread; ``_execute`` reads only this, never the live ``Worker``, so a
    concurrently-injected crash (generation bump) cannot race the compute
    — the stale result is simply discarded at commit."""
    task_id: int                     # engine-unique: never reused, even when
    wid: int                         # a wid rejoins as a fresh Worker
    generation: int
    round_seq: int
    params: PyTree
    opt: Any
    ef: PyTree
    s_i: int
    h_steps: int
    lang: Optional[int]
    inner_step_offset: int
    mixture: Optional[Tuple[float, ...]] = None
    dispatch_time: float = 0.0
    sleep_per_step: float = 0.0      # free-running pace throttle (wall sec)
    device: Any = None


@dataclass
class RoundResult:
    task_id: int
    wid: int
    generation: int
    round_seq: int
    delta: PyTree
    opt: Any
    ef: PyTree
    nbytes: int
    s_i: int
    h_steps: int
    lang: Optional[int]
    compute_seconds: float = 0.0


def execute_round(task: RoundTask, *, model, cfg: RunConfig, specs,
                  layout=None, tracer=None) -> RoundResult:
    """The functional inner round, shared VERBATIM between every engine
    thread and the socket worker processes: reads only the ``RoundTask``
    snapshot plus immutable run-wide state (model, config, language
    specs, optional packed int8 layout) — all deterministically
    reconstructible from the ``RunConfig`` in a fresh process, which is
    what makes the socket backend trace-identical to the in-process
    engines."""
    tracer = tracer if tracer is not None else NULL_TRACER
    t0 = _time.perf_counter()
    with tracer.span("worker_round", cat="compute", wid=task.wid,
                     s_i=task.s_i, h=task.h_steps):
        sampler = ShardSampler(specs, task.lang,
                               cfg.batch_size, cfg.seq_len,
                               seed=cfg.seed * 977 + task.wid,
                               mixture=task.mixture)
        result = run_inner(model, cfg.inner, task.params,
                           task.opt, sampler, task.h_steps,
                           step_offset=task.inner_step_offset)
        delta = pseudo_gradient(task.params, result.params)
    # int8 rides the server's packed layout: per-block scales, O(1)
    # kernel launches, and a packed error-feedback buffer per worker.
    with tracer.span("compress_roundtrip", cat="compute", wid=task.wid):
        decoded, ef, nbytes = roundtrip_with_error_feedback(
            delta, task.ef, cfg.outer.compression,
            cfg.outer.topk_ratio, layout=layout)
    if not cfg.outer.error_feedback:
        ef = None
    return RoundResult(
        task_id=task.task_id, wid=task.wid, generation=task.generation,
        round_seq=task.round_seq, delta=decoded, opt=result.opt, ef=ef,
        nbytes=nbytes, s_i=task.s_i, h_steps=task.h_steps,
        lang=task.lang, compute_seconds=_time.perf_counter() - t0)


class Engine(Protocol):
    """What callers (launchers, benchmarks, examples) may rely on."""
    cfg: RunConfig
    server: Synchronizer
    workers: Dict[int, Worker]
    history: History
    time: float

    def run(self, eval_every: int = 0,
            eval_fn: Optional[Callable[[PyTree, int, float], Dict]] = None,
            ckpt_every: int = 0, ckpt_dir: str = "",
            budget: Optional[Budget] = None) -> History: ...
    def checkpoint(self, ckpt_dir: str) -> str: ...
    def restore(self, path: str) -> None: ...


# ---------------------------------------------------------------------------
# Shared engine implementation
# ---------------------------------------------------------------------------

class EngineBase:
    ENGINE_NAME = "sim"              # telemetry RunMeta.engine vocabulary:
    # the make_engine dialect ("sim" | "wallclock"), one value per engine

    def __init__(self, run_cfg: RunConfig, *,
                 failures: Optional[List[FailureEvent]] = None,
                 elastic: Optional[List[ElasticEvent]] = None,
                 telemetry=None, tracer=None,
                 runtime_record_every: int = 0):
        self.cfg = run_cfg
        self.model = build_model(run_cfg.model)
        self.specs = make_language_specs(run_cfg.model.vocab_size,
                                         n_langs=max(run_cfg.n_workers, 2),
                                         seed=run_cfg.seed)
        key = jax.random.PRNGKey(run_cfg.seed)
        init_params = self.model.init(key)
        # telemetry: a repro.telemetry.TelemetryRecorder (or None). The
        # synchronizer then emits update-quality stats from the same fused
        # sweeps (zero extra launches); the engine streams arrival/eval
        # records into the recorder at commit time, plus a periodic
        # "runtime" health snapshot every `runtime_record_every` commits.
        # tracer: a repro.obs.spans.SpanTracer (or None -> shared no-op)
        # timing worker rounds / commits / evals as Chrome trace spans.
        self.telemetry = telemetry
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.runtime_record_every = int(runtime_record_every or 0)
        topology = getattr(run_cfg, "topology", "hub")
        if topology != "hub":
            # NoLoCo-style decentralized exchange: per-worker replicas,
            # pairwise peer averaging instead of a hub server. Duck-types
            # the Synchronizer surface the engines consume.
            from repro.async_engine.topology import PeerMixer
            self.server = PeerMixer(init_params, run_cfg.outer,
                                    run_cfg.n_workers, kind=topology,
                                    seed=run_cfg.seed)
        else:
            self.server = Synchronizer(init_params, run_cfg.outer,
                                       run_cfg.n_workers,
                                       telemetry=telemetry is not None)
        self.workers: Dict[int, Worker] = {}
        for wid in range(run_cfg.n_workers):
            pace = run_cfg.worker_paces[wid % len(run_cfg.worker_paces)]
            mixture = self._mixture_for(wid)
            if mixture is not None:
                lang = int(np.argmax(mixture))   # dominant shard (accounting)
            else:
                lang = (wid % len(self.specs)) if run_cfg.non_iid else None
            self.workers[wid] = Worker(
                wid=wid, pace=pace, lang=lang, mixture=mixture,
                opt=init_adam(init_params))
        self.failures = sorted(failures or [], key=lambda f: f.time)
        self.elastic = sorted(elastic or [], key=lambda e: e.time)
        self.lang_tokens = np.zeros(len(self.specs), np.int64)
        self.history = History()
        self.time = 0.0
        self._heap: List[Tuple[float, int, str, int, int]] = []
        self._seq = 0
        self._task_counter = 0
        self._min_pace = min(w.pace for w in self.workers.values())
        self._stop = False               # cooperative kill switch (request_stop)
        self.restored_arrivals = 0       # commits accounted by a restored ckpt

    def request_stop(self) -> None:
        """Cooperative kill switch: the run loop exits at the next commit
        boundary (server state stays consistent — a checkpoint taken after
        ``run`` returns is a valid resume point). Models killing the
        server mid-run; combined with ``checkpoint``/``restore`` it is the
        recovery path docs/faults.md describes."""
        self._stop = True

    # -------------------------------------------------------- engine hooks
    def _submit(self, task: RoundTask) -> None:
        """Hand a captured round to whatever executes it."""
        raise NotImplementedError

    def _obtain(self, w: Worker) -> RoundResult:
        """Produce/collect the result of the worker's outstanding round."""
        raise NotImplementedError

    def _sleep_per_step(self, w: Worker) -> float:
        """Wall-clock pace throttle (free-running runtime only)."""
        return 0.0

    def _on_worker_removed(self, w: Worker) -> None:
        """Crash / elastic-leave notification (runtime stops the thread)."""

    # ------------------------------------------------------------------ utils
    def _push(self, time: float, kind: str, wid: int, gen: int):
        heapq.heappush(self._heap, (time, self._seq, kind, wid, gen))
        self._seq += 1

    def _mixture_for(self, wid: int) -> Optional[Tuple[float, ...]]:
        """Per-worker Dirichlet language mixture (deterministic in
        (seed, wid), stable across crash/rejoin and elastic join)."""
        if not (self.cfg.non_iid and self.cfg.mixture_alpha):
            return None
        return tuple(mixture_weights(len(self.specs), self.cfg.mixture_alpha,
                                     wid, seed=self.cfg.seed))

    def _h_steps(self, w: Worker) -> int:
        if self.cfg.dylu:
            return max(1, int(round(self.cfg.inner_steps *
                                    self._min_pace / w.pace)))
        return self.cfg.inner_steps

    def _pick_lang(self, w: Worker) -> Optional[int]:
        if not self.cfg.non_iid:
            return None
        if w.mixture is not None:        # Dirichlet mixture: lang is the
            return w.lang                # dominant shard (accounting only)
        if self.cfg.shard_assignment == "flexible":
            return int(np.argmin(self.lang_tokens))
        return w.lang

    # --------------------------------------------------------------- dispatch
    def _make_task(self, w: Worker) -> RoundTask:
        """Capture the worker's initialization + round snapshot (server
        thread only — reads Synchronizer state and shard accounting)."""
        w.params = jax.tree.map(jnp.copy, self.server.worker_init(w.wid))
        w.s_i = self.server.t
        w.h_steps = self._h_steps(w)
        w.cur_lang = self._pick_lang(w)
        w.dispatch_time = self.time
        w.round_seq += 1
        w.in_flight = True
        self._task_counter += 1
        w.pending_task_id = self._task_counter
        return RoundTask(
            task_id=self._task_counter,
            wid=w.wid, generation=w.generation, round_seq=w.round_seq,
            params=w.params, opt=w.opt, ef=w.ef, s_i=w.s_i,
            h_steps=w.h_steps, lang=w.cur_lang, mixture=w.mixture,
            inner_step_offset=w.inner_step_count,
            dispatch_time=self.time,
            sleep_per_step=self._sleep_per_step(w), device=w.device)

    def _dispatch(self, w: Worker):
        """Capture the round, schedule its virtual return, submit it."""
        task = self._make_task(w)
        if self._use_virtual_clock():
            self._push(self.time + task.h_steps * w.pace, "return",
                       w.wid, w.generation)
        self._submit(task)

    def _use_virtual_clock(self) -> bool:
        return True

    # ------------------------------------------------------------ inner round
    def _execute(self, task: RoundTask) -> RoundResult:
        """Run one inner round from the task snapshot. Reads no mutable
        engine state — safe to call from any thread, results of a lost
        (crashed-generation) round can be discarded without side effects."""
        layout = (self.server.layout
                  if self.cfg.outer.compression == "int8" else None)
        return execute_round(task, model=self.model, cfg=self.cfg,
                             specs=self.specs, layout=layout,
                             tracer=self.tracer)

    # ----------------------------------------------------------------- commit
    def _commit_worker(self, w: Worker, res: RoundResult):
        """Fold a completed round back into worker + shared accounting
        (server thread only; order of commits defines the history)."""
        w.opt = res.opt
        w.ef = res.ef
        w.inner_step_count += res.h_steps
        w.in_flight = False
        w.pending_task_id = None
        toks = res.h_steps * self.cfg.batch_size * self.cfg.seq_len
        self.history.tokens += toks
        if res.lang is not None:
            self.lang_tokens[res.lang] += toks
        self.history.comm_bytes += res.nbytes

    def _commit(self, w: Worker, res: RoundResult):
        self._commit_worker(w, res)
        with self.tracer.span("server_commit", cat="server", wid=res.wid,
                              s_i=res.s_i):
            rec = self.server.on_arrival(
                res.delta, res.s_i, res.wid, sim_time=self.time,
                lang=(self.specs[res.lang].lang
                      if res.lang is not None else "iid"))
        self.history.arrivals.append(rec.__dict__)
        if self.telemetry is not None:
            self.telemetry.record_arrival(rec, mixture=w.mixture,
                                          tokens_total=self.history.tokens)
        return rec

    def _post_commit(self, eval_every, eval_fn, ckpt_every, ckpt_dir):
        t = self.server.t
        if eval_every and eval_fn and t % eval_every == 0:
            with self.tracer.span("eval", cat="eval", step=t):
                ev = eval_fn(self.server.state.params, t, self.time)
            self.history.evals.append(ev)
            if self.telemetry is not None:
                self.telemetry.record_eval(ev)
        if ckpt_every and ckpt_dir and t % ckpt_every == 0:
            with self.tracer.span("checkpoint", cat="ckpt", step=t):
                self.checkpoint(ckpt_dir)
        if (self.telemetry is not None and self.runtime_record_every
                and len(self.history.arrivals)
                % self.runtime_record_every == 0):
            self._record_runtime()

    # ----------------------------------------------- runtime health records
    def _runtime_snapshot(self) -> Dict:
        """Worker-membership health view; the concurrent runtime overrides
        this to add occupancy/parallelism/queue/liveness/delivery from its
        live counters. Pure observation: no jax ops, no RNG — telemetry-on
        runs stay byte-identical to the goldens."""
        return {
            "workers_alive": sum(1 for w in self.workers.values()
                                 if w.alive),
            "workers_total": len(self.workers),
            "in_flight": sum(1 for w in self.workers.values()
                             if w.in_flight),
        }

    def _record_runtime(self):
        if self.telemetry is None:
            return
        self.telemetry.record_runtime(outer_step=self.server.t,
                                      sim_time=self.time,
                                      **self._runtime_snapshot())

    def _finalize(self, eval_fn) -> History:
        self.history.final_time = self.time
        if eval_fn and (not self.history.evals
                        or self.history.evals[-1]["step"] != self.server.t):
            with self.tracer.span("eval", cat="eval", step=self.server.t):
                ev = eval_fn(self.server.state.params, self.server.t,
                             self.time)
            self.history.evals.append(ev)
            if self.telemetry is not None:
                self.telemetry.record_eval(ev)
        if self.telemetry is not None and self.runtime_record_every:
            self._record_runtime()           # end-of-run snapshot
        return self.history

    # -------------------------------------------------------------- main loop
    def _ensure_telemetry_meta(self):
        if self.telemetry is not None:
            self.telemetry.ensure_meta(
                method=self.server.method.name,
                engine=self.ENGINE_NAME,
                n_workers=self.cfg.n_workers,
                outer_steps=self.cfg.outer_steps,
                seed=self.cfg.seed,
                non_iid=self.cfg.non_iid,
                mixture_alpha=self.cfg.mixture_alpha)

    def run(self, eval_every: int = 0,
            eval_fn: Optional[Callable[[PyTree, int, float], Dict]] = None,
            ckpt_every: int = 0, ckpt_dir: str = "",
            budget: Optional[Budget] = None) -> History:
        self._ensure_telemetry_meta()
        if self.server.method.sync:
            return self._run_sync(eval_every, eval_fn, ckpt_every, ckpt_dir,
                                  budget)
        return self._run_async(eval_every, eval_fn, ckpt_every, ckpt_dir,
                               budget)

    def _run_async(self, eval_every, eval_fn, ckpt_every, ckpt_dir,
                   budget: Optional[Budget] = None) -> History:
        """Virtual-clock event loop. Used by the simulator AND by the
        deterministic wall-clock runtime (which overlaps compute but
        commits in exactly this event order)."""
        for w in self.workers.values():
            if w.alive and not w.in_flight:
                self._dispatch(w)
        fail_idx = el_idx = 0
        target = self.cfg.outer_steps
        while self.server.t < target and self._heap and not self._stop:
            time, _, kind, wid, gen = heapq.heappop(self._heap)
            if budget is not None and budget.over_time(time):
                break   # fixed clock horizon: never commit past it
            # interleave failure / elastic events that occur first
            while (fail_idx < len(self.failures)
                   and self.failures[fail_idx].time <= time):
                self._handle_failure(self.failures[fail_idx])
                fail_idx += 1
            while (el_idx < len(self.elastic)
                   and self.elastic[el_idx].time <= time):
                self._handle_elastic(self.elastic[el_idx])
                el_idx += 1
            self.time = time
            if kind == "restart":
                w = self.workers.get(wid)
                if w is not None:
                    w.alive = True
                    self._dispatch(w)
                continue
            w = self.workers.get(wid)
            if w is None or not w.alive or gen != w.generation:
                continue  # stale event (crashed/removed worker)
            res = self._obtain(w)
            self._commit(w, res)
            self._post_commit(eval_every, eval_fn, ckpt_every, ckpt_dir)
            if budget is not None and budget.over_tokens(self.history.tokens):
                break   # token budget reached at this commit
            if self.server.t < target:
                self._dispatch(w)
        return self._finalize(eval_fn)

    # ------------------------------------------------------------- sync mode
    def _execute_sync(self, tasks: List[RoundTask]) -> List[RoundResult]:
        """Barrier round execution; the concurrent runtime overrides this
        to compute all workers in parallel threads."""
        return [self._execute(t) for t in tasks]

    def _run_sync(self, eval_every, eval_fn, ckpt_every, ckpt_dir,
                  budget: Optional[Budget] = None) -> History:
        target = self.cfg.outer_steps
        while self.server.t < target and not self._stop:
            alive = [w for w in self.workers.values() if w.alive]
            round_time = max(self._h_steps(w) * w.pace for w in alive)
            if budget is not None and budget.over_time(self.time + round_time):
                break   # the next barrier round would cross the horizon
            tasks = [self._make_task(w) for w in alive]
            results = self._execute_sync(tasks)
            for w, res in zip(alive, results):
                self._commit_worker(w, res)
            self.time += round_time  # barrier: slowest worker gates the round
            rec = self.server.on_sync_round([r.delta for r in results],
                                            sim_time=self.time)
            self.history.arrivals.append(rec.__dict__)
            if self.telemetry is not None:
                self.telemetry.record_arrival(
                    rec, tokens_total=self.history.tokens)
            self._post_commit(eval_every, eval_fn, ckpt_every, ckpt_dir)
            if budget is not None and budget.over_tokens(self.history.tokens):
                break
        return self._finalize(eval_fn)

    # ------------------------------------------------------- fault tolerance
    def _crash_worker(self, w: Worker):
        """Shared crash bookkeeping: the in-flight round is lost."""
        w.alive = False
        w.generation += 1
        w.ef = None
        w.in_flight = False
        w.pending_task_id = None

    def _handle_failure(self, ev: FailureEvent):
        w = self.workers.get(ev.wid)
        if w is None:
            return
        self._crash_worker(w)
        self._push(ev.time + ev.restart_delay, "restart", w.wid, w.generation)

    def _handle_elastic(self, ev: ElasticEvent):
        if ev.action == "join":
            mixture = self._mixture_for(ev.wid)
            lang = (int(np.argmax(mixture)) if mixture is not None
                    else ev.lang)
            w = Worker(wid=ev.wid, pace=ev.pace, lang=lang, mixture=mixture,
                       opt=init_adam(self.server.state.params))
            self.workers[ev.wid] = w
            self.server.set_n_workers(
                sum(1 for x in self.workers.values() if x.alive))
            self._dispatch(w)
        elif ev.action == "leave":
            w = self.workers.pop(ev.wid, None)
            if w is not None:
                w.generation += 1
                self._on_worker_removed(w)
            self.server.set_n_workers(
                sum(1 for x in self.workers.values() if x.alive))
        self._min_pace = min((x.pace for x in self.workers.values()
                              if x.alive), default=1.0)

    # ---------------------------------------------------------- checkpointing
    def server_tree(self) -> Dict:
        state = self.server.state
        tree = {"params": state.params, "momentum": state.momentum,
                "step": state.step}
        if state.aux is not None:        # per-method auxiliary state
            tree["aux"] = state.aux      # (e.g. delayed-Nesterov buffer)
        return tree

    def checkpoint(self, ckpt_dir: str) -> str:
        path = os.path.join(ckpt_dir, f"step_{self.server.t}.npz")
        meta = {"time": self.time, "tokens": int(self.history.tokens),
                "arrivals": len(self.history.arrivals)}
        ckpt.save(path, self.server_tree(), meta)
        return path

    def restore(self, path: str):
        tree, meta = ckpt.restore(path, self.server_tree())
        self.server.state = self.server.state._replace(
            params=tree["params"],
            momentum=tree["momentum"],
            step=jnp.asarray(tree["step"]),
            aux=tree.get("aux", self.server.state.aux))
        self.time = float(meta.get("time", 0.0))
        self.history.tokens = int(meta.get("tokens", 0))
        # committed-arrival count up to the checkpoint: a resumed run's
        # total accounting is restored_arrivals + len(history.arrivals)
        self.restored_arrivals = int(meta.get("arrivals", 0))
        self._stop = False
        # in-flight worker rounds are lost on restart (real-world semantics)
        self._heap.clear()
        for w in self.workers.values():
            w.generation += 1
            w.in_flight = False
            w.pending_task_id = None
            if w.alive:
                self._dispatch(w)


# ---------------------------------------------------------------------------
# Factory + shared eval protocol
# ---------------------------------------------------------------------------

ENGINES = ("sim", "wallclock")


def make_engine(run_cfg: RunConfig, engine: Optional[str] = None, *,
                failures: Optional[List[FailureEvent]] = None,
                elastic: Optional[List[ElasticEvent]] = None,
                telemetry=None, tracer=None,
                runtime_record_every: Optional[int] = None,
                **runtime_kw) -> Engine:
    """Build a training engine. ``engine``: "sim" (default, virtual clock)
    or "wallclock" (threaded ``ConcurrentRuntime``; extra keywords —
    ``mode``, ``pace_scale``, ``transport``, ... — are forwarded to it).
    ``telemetry``: optional ``repro.telemetry.TelemetryRecorder`` the run
    streams arrival/eval diagnostics into (valid alongside a Scenario —
    observation, not configuration). ``tracer``: optional
    ``repro.obs.spans.SpanTracer`` recording worker-round / transport /
    commit / eval spans (same observation-only status).
    ``runtime_record_every``: emit a telemetry "runtime" health snapshot
    every N commits (None defers to the Scenario's ``telemetry_every``
    knob; 0 disables).

    Also accepts a ``repro.scenarios`` ``Scenario`` as the first argument:
    its ``materialize()`` then supplies the run config, engine choice,
    runtime options, and failure/elastic schedules — the declarative
    single-source-of-truth entry point."""
    if hasattr(run_cfg, "materialize"):          # Scenario (duck-typed to
        if engine is not None or failures or elastic or runtime_kw:
            raise TypeError("pass the engine choice, schedules, and "
                            "options inside the Scenario, not alongside it")
        if telemetry is not None:
            telemetry.ensure_meta(
                method=run_cfg.method, engine=run_cfg.engine,
                n_workers=run_cfg.n_workers,
                outer_steps=run_cfg.outer_steps, seed=run_cfg.seed,
                non_iid=run_cfg.non_iid,
                mixture_alpha=run_cfg.mixture_alpha,
                scenario=run_cfg.name)
        if runtime_record_every is None:
            runtime_record_every = getattr(run_cfg, "telemetry_every", 0)
        m = run_cfg.materialize()                # avoids a circular import
        return make_engine(m.run_cfg, m.engine, failures=m.failures,
                           elastic=m.elastic, telemetry=telemetry,
                           tracer=tracer,
                           runtime_record_every=runtime_record_every,
                           **m.engine_kw)
    obs_kw = dict(telemetry=telemetry, tracer=tracer,
                  runtime_record_every=runtime_record_every or 0)
    engine = engine or "sim"
    if engine in ("sim", "simulator", "virtual"):
        if runtime_kw:
            raise TypeError(f"simulator takes no runtime options: {runtime_kw}")
        from repro.async_engine.simulator import AsyncSimulator
        return AsyncSimulator(run_cfg, failures=failures, elastic=elastic,
                              **obs_kw)
    if engine in ("wallclock", "concurrent", "runtime"):
        from repro.async_engine.runtime import ConcurrentRuntime
        return ConcurrentRuntime(run_cfg, failures=failures, elastic=elastic,
                                 **obs_kw, **runtime_kw)
    raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")


def make_eval_fn(engine, batch: int = 16, seq: int = None):
    """Per-language + mean validation loss (Fig. 2/3 protocol)."""
    seq = seq or engine.cfg.seq_len
    batches = eval_batches(engine.specs, batch, seq,
                           seed=engine.cfg.seed + 4242)
    model = engine.model

    @jax.jit
    def loss_of(params, tokens, labels):
        return model.loss(params, {"tokens": tokens, "labels": labels})[0]

    def eval_fn(params, step, time):
        per = {}
        for b in batches:
            per[b["lang"]] = float(loss_of(params, jnp.asarray(b["tokens"]),
                                           jnp.asarray(b["labels"])))
        mean = float(np.mean(list(per.values())))
        return {"step": step, "time": time, "mean": mean, "per_lang": per}

    return eval_fn
