"""Shared Engine contract for the asynchronous training runtimes.

Two engines implement it:

  - ``AsyncSimulator`` (``repro.async_engine.simulator``): event-driven
    virtual clock — the paper's reference runtime. Inner rounds execute
    serially at event-pop time; only *time* is simulated.
  - ``ConcurrentRuntime`` (``repro.async_engine.runtime``): wall-clock
    concurrency — one thread per worker (optionally pinned to its own
    ``jax.devices()`` entry), pseudo-gradients travel through a
    ``Transport``, and the server applies the packed fused update while
    other workers keep computing.

The contract is enforced structurally: everything that must behave
identically across engines lives here —

  - worker bookkeeping (``Worker``), dispatch capture (``_make_task``),
    the functional inner round (``_execute``: reads only its ``RoundTask``
    snapshot, so it is safe on any thread and a lost round leaves no
    trace), and the server-side commit (``_commit``: optimizer state,
    token/byte accounting, ``Synchronizer.on_arrival``);
  - the virtual-clock event loop (``_run_async``) with failure injection,
    elastic membership, and checkpoint cadence. The deterministic
    wall-clock mode reuses this loop verbatim — arrivals are committed in
    virtual-deadline order regardless of which thread finished first,
    which is the determinism contract (see docs/runtime.md): a
    FIFO-forced ``ConcurrentRuntime`` reproduces the simulator's arrival
    sequence ``(wid, s_i, staleness, lang)`` exactly.

Subclasses provide two hooks: ``_submit`` (where a captured round goes —
nowhere for the simulator, a worker inbox for the runtime) and
``_obtain`` (how the result comes back — computed in-line vs. received
through the transport).
"""
from __future__ import annotations

import os
import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import RunConfig
from repro.core.compression import roundtrip_with_error_feedback
from repro.obs.spans import NULL_TRACER
from repro.async_engine.server import Synchronizer
from repro.data.synthetic import (
    ShardSampler, eval_batches, make_language_specs, mixture_weights,
)
from repro.models import build_model
from repro.optim.adamw import init_adam
from repro.train.inner import pseudo_gradient, run_inner

PyTree = Any


# ---------------------------------------------------------------------------
# Shared datatypes
# ---------------------------------------------------------------------------

class WorkerArena:
    """NumPy struct-of-arrays store for per-worker engine state.

    At O(10k) workers the per-worker bookkeeping dominated the event
    loop: every ``Worker`` was a Python dataclass, so aggregate queries
    (alive count, in-flight count, min pace) were full dict walks. Here
    every scalar field lives in one flat array indexed by slot; the
    ``Worker`` objects the engines pass around are thin views
    (``__slots__`` + properties) over a slot, so the per-worker
    attribute API is unchanged while aggregates become single vectorized
    reductions (docs/scale.md).

    Slots are recycled: elastic leave releases a slot (clearing its
    object cells so params/optimizer trees don't outlive the worker),
    a later join reuses it. A released view must not be read after the
    slot is re-allocated.
    """

    SCALAR_FIELDS = (
        ("wid", np.int64, -1),
        ("pace", np.float64, 1.0),       # seconds per inner step (virtual)
        ("s_i", np.int64, 0),            # outer step at dispatch
        ("h_steps", np.int64, 0),        # local steps this round
        ("inner_step_count", np.int64, 0),  # lifetime steps (LR schedule)
        ("dispatch_time", np.float64, 0.0),
        ("generation", np.int64, 0),     # bumped on crash: stale rounds drop
        ("round_seq", np.int64, 0),      # monotonic dispatch counter
        ("pending_task", np.int64, -1),  # engine-unique round id (-1 = none)
    )
    BOOL_FIELDS = (("used", True), ("alive", True), ("in_flight", False))
    OBJECT_FIELDS = ("lang", "mixture", "params", "opt", "ef", "cur_lang",
                     "device")

    def __init__(self, capacity: int = 64):
        cap = max(1, int(capacity))
        self.cols: Dict[str, np.ndarray] = {}
        for name, dt, _default in self.SCALAR_FIELDS:
            self.cols[name] = np.zeros(cap, dt)
        for name, _default in self.BOOL_FIELDS:
            self.cols[name] = np.zeros(cap, bool)
        for name in self.OBJECT_FIELDS:
            self.cols[name] = np.empty(cap, object)
        self._free = list(range(cap - 1, -1, -1))

    def _grow(self):
        old = len(self.cols["wid"])
        for name, arr in self.cols.items():
            ext = (np.empty(old, object) if arr.dtype == object
                   else np.zeros(old, arr.dtype))
            self.cols[name] = np.concatenate([arr, ext])
        self._free.extend(range(2 * old - 1, old - 1, -1))

    def alloc(self, wid: int) -> int:
        if not self._free:
            self._grow()
        slot = self._free.pop()
        for name, _dt, default in self.SCALAR_FIELDS:
            self.cols[name][slot] = default
        for name, default in self.BOOL_FIELDS:
            self.cols[name][slot] = default
        for name in self.OBJECT_FIELDS:
            self.cols[name][slot] = None
        self.cols["wid"][slot] = wid
        return slot

    def release(self, slot: int):
        self.cols["used"][slot] = False
        self.cols["alive"][slot] = False
        for name in self.OBJECT_FIELDS:
            self.cols[name][slot] = None     # drop param/opt references
        self._free.append(slot)

    # -- vectorized aggregates (O(capacity) array ops, no dict walks) -----
    def n_alive(self) -> int:
        return int(np.count_nonzero(self.cols["used"] & self.cols["alive"]))

    def n_in_flight(self) -> int:
        return int(np.count_nonzero(self.cols["used"]
                                    & self.cols["in_flight"]))

    def min_alive_pace(self, default: float = 1.0) -> float:
        mask = self.cols["used"] & self.cols["alive"]
        if not mask.any():
            return default
        return float(self.cols["pace"][mask].min())


def _scalar_prop(name, cast):
    def get(self):
        return cast(self.arena.cols[name][self.slot])

    def set(self, value):
        self.arena.cols[name][self.slot] = value

    return property(get, set)


def _object_prop(name):
    def get(self):
        return self.arena.cols[name][self.slot]

    def set(self, value):
        self.arena.cols[name][self.slot] = value

    return property(get, set)


class Worker:
    """Thin view over one ``WorkerArena`` slot — the attribute surface of
    the old per-worker dataclass, with every scalar living in the arena's
    flat arrays. Constructing one without an arena (standalone use) gives
    it a private single-slot arena."""

    __slots__ = ("arena", "slot")

    def __init__(self, wid: int, pace: float = 1.0,
                 lang: Optional[int] = None,
                 mixture: Optional[Tuple[float, ...]] = None,
                 params: PyTree = None, opt: Any = None, ef: PyTree = None,
                 device: Any = None, *,
                 arena: Optional[WorkerArena] = None):
        self.arena = arena if arena is not None else WorkerArena(1)
        self.slot = self.arena.alloc(wid)
        self.pace = pace
        self.lang = lang
        self.mixture = mixture
        self.params = params
        self.opt = opt
        self.ef = ef
        self.device = device

    wid = property(lambda self: int(self.arena.cols["wid"][self.slot]))
    pace = _scalar_prop("pace", float)
    s_i = _scalar_prop("s_i", int)
    h_steps = _scalar_prop("h_steps", int)
    inner_step_count = _scalar_prop("inner_step_count", int)
    dispatch_time = _scalar_prop("dispatch_time", float)
    generation = _scalar_prop("generation", int)
    round_seq = _scalar_prop("round_seq", int)
    alive = _scalar_prop("alive", bool)
    in_flight = _scalar_prop("in_flight", bool)
    lang = _object_prop("lang")
    mixture = _object_prop("mixture")
    params = _object_prop("params")
    opt = _object_prop("opt")
    ef = _object_prop("ef")
    cur_lang = _object_prop("cur_lang")
    device = _object_prop("device")

    @property
    def pending_task_id(self) -> Optional[int]:
        v = int(self.arena.cols["pending_task"][self.slot])
        return None if v < 0 else v

    @pending_task_id.setter
    def pending_task_id(self, value: Optional[int]):
        self.arena.cols["pending_task"][self.slot] = \
            -1 if value is None else int(value)

    def __repr__(self):
        return (f"Worker(wid={self.wid}, pace={self.pace}, "
                f"alive={self.alive}, in_flight={self.in_flight})")


class EventQueue:
    """Vectorized virtual-clock event queue.

    Events are (time, seq, kind, wid, gen) rows kept in NumPy column
    arrays sorted by (time, seq) — the exact order the old ``heapq``
    produced (seq is unique, so later tuple fields never tie-break).
    Pushes land in a staging list and merge lazily at the next pop, so a
    same-tick batch of K ready arrivals is ONE sorted-array slice
    (``pop_batch``) instead of K heap pops.

    Crash/rejoin storms orphan in-flight "return" events (their worker's
    generation has moved on); the engine reports each orphaning via
    ``note_stale`` and the queue compacts — one boolean-mask filter —
    as soon as stale entries outnumber live ones, so a storm can never
    make the loop quadratically re-pop dead events (``stale_skipped``
    counts the dead entries that survived to a pop; tests assert it
    stays bounded)."""

    KIND_RETURN = 0
    KIND_RESTART = 1
    _KINDS = {"return": KIND_RETURN, "restart": KIND_RESTART}
    _NAMES = ("return", "restart")
    _COMPACT_MIN = 64                # don't bother below this many entries

    def __init__(self):
        self._time = np.empty(0, np.float64)
        self._seq = np.empty(0, np.int64)
        self._kind = np.empty(0, np.int8)
        self._wid = np.empty(0, np.int64)
        self._gen = np.empty(0, np.int64)
        self._head = 0               # consumed prefix of the sorted arrays
        self._staging: List[Tuple] = []
        self._next_seq = 0
        self.stale = 0               # known-dead entries still queued
        self.stale_skipped = 0       # dead entries that reached a pop
        self.compactions = 0

    def __len__(self) -> int:
        return (len(self._time) - self._head) + len(self._staging)

    def push(self, time: float, kind: str, wid: int, gen: int):
        self._staging.append((float(time), self._next_seq,
                              self._KINDS[kind], int(wid), int(gen)))
        self._next_seq += 1

    def clear(self):
        self.__init__()

    def note_stale(self, n: int = 1):
        self.stale += n

    def note_skip(self):
        self.stale_skipped += 1
        self.stale = max(0, self.stale - 1)

    def _merge(self):
        if not self._staging:
            return
        t, s, k, w, g = (np.asarray(c) for c in zip(*self._staging))
        self._staging = []
        t = np.concatenate([self._time[self._head:], t.astype(np.float64)])
        s = np.concatenate([self._seq[self._head:], s.astype(np.int64)])
        k = np.concatenate([self._kind[self._head:], k.astype(np.int8)])
        w = np.concatenate([self._wid[self._head:], w.astype(np.int64)])
        g = np.concatenate([self._gen[self._head:], g.astype(np.int64)])
        order = np.lexsort((s, t))
        self._time, self._seq = t[order], s[order]
        self._kind, self._wid, self._gen = k[order], w[order], g[order]
        self._head = 0

    def pop_batch(self, max_n: int = 1) -> List[Tuple[float, str, int, int]]:
        """Pop the head event; when it is a "return", also pop up to
        ``max_n - 1`` further same-tick "return" events in seq order (a
        same-tick "restart" interleaved by seq ends the batch so global
        event order is preserved)."""
        self._merge()
        if self._head >= len(self._time):
            return []
        i = self._head
        t0 = self._time[i]
        if self._kind[i] != self.KIND_RETURN or max_n <= 1:
            end = i + 1
        else:
            tick_end = int(np.searchsorted(self._time, t0, side="right"))
            kinds = self._kind[i:tick_end]
            nonret = np.nonzero(kinds != self.KIND_RETURN)[0]
            end = i + int(nonret[0]) if len(nonret) else tick_end
            end = min(end, i + max_n)
        rows = [(float(self._time[j]), self._NAMES[self._kind[j]],
                 int(self._wid[j]), int(self._gen[j]))
                for j in range(i, end)]
        self._head = end
        return rows

    def maybe_compact(self, keep) -> bool:
        """Drop dead entries once they outnumber live ones. ``keep(kind,
        wid, gen) -> bool`` decides (restart events are always kept by
        the engine's predicate)."""
        n = len(self)
        if n < self._COMPACT_MIN or 2 * self.stale <= n:
            return False
        self._merge()
        mask = np.fromiter(
            (keep(self._NAMES[self._kind[j]], int(self._wid[j]),
                  int(self._gen[j]))
             for j in range(self._head, len(self._time))),
            bool, count=len(self._time) - self._head)
        for name in ("_time", "_seq", "_kind", "_wid", "_gen"):
            setattr(self, name, getattr(self, name)[self._head:][mask])
        self._head = 0
        self.stale = 0
        self.compactions += 1
        return True


@dataclass
class FailureEvent:
    time: float
    wid: int
    restart_delay: float = 60.0      # simulated seconds until rejoin


@dataclass
class ElasticEvent:
    time: float
    action: str                      # "join" | "leave"
    wid: int
    pace: float = 1.0
    lang: Optional[int] = None


#: most-recent arrivals kept in History.arrivals (same contract as
#: TelemetryRecorder's in-memory window; the unbounded per-commit stream
#: goes to the telemetry JSONL sink — docs/telemetry.md).
HISTORY_WINDOW = 4096


@dataclass
class History:
    """Run history. ``arrivals`` is a ring of the most recent ``window``
    arrival records — at O(10k) workers an unbounded list dominates
    memory — while ``total_arrivals`` counts every commit ever appended
    (summaries and checkpoint metadata use the total, never the ring
    length)."""
    arrivals: List[Dict] = field(default_factory=list)
    evals: List[Dict] = field(default_factory=list)
    tokens: int = 0
    comm_bytes: int = 0
    final_time: float = 0.0
    total_arrivals: int = 0
    window: int = HISTORY_WINDOW

    def append_arrival(self, rec: Dict):
        self.arrivals.append(rec)
        self.total_arrivals += 1
        if len(self.arrivals) > self.window:
            del self.arrivals[:len(self.arrivals) - self.window]

    def summary(self) -> Dict:
        return {
            "outer_steps": self.total_arrivals,
            "tokens": self.tokens,
            "comm_bytes": self.comm_bytes,
            "final_time": self.final_time,
            "final_eval": self.evals[-1] if self.evals else None,
        }


@dataclass(frozen=True)
class Budget:
    """Stopping rule for budgeted comparisons (paper Table 2): train to a
    fixed token count or a fixed (virtual/wall) clock horizon instead of a
    fixed number of outer steps. Both engines honour it within ONE outer
    round of the target:

      fixed_tokens     stop at the first commit whose cumulative token
                       count reaches ``amount``;
      fixed_wallclock  never commit an arrival past ``amount`` seconds of
                       engine time (sim: virtual; free-running: scaled
                       wall clock) — the run stops at the last arrival
                       inside the horizon.

    The configured ``outer_steps`` remains a hard cap on top.
    """
    kind: str                        # "fixed_tokens" | "fixed_wallclock"
    amount: float

    KINDS = ("fixed_tokens", "fixed_wallclock")

    def __post_init__(self):
        assert self.kind in self.KINDS, self.kind
        assert self.amount > 0, self.amount

    def over_time(self, t: float) -> bool:
        return self.kind == "fixed_wallclock" and t > self.amount + 1e-9

    def over_tokens(self, tokens: int) -> bool:
        return self.kind == "fixed_tokens" and tokens >= self.amount


@dataclass
class RoundTask:
    """Snapshot of one dispatched inner round. Captured on the server
    thread; ``_execute`` reads only this, never the live ``Worker``, so a
    concurrently-injected crash (generation bump) cannot race the compute
    — the stale result is simply discarded at commit."""
    task_id: int                     # engine-unique: never reused, even when
    wid: int                         # a wid rejoins as a fresh Worker
    generation: int
    round_seq: int
    params: PyTree
    opt: Any
    ef: PyTree
    s_i: int
    h_steps: int
    lang: Optional[int]
    inner_step_offset: int
    mixture: Optional[Tuple[float, ...]] = None
    dispatch_time: float = 0.0
    sleep_per_step: float = 0.0      # free-running pace throttle (wall sec)
    device: Any = None
    batch_size: int = 0              # per-round mini-batch (0 = cfg default;
    # nonzero under the hogwild ramp-up schedule, RunConfig.batch_rampup)


@dataclass
class RoundResult:
    task_id: int
    wid: int
    generation: int
    round_seq: int
    delta: PyTree
    opt: Any
    ef: PyTree
    nbytes: int
    s_i: int
    h_steps: int
    lang: Optional[int]
    compute_seconds: float = 0.0
    batch_size: int = 0              # per-round mini-batch actually trained
    # (0 = cfg default; token accounting uses this under ramp-up)


def execute_round(task: RoundTask, *, model, cfg: RunConfig, specs,
                  layout=None, tracer=None) -> RoundResult:
    """The functional inner round, shared VERBATIM between every engine
    thread and the socket worker processes: reads only the ``RoundTask``
    snapshot plus immutable run-wide state (model, config, language
    specs, optional packed int8 layout) — all deterministically
    reconstructible from the ``RunConfig`` in a fresh process, which is
    what makes the socket backend trace-identical to the in-process
    engines."""
    tracer = tracer if tracer is not None else NULL_TRACER
    t0 = _time.perf_counter()
    with tracer.span("worker_round", cat="compute", wid=task.wid,
                     s_i=task.s_i, h=task.h_steps):
        sampler = ShardSampler(specs, task.lang,
                               task.batch_size or cfg.batch_size,
                               cfg.seq_len,
                               seed=cfg.seed * 977 + task.wid,
                               mixture=task.mixture)
        result = run_inner(model, cfg.inner, task.params,
                           task.opt, sampler, task.h_steps,
                           step_offset=task.inner_step_offset)
        delta = pseudo_gradient(task.params, result.params)
    # int8 rides the server's packed layout: per-block scales, O(1)
    # kernel launches, and a packed error-feedback buffer per worker.
    with tracer.span("compress_roundtrip", cat="compute", wid=task.wid):
        decoded, ef, nbytes = roundtrip_with_error_feedback(
            delta, task.ef, cfg.outer.compression,
            cfg.outer.topk_ratio, layout=layout)
    if not cfg.outer.error_feedback:
        ef = None
    return RoundResult(
        task_id=task.task_id, wid=task.wid, generation=task.generation,
        round_seq=task.round_seq, delta=decoded, opt=result.opt, ef=ef,
        nbytes=nbytes, s_i=task.s_i, h_steps=task.h_steps,
        lang=task.lang, compute_seconds=_time.perf_counter() - t0,
        batch_size=task.batch_size)


class Engine(Protocol):
    """What callers (launchers, benchmarks, examples) may rely on."""
    cfg: RunConfig
    server: Synchronizer
    workers: Dict[int, Worker]
    history: History
    time: float

    def run(self, eval_every: int = 0,
            eval_fn: Optional[Callable[[PyTree, int, float], Dict]] = None,
            ckpt_every: int = 0, ckpt_dir: str = "",
            budget: Optional[Budget] = None) -> History: ...
    def checkpoint(self, ckpt_dir: str) -> str: ...
    def restore(self, path: str) -> None: ...


# ---------------------------------------------------------------------------
# Shared engine implementation
# ---------------------------------------------------------------------------

class EngineBase:
    ENGINE_NAME = "sim"              # telemetry RunMeta.engine vocabulary:
    # the make_engine dialect ("sim" | "wallclock"), one value per engine

    def __init__(self, run_cfg: RunConfig, *,
                 failures: Optional[List[FailureEvent]] = None,
                 elastic: Optional[List[ElasticEvent]] = None,
                 telemetry=None, tracer=None,
                 runtime_record_every: int = 0):
        self.cfg = run_cfg
        self.model = build_model(run_cfg.model)
        self.specs = make_language_specs(run_cfg.model.vocab_size,
                                         n_langs=max(run_cfg.n_workers, 2),
                                         seed=run_cfg.seed)
        key = jax.random.PRNGKey(run_cfg.seed)
        init_params = self.model.init(key)
        # telemetry: a repro.telemetry.TelemetryRecorder (or None). The
        # synchronizer then emits update-quality stats from the same fused
        # sweeps (zero extra launches); the engine streams arrival/eval
        # records into the recorder at commit time, plus a periodic
        # "runtime" health snapshot every `runtime_record_every` commits.
        # tracer: a repro.obs.spans.SpanTracer (or None -> shared no-op)
        # timing worker rounds / commits / evals as Chrome trace spans.
        self.telemetry = telemetry
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.runtime_record_every = int(runtime_record_every or 0)
        topology = getattr(run_cfg, "topology", "hub")
        if topology != "hub":
            # NoLoCo-style decentralized exchange: per-worker replicas,
            # pairwise peer averaging instead of a hub server. Duck-types
            # the Synchronizer surface the engines consume.
            from repro.async_engine.topology import PeerMixer
            self.server = PeerMixer(init_params, run_cfg.outer,
                                    run_cfg.n_workers, kind=topology,
                                    seed=run_cfg.seed)
        else:
            self.server = Synchronizer(
                init_params, run_cfg.outer, run_cfg.n_workers,
                telemetry=telemetry is not None,
                commit_batch=getattr(run_cfg, "commit_batch", 1))
        self.arena = WorkerArena(capacity=max(run_cfg.n_workers, 4))
        self.workers: Dict[int, Worker] = {}
        for wid in range(run_cfg.n_workers):
            pace = run_cfg.worker_paces[wid % len(run_cfg.worker_paces)]
            mixture = self._mixture_for(wid)
            if mixture is not None:
                lang = int(np.argmax(mixture))   # dominant shard (accounting)
            else:
                lang = (wid % len(self.specs)) if run_cfg.non_iid else None
            self.workers[wid] = Worker(
                wid=wid, pace=pace, lang=lang, mixture=mixture,
                opt=init_adam(init_params), arena=self.arena)
        self.failures = sorted(failures or [], key=lambda f: f.time)
        self.elastic = sorted(elastic or [], key=lambda e: e.time)
        self.lang_tokens = np.zeros(len(self.specs), np.int64)
        self.history = History()
        self.time = 0.0
        self._events = EventQueue()
        self._task_counter = 0
        self._min_pace = self.arena.min_alive_pace()
        self._stop = False               # cooperative kill switch (request_stop)
        self.restored_arrivals = 0       # commits accounted by a restored ckpt

    def request_stop(self) -> None:
        """Cooperative kill switch: the run loop exits at the next commit
        boundary (server state stays consistent — a checkpoint taken after
        ``run`` returns is a valid resume point). Models killing the
        server mid-run; combined with ``checkpoint``/``restore`` it is the
        recovery path docs/faults.md describes."""
        self._stop = True

    # -------------------------------------------------------- engine hooks
    def _submit(self, task: RoundTask) -> None:
        """Hand a captured round to whatever executes it."""
        raise NotImplementedError

    def _obtain(self, w: Worker) -> RoundResult:
        """Produce/collect the result of the worker's outstanding round."""
        raise NotImplementedError

    def _sleep_per_step(self, w: Worker) -> float:
        """Wall-clock pace throttle (free-running runtime only)."""
        return 0.0

    def _on_worker_removed(self, w: Worker) -> None:
        """Crash / elastic-leave notification (runtime stops the thread)."""

    # ------------------------------------------------------------------ utils
    def _push(self, time: float, kind: str, wid: int, gen: int):
        self._events.push(time, kind, wid, gen)

    def _event_is_live(self, kind: str, wid: int, gen: int) -> bool:
        """Compaction predicate: restart events always survive; a return
        event survives only while its (wid, generation) is still the live
        worker's outstanding round."""
        if kind == "restart":
            return True
        w = self.workers.get(wid)
        return w is not None and w.alive and w.generation == gen

    def _mixture_for(self, wid: int) -> Optional[Tuple[float, ...]]:
        """Per-worker Dirichlet language mixture (deterministic in
        (seed, wid), stable across crash/rejoin and elastic join)."""
        if not (self.cfg.non_iid and self.cfg.mixture_alpha):
            return None
        return tuple(mixture_weights(len(self.specs), self.cfg.mixture_alpha,
                                     wid, seed=self.cfg.seed))

    def _h_steps(self, w: Worker) -> int:
        if self.cfg.dylu:
            return max(1, int(round(self.cfg.inner_steps *
                                    self._min_pace / w.pace)))
        return self.cfg.inner_steps

    def _pick_lang(self, w: Worker) -> Optional[int]:
        if not self.cfg.non_iid:
            return None
        if w.mixture is not None:        # Dirichlet mixture: lang is the
            return w.lang                # dominant shard (accounting only)
        if self.cfg.shard_assignment == "flexible":
            return int(np.argmin(self.lang_tokens))
        return w.lang

    # --------------------------------------------------------------- dispatch
    def _make_task(self, w: Worker) -> RoundTask:
        """Capture the worker's initialization + round snapshot (server
        thread only — reads Synchronizer state and shard accounting)."""
        w.params = jax.tree.map(jnp.copy, self.server.worker_init(w.wid))
        w.s_i = self.server.t
        w.h_steps = self._h_steps(w)
        w.cur_lang = self._pick_lang(w)
        w.dispatch_time = self.time
        w.round_seq += 1
        w.in_flight = True
        self._task_counter += 1
        w.pending_task_id = self._task_counter
        return RoundTask(
            task_id=self._task_counter,
            wid=w.wid, generation=w.generation, round_seq=w.round_seq,
            params=w.params, opt=w.opt, ef=w.ef, s_i=w.s_i,
            h_steps=w.h_steps, lang=w.cur_lang, mixture=w.mixture,
            inner_step_offset=w.inner_step_count,
            dispatch_time=self.time,
            sleep_per_step=self._sleep_per_step(w), device=w.device,
            batch_size=self._round_batch())

    def _round_batch(self) -> int:
        """Per-round mini-batch under the hogwild ramp-up schedule
        (RunConfig.batch_rampup): linear from batch_size at t=0 to the
        target at the final outer step. 0 (= cfg.batch_size) without."""
        target = getattr(self.cfg, "batch_rampup", None)
        if not target:
            return 0
        frac = min(1.0, self.server.t / max(self.cfg.outer_steps - 1, 1))
        return max(1, int(round(self.cfg.batch_size
                                + frac * (target - self.cfg.batch_size))))

    def _dispatch(self, w: Worker):
        """Capture the round, schedule its virtual return, submit it."""
        task = self._make_task(w)
        if self._use_virtual_clock():
            self._push(self.time + task.h_steps * w.pace, "return",
                       w.wid, w.generation)
        self._submit(task)

    def _use_virtual_clock(self) -> bool:
        return True

    # ------------------------------------------------------------ inner round
    def _execute(self, task: RoundTask) -> RoundResult:
        """Run one inner round from the task snapshot. Reads no mutable
        engine state — safe to call from any thread, results of a lost
        (crashed-generation) round can be discarded without side effects."""
        layout = (self.server.layout
                  if self.cfg.outer.compression == "int8" else None)
        return execute_round(task, model=self.model, cfg=self.cfg,
                             specs=self.specs, layout=layout,
                             tracer=self.tracer)

    # ----------------------------------------------------------------- commit
    def _commit_worker(self, w: Worker, res: RoundResult):
        """Fold a completed round back into worker + shared accounting
        (server thread only; order of commits defines the history)."""
        w.opt = res.opt
        w.ef = res.ef
        w.inner_step_count += res.h_steps
        w.in_flight = False
        w.pending_task_id = None
        toks = (res.h_steps * (res.batch_size or self.cfg.batch_size)
                * self.cfg.seq_len)
        self.history.tokens += toks
        if res.lang is not None:
            self.lang_tokens[res.lang] += toks
        self.history.comm_bytes += res.nbytes

    def _commit(self, w: Worker, res: RoundResult):
        self._commit_worker(w, res)
        with self.tracer.span("server_commit", cat="server", wid=res.wid,
                              s_i=res.s_i):
            rec = self.server.on_arrival(
                res.delta, res.s_i, res.wid, sim_time=self.time,
                lang=(self.specs[res.lang].lang
                      if res.lang is not None else "iid"))
        self.history.append_arrival(rec.__dict__)
        if self.telemetry is not None:
            self.telemetry.record_arrival(rec, mixture=w.mixture,
                                          tokens_total=self.history.tokens)
        return rec

    def _commit_batch(self, pairs: List[Tuple[Worker, RoundResult]],
                      reason: str = "batch-full"):
        """Commit a coalesced batch of same-tick arrivals through the
        server's commit buffer: one fused multi-apply instead of
        len(pairs) sequential outer steps (docs/scale.md). Only reached
        with ``commit_batch > 1``; a batch of one goes through _commit.
        ``reason`` labels the trailing flush (why the batch was capped:
        batch-full / eval / ckpt / close) for the flush telemetry."""
        recs = []
        with self.tracer.span("server_commit_batch", cat="server",
                              k=len(pairs)):
            for w, res in pairs:
                self._commit_worker(w, res)
                out = self.server.buffer_arrival(
                    res.delta, res.s_i, res.wid, sim_time=self.time,
                    lang=(self.specs[res.lang].lang
                          if res.lang is not None else "iid"))
                if out:
                    recs.extend(out)
            recs.extend(self.server.flush(reason))
        for (w, _res), rec in zip(pairs, recs):
            self.history.append_arrival(rec.__dict__)
            if self.telemetry is not None:
                self.telemetry.record_arrival(rec, mixture=w.mixture,
                                              tokens_total=self.history.tokens)
        self._drain_flush_log()
        return recs

    def _drain_flush_log(self):
        """Turn the server's pending flush events into "flush" telemetry
        records (observation only; the log is tiny — one dict per flush
        since the last drain)."""
        log = getattr(self.server, "flush_log", None)
        if not log:
            return
        if self.telemetry is not None:
            for ev in log:
                self.telemetry.record_flush(outer_step=self.server.t,
                                            sim_time=self.time, **ev)
        log.clear()

    def _post_commit(self, eval_every, eval_fn, ckpt_every, ckpt_dir):
        t = self.server.t
        if eval_every and eval_fn and t % eval_every == 0:
            with self.tracer.span("eval", cat="eval", step=t):
                ev = eval_fn(self.server.state.params, t, self.time)
            self.history.evals.append(ev)
            if self.telemetry is not None:
                self.telemetry.record_eval(ev)
        if ckpt_every and ckpt_dir and t % ckpt_every == 0:
            with self.tracer.span("checkpoint", cat="ckpt", step=t):
                self.checkpoint(ckpt_dir)
        if (self.telemetry is not None and self.runtime_record_every
                and self.history.total_arrivals
                % self.runtime_record_every == 0):
            self._record_runtime()

    # ----------------------------------------------- runtime health records
    def _runtime_snapshot(self) -> Dict:
        """Worker-membership health view; the concurrent runtime overrides
        this to add occupancy/parallelism/queue/liveness/delivery from its
        live counters. Pure observation: no jax ops, no RNG — telemetry-on
        runs stay byte-identical to the goldens."""
        return {
            "workers_alive": self.arena.n_alive(),
            "workers_total": len(self.workers),
            "in_flight": self.arena.n_in_flight(),
        }

    def _record_runtime(self):
        if self.telemetry is None:
            return
        self.telemetry.record_runtime(outer_step=self.server.t,
                                      sim_time=self.time,
                                      **self._runtime_snapshot())

    def _finalize(self, eval_fn) -> History:
        self.history.final_time = self.time
        if eval_fn and (not self.history.evals
                        or self.history.evals[-1]["step"] != self.server.t):
            with self.tracer.span("eval", cat="eval", step=self.server.t):
                ev = eval_fn(self.server.state.params, self.server.t,
                             self.time)
            self.history.evals.append(ev)
            if self.telemetry is not None:
                self.telemetry.record_eval(ev)
        if self.telemetry is not None and self.runtime_record_every:
            self._record_runtime()           # end-of-run snapshot
        return self.history

    # -------------------------------------------------------------- main loop
    def _ensure_telemetry_meta(self):
        if self.telemetry is not None:
            self.telemetry.ensure_meta(
                method=self.server.method.name,
                engine=self.ENGINE_NAME,
                n_workers=self.cfg.n_workers,
                outer_steps=self.cfg.outer_steps,
                seed=self.cfg.seed,
                non_iid=self.cfg.non_iid,
                mixture_alpha=self.cfg.mixture_alpha)

    def run(self, eval_every: int = 0,
            eval_fn: Optional[Callable[[PyTree, int, float], Dict]] = None,
            ckpt_every: int = 0, ckpt_dir: str = "",
            budget: Optional[Budget] = None) -> History:
        self._ensure_telemetry_meta()
        if self.server.method.sync:
            return self._run_sync(eval_every, eval_fn, ckpt_every, ckpt_dir,
                                  budget)
        return self._run_async(eval_every, eval_fn, ckpt_every, ckpt_dir,
                               budget)

    def _run_async(self, eval_every, eval_fn, ckpt_every, ckpt_dir,
                   budget: Optional[Budget] = None) -> History:
        """Virtual-clock event loop. Used by the simulator AND by the
        deterministic wall-clock runtime (which overlaps compute but
        commits in exactly this event order).

        With ``RunConfig.commit_batch > 1``, up to that many same-tick
        ready arrivals pop as ONE vectorized batch and commit through the
        server's fused multi-apply; the batch is capped so an
        eval/checkpoint boundary always lands exactly at a batch end
        (docs/scale.md). commit_batch=1 is the exact sequential path."""
        for w in self.workers.values():
            if w.alive and not w.in_flight:
                self._dispatch(w)
        fail_idx = el_idx = 0
        target = self.cfg.outer_steps
        commit_batch = max(1, int(getattr(self.cfg, "commit_batch", 1)))
        while self.server.t < target and len(self._events) and not self._stop:
            # labelled cap: the tightest boundary names the flush reason
            # (min picks the FIRST minimal entry, so a coinciding
            # eval/ckpt boundary still reads "batch-full")
            limits = [(commit_batch, "batch-full"),
                      (target - self.server.t, "close")]
            if eval_every:
                limits.append((eval_every - self.server.t % eval_every,
                               "eval"))
            if ckpt_every:
                limits.append((ckpt_every - self.server.t % ckpt_every,
                               "ckpt"))
            cap, flush_reason = min(limits, key=lambda kv: kv[0])
            events = self._events.pop_batch(cap)
            time = events[0][0]
            if budget is not None and budget.over_time(time):
                break   # fixed clock horizon: never commit past it
            # interleave failure / elastic events that occur first
            while (fail_idx < len(self.failures)
                   and self.failures[fail_idx].time <= time):
                self._handle_failure(self.failures[fail_idx])
                fail_idx += 1
            while (el_idx < len(self.elastic)
                   and self.elastic[el_idx].time <= time):
                self._handle_elastic(self.elastic[el_idx])
                el_idx += 1
            self.time = time
            ready: List[Worker] = []
            for _t, kind, wid, gen in events:
                if kind == "restart":
                    w = self.workers.get(wid)
                    if w is not None:
                        w.alive = True
                        self._dispatch(w)
                    continue
                w = self.workers.get(wid)
                if w is None or not w.alive or gen != w.generation:
                    self._events.note_skip()
                    continue  # stale event (crashed/removed worker)
                ready.append(w)
            if not ready:
                continue
            if len(ready) == 1:
                self._commit(ready[0], self._obtain(ready[0]))
            else:
                self._commit_batch([(w, self._obtain(w)) for w in ready],
                                   reason=flush_reason)
            self._post_commit(eval_every, eval_fn, ckpt_every, ckpt_dir)
            if budget is not None and budget.over_tokens(self.history.tokens):
                break   # token budget reached at this commit
            for w in ready:
                if self.server.t < target:
                    self._dispatch(w)
        return self._finalize(eval_fn)

    # ------------------------------------------------------------- sync mode
    def _execute_sync(self, tasks: List[RoundTask]) -> List[RoundResult]:
        """Barrier round execution; the concurrent runtime overrides this
        to compute all workers in parallel threads."""
        return [self._execute(t) for t in tasks]

    def _run_sync(self, eval_every, eval_fn, ckpt_every, ckpt_dir,
                  budget: Optional[Budget] = None) -> History:
        target = self.cfg.outer_steps
        while self.server.t < target and not self._stop:
            alive = [w for w in self.workers.values() if w.alive]
            round_time = max(self._h_steps(w) * w.pace for w in alive)
            if budget is not None and budget.over_time(self.time + round_time):
                break   # the next barrier round would cross the horizon
            tasks = [self._make_task(w) for w in alive]
            results = self._execute_sync(tasks)
            for w, res in zip(alive, results):
                self._commit_worker(w, res)
            self.time += round_time  # barrier: slowest worker gates the round
            rec = self.server.on_sync_round([r.delta for r in results],
                                            sim_time=self.time)
            self.history.append_arrival(rec.__dict__)
            if self.telemetry is not None:
                self.telemetry.record_arrival(
                    rec, tokens_total=self.history.tokens)
            self._post_commit(eval_every, eval_fn, ckpt_every, ckpt_dir)
            if budget is not None and budget.over_tokens(self.history.tokens):
                break
        return self._finalize(eval_fn)

    # ------------------------------------------------------- fault tolerance
    def _crash_worker(self, w: Worker):
        """Shared crash bookkeeping: the in-flight round is lost."""
        if w.in_flight and self._use_virtual_clock():
            self._events.note_stale()    # its return event is now dead
        w.alive = False
        w.generation += 1
        w.ef = None
        w.in_flight = False
        w.pending_task_id = None
        self._events.maybe_compact(self._event_is_live)

    def _handle_failure(self, ev: FailureEvent):
        w = self.workers.get(ev.wid)
        if w is None:
            return
        self._crash_worker(w)
        self._push(ev.time + ev.restart_delay, "restart", w.wid, w.generation)

    def _handle_elastic(self, ev: ElasticEvent):
        if ev.action == "join":
            mixture = self._mixture_for(ev.wid)
            lang = (int(np.argmax(mixture)) if mixture is not None
                    else ev.lang)
            w = Worker(wid=ev.wid, pace=ev.pace, lang=lang, mixture=mixture,
                       opt=init_adam(self.server.state.params),
                       arena=self.arena)
            self.workers[ev.wid] = w
            self.server.set_n_workers(self.arena.n_alive())
            self._dispatch(w)
        elif ev.action == "leave":
            w = self.workers.pop(ev.wid, None)
            if w is not None:
                if w.in_flight and self._use_virtual_clock():
                    self._events.note_stale()
                w.generation += 1
                self._on_worker_removed(w)
                self.arena.release(w.slot)
                self._events.maybe_compact(self._event_is_live)
            self.server.set_n_workers(self.arena.n_alive())
        self._min_pace = self.arena.min_alive_pace(default=1.0)

    # ---------------------------------------------------------- checkpointing
    def server_tree(self) -> Dict:
        state = self.server.state
        tree = {"params": state.params, "momentum": state.momentum,
                "step": state.step}
        if state.aux is not None:        # per-method auxiliary state
            tree["aux"] = state.aux      # (e.g. delayed-Nesterov buffer)
        return tree

    def checkpoint(self, ckpt_dir: str) -> str:
        path = os.path.join(ckpt_dir, f"step_{self.server.t}.npz")
        meta = {"time": self.time, "tokens": int(self.history.tokens),
                "arrivals": self.history.total_arrivals}
        ckpt.save(path, self.server_tree(), meta)
        return path

    def restore(self, path: str):
        tree, meta = ckpt.restore(path, self.server_tree())
        self.server.state = self.server.state._replace(
            params=tree["params"],
            momentum=tree["momentum"],
            step=jnp.asarray(tree["step"]),
            aux=tree.get("aux", self.server.state.aux))
        self.time = float(meta.get("time", 0.0))
        self.history.tokens = int(meta.get("tokens", 0))
        # committed-arrival count up to the checkpoint: a resumed run's
        # total accounting is restored_arrivals + len(history.arrivals)
        self.restored_arrivals = int(meta.get("arrivals", 0))
        self._stop = False
        # in-flight worker rounds are lost on restart (real-world semantics)
        self._events.clear()
        for w in self.workers.values():
            w.generation += 1
            w.in_flight = False
            w.pending_task_id = None
            if w.alive:
                self._dispatch(w)


# ---------------------------------------------------------------------------
# Factory + shared eval protocol
# ---------------------------------------------------------------------------

ENGINES = ("sim", "wallclock")


def make_engine(run_cfg: RunConfig, engine: Optional[str] = None, *,
                failures: Optional[List[FailureEvent]] = None,
                elastic: Optional[List[ElasticEvent]] = None,
                telemetry=None, tracer=None,
                runtime_record_every: Optional[int] = None,
                **runtime_kw) -> Engine:
    """Build a training engine. ``engine``: "sim" (default, virtual clock)
    or "wallclock" (threaded ``ConcurrentRuntime``; extra keywords —
    ``mode``, ``pace_scale``, ``transport``, ... — are forwarded to it).
    ``telemetry``: optional ``repro.telemetry.TelemetryRecorder`` the run
    streams arrival/eval diagnostics into (valid alongside a Scenario —
    observation, not configuration). ``tracer``: optional
    ``repro.obs.spans.SpanTracer`` recording worker-round / transport /
    commit / eval spans (same observation-only status).
    ``runtime_record_every``: emit a telemetry "runtime" health snapshot
    every N commits (None defers to the Scenario's ``telemetry_every``
    knob; 0 disables).

    Also accepts a ``repro.scenarios`` ``Scenario`` as the first argument:
    its ``materialize()`` then supplies the run config, engine choice,
    runtime options, and failure/elastic schedules — the declarative
    single-source-of-truth entry point."""
    if hasattr(run_cfg, "materialize"):          # Scenario (duck-typed to
        if engine is not None or failures or elastic or runtime_kw:
            raise TypeError("pass the engine choice, schedules, and "
                            "options inside the Scenario, not alongside it")
        if telemetry is not None:
            telemetry.ensure_meta(
                method=run_cfg.method, engine=run_cfg.engine,
                n_workers=run_cfg.n_workers,
                outer_steps=run_cfg.outer_steps, seed=run_cfg.seed,
                non_iid=run_cfg.non_iid,
                mixture_alpha=run_cfg.mixture_alpha,
                scenario=run_cfg.name)
        if runtime_record_every is None:
            runtime_record_every = getattr(run_cfg, "telemetry_every", 0)
        m = run_cfg.materialize()                # avoids a circular import
        return make_engine(m.run_cfg, m.engine, failures=m.failures,
                           elastic=m.elastic, telemetry=telemetry,
                           tracer=tracer,
                           runtime_record_every=runtime_record_every,
                           **m.engine_kw)
    obs_kw = dict(telemetry=telemetry, tracer=tracer,
                  runtime_record_every=runtime_record_every or 0)
    engine = engine or "sim"
    if engine in ("sim", "simulator", "virtual"):
        if runtime_kw:
            raise TypeError(f"simulator takes no runtime options: {runtime_kw}")
        from repro.async_engine.simulator import AsyncSimulator
        return AsyncSimulator(run_cfg, failures=failures, elastic=elastic,
                              **obs_kw)
    if engine in ("wallclock", "concurrent", "runtime"):
        from repro.async_engine.runtime import ConcurrentRuntime
        return ConcurrentRuntime(run_cfg, failures=failures, elastic=elastic,
                                 **obs_kw, **runtime_kw)
    raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")


def make_eval_fn(engine, batch: int = 16, seq: int = None):
    """Per-language + mean validation loss (Fig. 2/3 protocol)."""
    seq = seq or engine.cfg.seq_len
    batches = eval_batches(engine.specs, batch, seq,
                           seed=engine.cfg.seed + 4242)
    model = engine.model

    @jax.jit
    def loss_of(params, tokens, labels):
        return model.loss(params, {"tokens": tokens, "labels": labels})[0]

    def eval_fn(params, step, time):
        per = {}
        for b in batches:
            per[b["lang"]] = float(loss_of(params, jnp.asarray(b["tokens"]),
                                           jnp.asarray(b["labels"])))
        mean = float(np.mean(list(per.values())))
        return {"step": step, "time": time, "mean": mean, "per_lang": per}

    return eval_fn
