"""Delivery-robustness layer: deterministic fault injection and the
server-side at-least-once bookkeeping.

HeLoCo's system-heterogeneity claim only means something if the runtime
survives an *unreliable* channel — DiLoCo motivates local-step training
with poorly connected, failure-prone devices, and coordinator-less
topologies (NoLoCo) make lossy links the norm. This module provides:

  ``FaultSpec``         a frozen, seeded description of channel
                        pathology: drop / duplicate / reorder / delay /
                        corrupt probabilities, ack loss, partition
                        windows, plus the detection policy knobs
                        (heartbeat cadence, liveness misses, quarantine
                        threshold, retry timeouts). A scenario axis:
                        ``Scenario.faults``.
  ``FaultyTransport``   wraps any inner ``Transport`` and injects those
                        faults *deterministically*: every decision is a
                        pure function of ``(seed, stream, wid, seq,
                        attempt)``, so a chaos run is replayable no
                        matter how threads interleave, and a retried
                        frame draws fresh dice.
  ``DeliveryTracker``   the receiver half of at-least-once delivery:
                        CRC verification, ``(wid, generation, seq)``
                        dedup of redeliveries, consecutive-corruption
                        quarantine, and the delivery-health counters
                        surfaced in ``ConcurrentRuntime.stats()`` and
                        the telemetry ``fault`` records.

The determinism contract under faults (docs/faults.md): with retries and
dedup, the *committed* history of a deterministic-mode run is identical
to its fault-free twin — drop/duplicate/reorder/delay/corrupt change
only wall-clock latency and the delivery counters, never the arrival
sequence or the final parameters. The chaos golden traces pin this.
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

from repro.async_engine.transport import (
    Envelope, KIND_RESULT, Transport, payload_crc,
)


# ---------------------------------------------------------------------------
# Deterministic per-message dice: splitmix64 over a mixed key
# ---------------------------------------------------------------------------

_MASK = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return x ^ (x >> 31)


def _unit(seed: int, *key: int) -> float:
    """Deterministic uniform [0, 1) from an integer key. Thread-safe by
    construction (no shared state): fault decisions depend only on the
    message identity, never on call order."""
    x = seed & _MASK
    for k in key:
        x = _splitmix64(x ^ (k & _MASK))
    return x / float(1 << 64)


# stream salts: independent dice per fault type / channel
_S_DROP, _S_DUP, _S_REORDER, _S_DELAY, _S_CORRUPT, _S_ACK, _S_JITTER = \
    range(1, 8)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PartitionSpec:
    """A network partition window on the scenario's virtual clock:
    frames (data AND heartbeats) from ``wids`` are black-holed while
    ``start <= t < end``. Empty ``wids`` partitions every worker.
    Requires a free-running runtime (the deterministic mode has no
    wall-to-virtual coupling to evaluate the window against)."""
    start: float
    end: float
    wids: Tuple[int, ...] = ()

    def __post_init__(self):
        assert self.end > self.start >= 0.0, (self.start, self.end)

    def covers(self, wid: int, t: float) -> bool:
        return (self.start <= t < self.end
                and (not self.wids or wid in self.wids))


@dataclass(frozen=True)
class FaultSpec:
    """Seeded description of an unreliable delivery layer.

    Injection probabilities (per frame attempt, deterministic in
    ``(seed, wid, seq, attempt)``):

      drop_p     frame silently black-holed;
      dup_p      frame delivered twice;
      reorder_p  frame shelved and released after the next frame passes
                 (adjacent swap — FIFO broken);
      delay_p    frame held ``delay_s`` wall seconds before delivery;
      corrupt_p  frame delivered with a corrupted checksum (payload
                 integrity violation; the receiver must reject it);
      ack_drop_p the delivery receipt is lost (classic duplicate cause).

    ``corrupt_wids`` scopes corruption to specific workers (None = all);
    ``partitions`` are virtual-clock blackout windows (free mode only).

    Protocol / policy knobs consumed by the runtime:

      ack_timeout        seconds a worker waits for an ack before
                         resending (exponential backoff ``backoff_base``
                         capped at ``max_backoff``, plus deterministic
                         jitter);
      heartbeat_interval liveness beacon cadence in wall seconds
                         (0 = heartbeats disabled);
      liveness_misses    missed intervals before the server declares a
                         silent worker dead (crash/rejoin machinery);
      quarantine_after   consecutive corrupt frames from one worker
                         before the server stops accepting it.
    """
    drop_p: float = 0.0
    dup_p: float = 0.0
    reorder_p: float = 0.0
    delay_p: float = 0.0
    delay_s: float = 0.0
    corrupt_p: float = 0.0
    ack_drop_p: float = 0.0
    corrupt_wids: Optional[Tuple[int, ...]] = None
    partitions: Tuple[PartitionSpec, ...] = ()
    seed: int = 0
    # protocol / policy
    ack_timeout: float = 0.25
    backoff_base: float = 2.0
    max_backoff: float = 2.0
    heartbeat_interval: float = 0.0
    liveness_misses: int = 3
    quarantine_after: int = 8

    def __post_init__(self):
        for name in ("drop_p", "dup_p", "reorder_p", "delay_p",
                     "corrupt_p", "ack_drop_p"):
            p = getattr(self, name)
            assert 0.0 <= p <= 1.0, (name, p)
        assert self.ack_timeout > 0 and self.backoff_base >= 1.0
        assert self.quarantine_after >= 1 and self.liveness_misses >= 1

    # ------------------------------------------------------------- decisions
    def drops(self, wid: int, seq: int, attempt: int) -> bool:
        return _unit(self.seed, _S_DROP, wid, seq, attempt) < self.drop_p

    def duplicates(self, wid: int, seq: int, attempt: int) -> bool:
        return _unit(self.seed, _S_DUP, wid, seq, attempt) < self.dup_p

    def reorders(self, wid: int, seq: int, attempt: int) -> bool:
        return _unit(self.seed, _S_REORDER, wid, seq, attempt) < self.reorder_p

    def delays(self, wid: int, seq: int, attempt: int) -> bool:
        return _unit(self.seed, _S_DELAY, wid, seq, attempt) < self.delay_p

    def corrupts(self, wid: int, seq: int, attempt: int) -> bool:
        if self.corrupt_wids is not None and wid not in self.corrupt_wids:
            return False
        return _unit(self.seed, _S_CORRUPT, wid, seq, attempt) < self.corrupt_p

    def drops_ack(self, wid: int, seq: int, attempt: int) -> bool:
        return _unit(self.seed, _S_ACK, wid, seq, attempt) < self.ack_drop_p

    def retry_jitter(self, wid: int, seq: int, attempt: int) -> float:
        """Deterministic jitter fraction in [0, 0.25): desynchronizes
        retry storms without sacrificing replayability."""
        return 0.25 * _unit(self.seed, _S_JITTER, wid, seq, attempt)

    def in_partition(self, wid: int, t: float) -> bool:
        return any(p.covers(wid, t) for p in self.partitions)

    @property
    def liveness_enabled(self) -> bool:
        return self.heartbeat_interval > 0

    # ------------------------------------------------------------------ json
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FaultSpec":
        d = dict(d)
        if d.get("corrupt_wids") is not None:
            d["corrupt_wids"] = tuple(d["corrupt_wids"])
        parts = []
        for p in d.get("partitions", ()):
            p = dict(p)
            p["wids"] = tuple(p.get("wids", ()))
            parts.append(PartitionSpec(**p))
        d["partitions"] = tuple(parts)
        return cls(**d)


# ---------------------------------------------------------------------------
# The faulty channel
# ---------------------------------------------------------------------------

class FaultyTransport(Transport):
    """Deterministic fault injector around any inner ``Transport``.

    Only ``Envelope`` traffic is faulted (the frame identity is what the
    dice key off); any other message passes through untouched. Corruption
    is modeled by flipping the envelope's CRC on a *copy* — the sender's
    frame object is never mutated, so a retry resends the pristine
    payload. Reordering shelves a frame and releases it after the next
    frame passes (an adjacent swap); retries naturally flush a shelf that
    would otherwise starve the receiver. ``clock`` maps wall time to the
    scenario's virtual clock for partition windows (required iff the spec
    has partitions).
    """

    def __init__(self, inner: Transport, spec: FaultSpec, *,
                 stream: int = 0,
                 clock: Optional[Callable[[], float]] = None):
        if spec.partitions and clock is None:
            raise ValueError("partition windows need a virtual clock "
                             "(free-running runtime only)")
        self.inner = inner
        self.spec = spec
        self.stream = stream             # salt: data vs heartbeat channel
        self.clock = clock
        self._shelf: Optional[Envelope] = None
        self._lock = threading.Lock()
        self.counters: Dict[str, int] = {
            "injected_drops": 0, "injected_dups": 0, "injected_reorders": 0,
            "injected_delays": 0, "injected_corruptions": 0,
            "partition_drops": 0,
        }

    # ------------------------------------------------------------------ send
    def send(self, msg: Any, timeout: Optional[float] = None) -> None:
        if not isinstance(msg, Envelope):
            self.inner.send(msg, timeout=timeout)
            return
        key = (msg.wid, msg.seq + (self.stream << 40), msg.attempt)
        spec = self.spec
        if spec.partitions and spec.in_partition(msg.wid, self.clock()):
            self._count("partition_drops")
            return
        if spec.drops(*key):
            self._count("injected_drops")
            return
        if msg.kind == KIND_RESULT and spec.corrupts(*key):
            self._count("injected_corruptions")
            msg = dataclasses.replace(msg, crc=msg.crc ^ 0xDEADBEEF)
        if spec.delays(*key) and spec.delay_s > 0:
            self._count("injected_delays")
            import time as _t
            _t.sleep(spec.delay_s)
        copies = 1
        if spec.duplicates(*key):
            self._count("injected_dups")
            copies = 2
        for _ in range(copies):
            self._send_with_shelf(msg, key, timeout)

    def _send_with_shelf(self, msg: Envelope, key, timeout):
        """Adjacent-swap reordering: a shelved frame is released after
        the next frame passes through."""
        with self._lock:
            held, self._shelf = self._shelf, None
            if held is None and self.spec.reorders(*key):
                self._count_locked("injected_reorders")
                self._shelf = msg
                return
        self.inner.send(msg, timeout=timeout)
        if held is not None:
            self.inner.send(held, timeout=timeout)

    # ----------------------------------------------------------- delegation
    def recv(self, timeout: Optional[float] = None) -> Any:
        return self.inner.recv(timeout=timeout)

    def close(self) -> None:
        # flush the shelf so no frame is silently lost at teardown
        with self._lock:
            held, self._shelf = self._shelf, None
        if held is not None:
            try:
                self.inner.send(held, timeout=0.1)
            except Exception:                      # noqa: BLE001 (teardown)
                pass
        self.inner.close()

    def depth(self) -> int:
        return self.inner.depth()

    def _count(self, key: str):
        with self._lock:
            self.counters[key] += 1

    def _count_locked(self, key: str):
        self.counters[key] += 1


# ---------------------------------------------------------------------------
# Receiver-side at-least-once bookkeeping
# ---------------------------------------------------------------------------

#: delivery-health counter names, in reporting order
DELIVERY_COUNTERS = (
    "retries", "redelivered_deduped", "checksum_rejects", "acks_dropped",
    "quarantines", "heartbeat_misses", "liveness_deaths",
    "liveness_revivals",
)


@dataclass
class Verdict:
    """DeliveryTracker's decision for one received frame."""
    status: str                      # "accept" | "dup" | "reject"
    ack: bool                        # send a delivery receipt
    quarantine: bool = False         # this frame crossed the threshold


class DeliveryTracker:
    """Server-side half of at-least-once delivery.

    - verifies the payload CRC of every result frame and rejects
      mismatches (a rejected frame is never acked, so the sender
      retries — a fresh attempt re-rolls the corruption dice);
    - deduplicates redeliveries by ``(wid, generation, seq)``: per-worker
      streams are strictly monotonic (one frame in flight at a time), so
      a high-water mark per stream suffices;
    - quarantines a worker after ``quarantine_after`` CONSECUTIVE corrupt
      frames: its frames are acked-with-quarantine (so the sender stops
      retrying) and discarded — graceful degradation instead of poisoning
      the outer state.
    """

    def __init__(self, quarantine_after: int = 8):
        self.quarantine_after = quarantine_after
        self._high_water: Dict[int, Tuple[int, int]] = {}  # wid->(gen,seq)
        self._consec_bad: Dict[int, int] = {}
        self.quarantined: set = set()
        self.counters: Dict[str, int] = {k: 0 for k in DELIVERY_COUNTERS}

    def reset_stream(self, wid: int) -> None:
        """A (re)started worker thread begins a fresh seq stream."""
        self._high_water.pop(wid, None)
        self._consec_bad.pop(wid, None)

    def process(self, env: Envelope) -> Verdict:
        wid = env.wid
        if wid in self.quarantined:
            return Verdict("reject", ack=True, quarantine=True)
        if env.kind == KIND_RESULT:
            if payload_crc(env.payload) != env.crc:
                self.counters["checksum_rejects"] += 1
                bad = self._consec_bad.get(wid, 0) + 1
                self._consec_bad[wid] = bad
                if bad >= self.quarantine_after:
                    self.counters["quarantines"] += 1
                    self.quarantined.add(wid)
                    return Verdict("reject", ack=True, quarantine=True)
                return Verdict("reject", ack=False)
        self._consec_bad[wid] = 0
        hw = self._high_water.get(wid)
        if hw is not None and (env.generation, env.seq) <= hw:
            self.counters["redelivered_deduped"] += 1
            return Verdict("dup", ack=True)
        self._high_water[wid] = (env.generation, env.seq)
        return Verdict("accept", ack=True)
