"""Transport abstraction for worker -> server pseudo-gradient traffic.

The concurrent runtime never touches ``queue`` directly: workers push
framed ``Envelope`` messages through a ``Transport`` and the server
drains them. The only backend today is ``InProcTransport`` — a bounded
in-process MPSC channel whose blocking ``send`` gives natural
backpressure (a worker that outruns the server parks on the channel
instead of piling up pseudo-gradients in memory). The interface is
deliberately small and byte-agnostic so a socket/RPC backend (serialize
the packed (R, 128) buffer, ship int8 + per-block scales) can slot in
without touching the runtime: ``send`` / ``recv`` / ``close`` /
``depth``.

``close`` wakes every blocked producer and consumer with
``TransportClosed`` — that is how the runtime tears worker threads down
without draining in-flight rounds (they are lost, exactly like a real
disconnect; generation counters on the server make that safe).

Blocking is implemented with ``threading.Condition`` wakeups: a parked
``send``/``recv`` sleeps until notified (message consumed / produced /
channel closed), so there is no idle poll burn and timeout deadlines
are exact rather than quantized to a poll interval.

Delivery framing
----------------

A ``Transport`` makes no reliability promises beyond what its backend
gives it — and ``repro.async_engine.faults.FaultyTransport``
deliberately takes even those away (drop / duplicate / reorder / delay /
corrupt). The at-least-once protocol that survives such a channel is
expressed with the frame types defined here:

  ``Envelope``   one framed message: per-worker monotonic ``seq``,
                 worker ``generation``, CRC32 of the payload bytes, and
                 the retry ``attempt`` (not part of the frame identity);
  ``Ack``        the server's delivery receipt, routed back on a
                 per-worker side channel; a worker retries an
                 unacknowledged frame with exponential backoff.

The server deduplicates redeliveries by ``(wid, generation, seq)`` and
rejects frames whose recomputed CRC disagrees with the envelope — see
``repro.async_engine.faults.DeliveryTracker``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from abc import ABC, abstractmethod
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.obs.spans import NULL_TRACER


class TransportClosed(Exception):
    """The channel was torn down while a send/recv was in progress."""


class TransportTimeout(Exception):
    """No progress within the caller-supplied timeout."""


class Transport(ABC):
    """One-directional message channel: many producers, one consumer."""

    @abstractmethod
    def send(self, msg: Any, timeout: Optional[float] = None) -> None:
        """Enqueue ``msg``; BLOCKS while the channel is full (backpressure).
        Raises ``TransportClosed`` if the channel is (or becomes) closed,
        ``TransportTimeout`` after ``timeout`` seconds without space."""

    @abstractmethod
    def recv(self, timeout: Optional[float] = None) -> Any:
        """Dequeue the oldest message (FIFO). Raises ``TransportClosed``
        when closed and drained, ``TransportTimeout`` on timeout."""

    @abstractmethod
    def close(self) -> None:
        """Tear the channel down; wakes all blocked senders/receivers."""

    @abstractmethod
    def depth(self) -> int:
        """Messages currently queued (approximate under concurrency)."""


class InProcTransport(Transport):
    """Bounded in-process channel. ``capacity`` is the backpressure knob:
    once full, producers block in ``send`` until the server drains an
    arrival — no message is ever dropped. Condition-variable wakeups:
    blocked peers sleep (no polling) and honour timeout deadlines
    exactly; ``close`` notifies everyone."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._dq: deque = deque()
        lock = threading.Lock()
        self._not_full = threading.Condition(lock)
        self._not_empty = threading.Condition(lock)
        self._closed = False

    def send(self, msg: Any, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._not_full:
            while True:
                if self._closed:
                    raise TransportClosed("send on closed transport")
                if len(self._dq) < self.capacity:
                    self._dq.append(msg)
                    self._not_empty.notify()
                    return
                if deadline is None:
                    self._not_full.wait()
                else:
                    rest = deadline - time.monotonic()
                    if rest <= 0:
                        raise TransportTimeout(
                            f"send blocked > {timeout}s "
                            f"(capacity {self.capacity})")
                    self._not_full.wait(rest)

    def recv(self, timeout: Optional[float] = None) -> Any:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._not_empty:
            while True:
                if self._dq:
                    msg = self._dq.popleft()
                    self._not_full.notify()
                    return msg
                if self._closed:
                    raise TransportClosed("recv on closed, drained transport")
                if deadline is None:
                    self._not_empty.wait()
                else:
                    rest = deadline - time.monotonic()
                    if rest <= 0:
                        raise TransportTimeout(f"recv idle > {timeout}s")
                    self._not_empty.wait(rest)

    def close(self) -> None:
        with self._not_full:                 # shared lock with _not_empty
            self._closed = True
            self._not_full.notify_all()
            self._not_empty.notify_all()

    def depth(self) -> int:
        return len(self._dq)


# ---------------------------------------------------------------------------
# Delivery framing: envelopes, acks, payload checksums
# ---------------------------------------------------------------------------

# Envelope kinds. "result" carries a RoundResult (CRC-protected);
# "error" carries a RoundError (re-raised server-side); "heartbeat" is
# the liveness side-channel beacon (no payload, no ack).
KIND_RESULT = "result"
KIND_ERROR = "error"
KIND_HEARTBEAT = "heartbeat"


@dataclass(frozen=True)
class Envelope:
    """One framed transport message. Identity is ``(wid, generation,
    seq)`` — ``seq`` is the sender's monotonic per-stream counter, so the
    server can deduplicate at-least-once redeliveries. ``attempt`` counts
    retries of the same frame and is NOT part of the identity (fault
    injection keys off it so a retried frame draws fresh fault dice)."""
    wid: int
    generation: int
    seq: int
    kind: str
    payload: Any
    crc: int = 0
    attempt: int = 0
    sent_time: float = 0.0           # sender clock (diagnostics only)

    @property
    def key(self):
        return (self.wid, self.generation, self.seq)


@dataclass(frozen=True)
class Ack:
    """Server -> worker delivery receipt (per-worker side channel).
    ``quarantined`` tells the sender to stop retrying: the server has
    stopped accepting its frames (graceful degradation)."""
    wid: int
    generation: int
    seq: int
    quarantined: bool = False


def payload_crc(payload: Any) -> int:
    """CRC32 over the serialized pseudo-gradient payload: every leaf of
    ``payload.delta`` (packed fp32 or decoded int8 round-trip) in pytree
    order, host bytes. This is what a socket backend would checksum on
    the wire; corrupt frames fail verification server-side and are never
    folded into outer state."""
    delta = getattr(payload, "delta", payload)
    crc = 0
    for leaf in jax.tree.leaves(delta):
        crc = zlib.crc32(np.asarray(leaf).tobytes(), crc)
    return crc


@dataclass
class AckWaiter:
    """The worker half of the retry loop: a plain Condition-guarded
    mailbox the server drops ``Ack``s into. Deliberately not a
    ``Transport`` — acks are tiny, per-worker, and never backpressure."""
    _acks: deque = field(default_factory=deque)
    _cond: threading.Condition = field(default_factory=threading.Condition)
    _closed: bool = False

    def put(self, ack: Optional[Ack]) -> None:
        with self._cond:
            if ack is None:
                self._closed = True
            else:
                self._acks.append(ack)
            self._cond.notify_all()

    def wait_for(self, env: Envelope, timeout: float) -> Optional[Ack]:
        """Block until an ack matching ``env``'s identity arrives, the
        mailbox closes (returns None), or ``timeout`` elapses (returns
        None — caller retries). Stale acks for earlier frames are
        discarded."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while True:
                while self._acks:
                    ack = self._acks.popleft()
                    if (ack.wid == env.wid
                            and ack.generation == env.generation
                            and ack.seq == env.seq):
                        return ack
                if self._closed:
                    return None
                rest = deadline - time.monotonic()
                if rest <= 0:
                    return None
                self._cond.wait(rest)

    def close(self) -> None:
        self.put(None)

    @property
    def closed(self) -> bool:
        return self._closed


class ReliableSender:
    """The sender half of at-least-once delivery, shared VERBATIM between
    the threaded runtime (one per ``ConcurrentRuntime``) and the socket
    worker processes (one per child): send the frame, wait for the
    server's delivery receipt, resend with exponential backoff +
    deterministic jitter until it lands. A quarantine ack stops the
    retries like any other ack — the server will simply never accept this
    worker again.

    ``spec`` is an optional ``repro.async_engine.faults.FaultSpec``
    supplying the protocol knobs (``ack_timeout`` / ``backoff_base`` /
    ``max_backoff`` / ``retry_jitter``); without one the fault-free
    defaults apply. ``on_retry`` is called once per resend (the runtime
    bumps its ``retries`` delivery counter there).
    """

    #: ack wait on a fault-free channel before a (harmless) resend
    DEFAULT_ACK_TIMEOUT = 5.0

    def __init__(self, transport: "Transport", *, spec=None,
                 tracer=None, default_timeout: Optional[float] = None,
                 on_retry: Optional[Callable[["Envelope", int], None]] = None):
        self.transport = transport
        self.spec = spec
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.default_timeout = default_timeout or self.DEFAULT_ACK_TIMEOUT
        self.on_retry = on_retry

    def send(self, env: "Envelope", waiter: "AckWaiter") -> bool:
        """Deliver ``env`` at least once. Returns False when the channel
        (or the ack mailbox) is torn down before the receipt lands."""
        spec = self.spec
        base = spec.ack_timeout if spec else self.default_timeout
        boff = spec.backoff_base if spec else 2.0
        cap = spec.max_backoff if spec else self.default_timeout
        attempt = 0
        while True:
            try:
                with self.tracer.span("transport.send", cat="transport",
                                      wid=env.wid, seq=env.seq,
                                      attempt=attempt):
                    self.transport.send(dataclasses.replace(env,
                                                            attempt=attempt))
            except TransportClosed:
                return False
            timeout = min(base * (boff ** attempt), cap)
            if spec is not None:
                timeout *= 1.0 + spec.retry_jitter(env.wid, env.seq, attempt)
            with self.tracer.span("transport.ack_wait", cat="transport",
                                  wid=env.wid, seq=env.seq,
                                  attempt=attempt):
                ack = waiter.wait_for(env, timeout)
            if ack is not None:
                return True                  # delivered (or quarantined)
            if waiter.closed:
                return False
            attempt += 1
            self.tracer.instant("transport.retry", cat="transport",
                                wid=env.wid, seq=env.seq, attempt=attempt)
            if self.on_retry is not None:
                self.on_retry(env, attempt)
