"""Transport abstraction for worker -> server pseudo-gradient traffic.

The concurrent runtime never touches ``queue`` directly: workers push
``RoundResult`` messages through a ``Transport`` and the server drains
them. The only backend today is ``InProcTransport`` — a bounded
in-process MPSC queue whose blocking ``send`` gives natural backpressure
(a worker that outruns the server parks on the channel instead of piling
up pseudo-gradients in memory). The interface is deliberately small and
byte-agnostic so a socket/RPC backend (serialize the packed (R, 128)
buffer, ship int8 + per-block scales) can slot in without touching the
runtime: ``send`` / ``recv`` / ``close`` / ``depth``.

``close`` wakes every blocked producer and consumer with
``TransportClosed`` — that is how the runtime tears worker threads down
without draining in-flight rounds (they are lost, exactly like a real
disconnect; generation counters on the server make that safe).
"""
from __future__ import annotations

import queue
import threading
import time
from abc import ABC, abstractmethod
from typing import Any, Optional

_POLL_S = 0.02       # how often blocked send/recv re-checks for close()


class TransportClosed(Exception):
    """The channel was torn down while a send/recv was in progress."""


class TransportTimeout(Exception):
    """No progress within the caller-supplied timeout."""


class Transport(ABC):
    """One-directional message channel: many producers, one consumer."""

    @abstractmethod
    def send(self, msg: Any, timeout: Optional[float] = None) -> None:
        """Enqueue ``msg``; BLOCKS while the channel is full (backpressure).
        Raises ``TransportClosed`` if the channel is (or becomes) closed,
        ``TransportTimeout`` after ``timeout`` seconds without space."""

    @abstractmethod
    def recv(self, timeout: Optional[float] = None) -> Any:
        """Dequeue the oldest message (FIFO). Raises ``TransportClosed``
        when closed and drained, ``TransportTimeout`` on timeout."""

    @abstractmethod
    def close(self) -> None:
        """Tear the channel down; wakes all blocked senders/receivers."""

    @abstractmethod
    def depth(self) -> int:
        """Messages currently queued (approximate under concurrency)."""


class InProcTransport(Transport):
    """Bounded in-process queue. ``capacity`` is the backpressure knob:
    once full, producers block in ``send`` until the server drains an
    arrival — no message is ever dropped."""

    def __init__(self, capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=capacity)
        self._closed = threading.Event()

    def send(self, msg: Any, timeout: Optional[float] = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self._closed.is_set():
                raise TransportClosed("send on closed transport")
            try:
                self._q.put(msg, timeout=_POLL_S)
                return
            except queue.Full:
                if deadline is not None and time.monotonic() > deadline:
                    raise TransportTimeout(
                        f"send blocked > {timeout}s (capacity {self.capacity})")

    def recv(self, timeout: Optional[float] = None) -> Any:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            try:
                return self._q.get(timeout=_POLL_S)
            except queue.Empty:
                if self._closed.is_set():
                    raise TransportClosed("recv on closed, drained transport")
                if deadline is not None and time.monotonic() > deadline:
                    raise TransportTimeout(f"recv idle > {timeout}s")

    def close(self) -> None:
        self._closed.set()

    def depth(self) -> int:
        return self._q.qsize()
