"""Wall-clock concurrent runtime: real asynchronous workers behind the
shared ``Engine`` API.

Each worker runs in its own thread (optionally pinned to its own
``jax.devices()`` entry when more than one is visible), executes the same
functional inner round as the simulator (``execute_round``), and
pushes its compressed pseudo-gradient through a ``Transport``. Two
backends: the bounded in-process queue (default) and
``transport="socket"`` — real worker *processes* behind the socket
rendezvous in ``repro.async_engine.proc``, same protocol, same commit
orders (docs/runtime.md, "Process transport").
The server loop drains arrivals and applies the packed fused update from
``Synchronizer.on_arrival`` while the other workers keep computing — the
compute/update overlap the paper's wall-clock claims rest on.

Two commit orders:

  mode="deterministic" (default)
      The virtual-clock event loop from ``EngineBase`` runs unchanged on
      the server thread; compute is merely *eager* (dispatched to the
      worker thread at capture time) instead of lazy. Arrivals are
      committed in virtual-deadline order no matter which thread finishes
      first, so with a fixed seed this runtime reproduces the simulator's
      arrival sequence ``(wid, s_i, staleness, lang)`` exactly and its
      final parameters to fp32 tolerance — the determinism contract
      (docs/runtime.md) and the acceptance anchor for every wall-clock
      experiment.

  mode="free"
      True arrival order: first pseudo-gradient through the transport is
      applied first. ``pace_scale`` maps the configured virtual paces
      onto wall-clock sleeps (a worker with pace p takes at least
      ``h * p * pace_scale`` wall seconds per round), reproducing the
      paper's (1, 2, 6, 15)-style device heterogeneity on homogeneous
      hardware. Failure / elastic event times are interpreted on the same
      scaled clock.

Unreliable delivery (docs/faults.md)
------------------------------------

The channel is never trusted. Every worker->server message is a framed
``Envelope`` (monotonic per-worker seq, generation, CRC32 of the packed
payload); the worker retries unacknowledged frames with exponential
backoff + deterministic jitter, and the server side is idempotent —
``DeliveryTracker`` dedups redeliveries by ``(wid, generation, seq)``,
rejects checksum-failed frames (never acked, so the sender retries), and
quarantines a worker after K consecutive corrupt frames. Pass
``faults=FaultSpec(...)`` to wrap the channel in a deterministic fault
injector (drop / duplicate / reorder / delay / corrupt / partition); the
committed history of a deterministic-mode run is unchanged by any
eventually-delivering fault pattern — only latency and the delivery
counters move. In free mode, workers additionally beat on a heartbeat
side channel and a liveness monitor routes silent workers through the
existing crash/rejoin generation machinery.

Fault tolerance rides the generation counters the simulator already
uses: a crash bumps the worker's generation, so the in-flight round that
eventually lands through the transport is discarded at the server —
exactly a lost round in a real deployment. The thread itself is only
torn down on elastic leave / shutdown (poison pill + transport close).
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.async_engine.engine import (
    ElasticEvent, EngineBase, FailureEvent, History, RoundResult, RoundTask,
    Worker,
)
from repro.async_engine.faults import (
    DELIVERY_COUNTERS, DeliveryTracker, FaultSpec, FaultyTransport,
)
from repro.async_engine.proc import WorkerExit, WorkerProcessPool
from repro.async_engine.transport import (
    Ack, AckWaiter, Envelope, InProcTransport, KIND_ERROR, KIND_HEARTBEAT,
    KIND_RESULT, ReliableSender, Transport, TransportClosed,
    TransportTimeout, payload_crc,
)
from repro.configs.base import RunConfig

#: transport backends selectable by name (``transport="socket"``)
TRANSPORTS = ("inproc", "socket")

PyTree = Any


@dataclass
class RoundError:
    """A worker thread raised; carried to the server and re-raised there."""
    wid: int
    generation: int
    round_seq: int
    error: str


class ConcurrentRuntime(EngineBase):
    ENGINE_NAME = "wallclock"

    #: ack wait on a fault-free channel before a (harmless) resend
    _RELIABLE_ACK_TIMEOUT = 5.0

    def __init__(self, run_cfg: RunConfig, *,
                 failures: Optional[List[FailureEvent]] = None,
                 elastic: Optional[List[ElasticEvent]] = None,
                 transport: Optional[Any] = None,
                 mode: str = "deterministic",
                 pace_scale: float = 0.0,
                 pin_devices: bool = True,
                 queue_capacity: Optional[int] = None,
                 result_timeout: float = 600.0,
                 faults: Optional[FaultSpec] = None,
                 telemetry=None, tracer=None,
                 runtime_record_every: int = 0):
        if mode not in ("deterministic", "free"):
            raise ValueError(f"mode must be 'deterministic' or 'free': {mode}")
        if faults is not None and faults.partitions and mode != "free":
            raise ValueError(
                "partition windows are defined on the free-running virtual "
                "clock; deterministic mode has no wall-to-virtual coupling "
                "to evaluate them against (use mode='free')")
        super().__init__(run_cfg, failures=failures, elastic=elastic,
                         telemetry=telemetry, tracer=tracer,
                         runtime_record_every=runtime_record_every)
        self.mode = mode
        self._run_t0: Optional[float] = None
        self.pace_scale = pace_scale
        self.result_timeout = result_timeout
        self.faults = faults
        self._capacity = queue_capacity or max(2 * len(self.workers), 4)
        self.transport_kind = "inproc"
        if isinstance(transport, str):
            if transport not in TRANSPORTS:
                raise ValueError(f"transport must be one of {TRANSPORTS} "
                                 f"or a Transport instance: {transport!r}")
            self.transport_kind = transport
            transport = None
        self._pool: Optional[WorkerProcessPool] = None
        self._last_task: Dict[int, Tuple[int, RoundTask]] = {}
        self._proc_counters: Dict[str, int] = {"proc_exits": 0,
                                               "proc_restarts": 0}
        self._channel_counters: Dict[str, Dict[str, int]] = {}
        self._own_transport = transport is None
        self._free_t0: Optional[float] = None
        # cross-process observability: child obs frames arrive on pool
        # reader threads, so merging into the tracer/telemetry is
        # lock-guarded; _child_wire keeps the latest CUMULATIVE counter
        # snapshot per (wid, pid) incarnation
        self._obs_lock = threading.Lock()
        self._child_wire: Dict[Tuple[int, int], Dict[str, Any]] = {}
        if self.transport_kind == "socket":
            # heartbeat sink first: the pool routes child beacons into it
            self._hb_channel: Transport = self._heartbeat_channel()
            self.transport = self._data_channel()
        else:
            if transport is not None and faults is not None:
                transport = self._wrap(transport, stream=0)
            self.transport = transport or self._data_channel()
            self._hb_channel = self._heartbeat_channel()
        self._sender = self._make_sender()
        self._hb_enabled = (faults is not None and faults.liveness_enabled
                            and mode == "free")
        self._delivery = DeliveryTracker(
            quarantine_after=(faults.quarantine_after if faults else 8))
        self._dlock = threading.Lock()
        self._fault_accum: Dict[str, int] = {}
        self._inboxes: Dict[int, "_queue.Queue"] = {}
        self._ack_waiters: Dict[int, AckWaiter] = {}
        self._threads: Dict[int, threading.Thread] = {}
        self._hb_threads: Dict[int, threading.Thread] = {}
        self._hb_stops: Dict[int, threading.Event] = {}
        self._last_beat: Dict[int, float] = {}
        self._miss_counted: Dict[int, int] = {}
        self._liveness_dead: set = set()
        self._quarantine_acted: set = set()
        self._results: Dict[int, RoundResult] = {}      # task_id -> result
        self._computing = 0
        self._comp_lock = threading.Lock()
        self._shut = False
        self.stats: Dict[str, Any] = {
            "mode": mode, "arrivals": 0, "server_busy_seconds": 0.0,
            "wall_seconds": 0.0, "queue_depth_samples": [],
            "overlap_samples": [], "compute_seconds_total": 0.0,
        }
        devices = jax.devices()
        if pin_devices and len(devices) > 1 and self.transport_kind != "socket":
            for w in self.workers.values():
                w.device = devices[w.wid % len(devices)]

    # ------------------------------------------------------------- channels
    def _virtual_now(self) -> float:
        """Free-running virtual clock (partition windows live on it)."""
        if self._free_t0 is None:
            return 0.0
        scale = self.pace_scale if self.pace_scale > 0 else 1.0
        return (time.monotonic() - self._free_t0) / scale

    def _wrap(self, inner: Transport, stream: int) -> Transport:
        return FaultyTransport(inner, self.faults, stream=stream,
                               clock=self._virtual_now)

    def _data_channel(self) -> Transport:
        if self.transport_kind == "socket":
            # the pool's SocketTransport is deliberately UNWRAPPED here:
            # the worker processes inject faults on their side of the
            # wire (same streams, same dice), so wrapping again would
            # double-inject
            self._pool = WorkerProcessPool(
                self.cfg, capacity=self._capacity, faults=self.faults,
                mode=self.mode, pace_scale=self.pace_scale,
                hb_sink=self._hb_channel,
                obs=(self.tracer.enabled or self.telemetry is not None))
            self._pool.on_obs = self._on_obs
            return self._pool.transport
        inner = InProcTransport(self._capacity)
        return self._wrap(inner, stream=0) if self.faults else inner

    def _heartbeat_channel(self) -> Transport:
        # side channel: beacons never queue behind pseudo-gradient
        # backpressure, and partitions silence them like any other frame
        inner = InProcTransport(max(64 * max(len(self.workers), 1), 256))
        if self.transport_kind == "socket":
            return inner                 # children wrap their own hb stream
        return self._wrap(inner, stream=1) if self.faults else inner

    def _make_sender(self) -> ReliableSender:
        return ReliableSender(
            self.transport, spec=self.faults, tracer=self.tracer,
            default_timeout=self._RELIABLE_ACK_TIMEOUT,
            on_retry=lambda env, attempt: self._bump("retries"))

    # ------------------------------------------------------- worker threads
    def _start_worker_thread(self, wid: int):
        inbox: "_queue.Queue[Optional[RoundTask]]" = _queue.Queue()
        waiter = AckWaiter()
        self._inboxes[wid] = inbox
        self._ack_waiters[wid] = waiter
        # a (re)started thread begins a fresh delivery stream
        self._delivery.reset_stream(wid)
        self._last_beat[wid] = time.monotonic()
        self._miss_counted[wid] = 0
        t = threading.Thread(target=self._worker_loop,
                             args=(wid, inbox, waiter),
                             name=f"heloco-worker-{wid}", daemon=True)
        self._threads[wid] = t
        t.start()
        if self._hb_enabled:
            stop = threading.Event()
            self._hb_stops[wid] = stop
            ht = threading.Thread(target=self._heartbeat_loop,
                                  args=(wid, stop),
                                  name=f"heloco-hb-{wid}", daemon=True)
            self._hb_threads[wid] = ht
            ht.start()

    def _worker_loop(self, wid: int, inbox, waiter: AckWaiter):
        seq = 0                          # per-stream monotonic frame counter
        while True:
            task = inbox.get()
            if task is None:
                return
            t0 = time.monotonic()
            with self._comp_lock:
                self._computing += 1
            try:
                if task.device is not None:
                    with jax.default_device(task.device):
                        out: Any = self._execute(task)
                else:
                    out = self._execute(task)
            except Exception as e:                      # noqa: BLE001
                out = RoundError(task.wid, task.generation,
                                 task.round_seq, repr(e))
            finally:
                # the throttle sleep below is emulated device time, not
                # real compute: keep it out of the overlap evidence
                with self._comp_lock:
                    self._computing -= 1
            # pace throttle: a device at `pace` sec/step takes at least
            # h * pace * pace_scale wall seconds per round
            if task.sleep_per_step > 0 and not isinstance(out, RoundError):
                rest = (task.h_steps * task.sleep_per_step
                        - (time.monotonic() - t0))
                if rest > 0:
                    time.sleep(rest)
            seq += 1
            if isinstance(out, RoundError):
                env = Envelope(wid=wid, generation=task.generation, seq=seq,
                               kind=KIND_ERROR, payload=out)
            else:
                env = Envelope(wid=wid, generation=task.generation, seq=seq,
                               kind=KIND_RESULT, payload=out,
                               crc=payload_crc(out))
            if not self._send_reliably(env, waiter):
                return                              # channel torn down

    def _send_reliably(self, env: Envelope, waiter: AckWaiter) -> bool:
        """At-least-once send via the shared ``ReliableSender`` (the same
        class the socket worker processes run). Returns False when the
        channel is gone."""
        return self._sender.send(env, waiter)

    def _heartbeat_loop(self, wid: int, stop: threading.Event):
        """Liveness side channel: one beacon per interval until the
        worker is torn down. Beacons ride the same fault injector as data
        frames, so a partition silences them — which is exactly how the
        server detects it."""
        interval = self.faults.heartbeat_interval
        seq = 0
        while not stop.wait(interval):
            seq += 1
            w = self.workers.get(wid)
            gen = w.generation if w is not None else 0
            try:
                self._hb_channel.send(
                    Envelope(wid=wid, generation=gen, seq=seq,
                             kind=KIND_HEARTBEAT, payload=None,
                             sent_time=time.monotonic()),
                    timeout=0.01)
            except TransportTimeout:
                continue                         # channel full: drop beacon
            except TransportClosed:
                return

    # --------------------------------------------------------- engine hooks
    def _use_virtual_clock(self) -> bool:
        return self.mode == "deterministic"

    def _sleep_per_step(self, w: Worker) -> float:
        return w.pace * self.pace_scale if self.mode == "free" else 0.0

    def _submit(self, task: RoundTask):
        self._ensure_open()
        if self._pool is not None:
            inc = self._pool.ensure(task.wid)
            if inc is not None:          # fresh process: fresh stream
                self._delivery.reset_stream(task.wid)
                self._last_beat[task.wid] = time.monotonic()
                self._miss_counted[task.wid] = 0
            self._pool.clock = (self._free_t0, self.pace_scale)
            self._last_task[task.wid] = (self._pool.incarnation(task.wid),
                                         task)
            self._pool.submit(task.wid, task)
            return
        th = self._threads.get(task.wid)
        if th is None or not th.is_alive():
            self._start_worker_thread(task.wid)
        self._inboxes[task.wid].put(task)

    def _recv_result(self, timeout: Optional[float] = None) -> RoundResult:
        """One *accepted* transport message, with stats + error
        unwrapping. Duplicate, corrupt, and quarantined frames are
        consumed (and acked/rejected per the delivery protocol) without
        being returned. With an explicit ``timeout`` the
        ``TransportTimeout`` propagates (polling callers keep their event
        clock ticking); without one it is a hard liveness failure."""
        budget = self.result_timeout if timeout is None else timeout
        deadline = time.monotonic() + budget
        while True:
            rest = deadline - time.monotonic()
            try:
                if rest <= 0:
                    raise TransportTimeout(f"recv idle > {budget}s")
                msg = self.transport.recv(timeout=rest)
            except TransportTimeout:
                if timeout is not None:
                    raise
                raise RuntimeError(
                    f"no arrival within {self.result_timeout}s — worker "
                    f"thread/process dead, wedged, or quarantined (threads "
                    f"alive: "
                    f"{[w for w, t in self._threads.items() if t.is_alive()]},"
                    f" procs alive: "
                    f"{[w for w in self.workers if self._pool is not None and self._pool.alive(w)]},"
                    f" quarantined: {sorted(self._delivery.quarantined)})")
            if isinstance(msg, WorkerExit):
                self._handle_worker_exit(msg)
                continue
            if isinstance(msg, Envelope):
                payload = self._process_envelope(msg)
                if payload is None:
                    continue                     # dup / reject / heartbeat
                msg = payload
            self.stats["queue_depth_samples"].append(self.transport.depth())
            if isinstance(msg, RoundError):
                raise RuntimeError(
                    f"worker {msg.wid} round {msg.round_seq} failed: "
                    f"{msg.error}")
            self.stats["compute_seconds_total"] += msg.compute_seconds
            return msg

    # ------------------------------------------------- process supervision
    def _handle_worker_exit(self, ev: WorkerExit):
        """A worker process died outside a graceful shutdown. If the
        round the engine is waiting on was submitted to exactly that
        incarnation, respawn the process and resubmit the SAME task
        snapshot — a deterministic recompute of the same round (same
        task_id), so deterministic replay sails straight through a
        mid-run process kill. Anything else (stale incarnation, worker
        already crashed/departed) needs no action: the generation
        machinery has it covered."""
        self._proc_counters["proc_exits"] += 1
        if self._pool is None or self._shut:
            return
        entry = self._last_task.get(ev.wid)
        w = self.workers.get(ev.wid)
        if (entry is not None and w is not None and w.alive
                and entry[0] == ev.incarnation
                and w.pending_task_id is not None
                and entry[1].task_id == w.pending_task_id):
            self._proc_counters["proc_restarts"] += 1
            self._telemetry_fault("proc_restart", wid=ev.wid)
            self._submit(entry[1])

    # --------------------------------------------------- delivery protocol
    def _process_envelope(self, env: Envelope) -> Optional[Any]:
        """Idempotent-commit gate: CRC verification, (wid, generation,
        seq) dedup, quarantine policy, ack routing. Returns the payload
        only for first-time, checksum-clean deliveries."""
        if env.kind == KIND_HEARTBEAT:
            self._note_heartbeat(env)            # stray beacon: harmless
            return None
        verdict = self._delivery.process(env)
        if verdict.ack:
            self._send_ack(env, quarantined=env.wid
                           in self._delivery.quarantined)
        if verdict.quarantine:
            self._on_quarantine(env)
        elif verdict.status == "reject":
            self._telemetry_fault("checksum_reject", env)
        elif verdict.status == "dup":
            self._telemetry_fault("dedup", env)
        if verdict.status != "accept":
            return None
        return env.payload

    def _send_ack(self, env: Envelope, quarantined: bool = False):
        spec = self.faults
        if (spec is not None and not quarantined
                and spec.drops_ack(env.wid, env.seq, env.attempt)):
            self._bump("acks_dropped")           # lost receipt -> redelivery
            return
        if self._pool is not None:
            self._pool.send_ack(env.wid,
                                Ack(wid=env.wid, generation=env.generation,
                                    seq=env.seq, quarantined=quarantined))
            return
        waiter = self._ack_waiters.get(env.wid)
        if waiter is not None:
            waiter.put(Ack(wid=env.wid, generation=env.generation,
                           seq=env.seq, quarantined=quarantined))

    def _on_quarantine(self, env: Envelope):
        """K consecutive corrupt frames: stop accepting this worker.
        Free mode degrades gracefully (the worker leaves the rotation via
        the crash machinery, no restart); deterministic mode records the
        quarantine and the event loop surfaces a hard liveness error if
        it ends up starved of that worker's round."""
        if env.wid in self._quarantine_acted:
            return                      # already handled the transition
        self._quarantine_acted.add(env.wid)
        self._telemetry_fault("quarantine", env)
        w = self.workers.get(env.wid)
        if w is not None and w.alive and self.mode == "free":
            self._crash_worker(w)

    def _bump(self, key: str, n: int = 1):
        with self._dlock:
            self._delivery.counters[key] += n

    def _telemetry_fault(self, event: str, env: Optional[Envelope] = None,
                         wid: Optional[int] = None, detail=None):
        if self.telemetry is None:
            return
        self.telemetry.record_fault(
            event=event,
            wid=env.wid if env is not None else (-1 if wid is None else wid),
            seq=env.seq if env is not None else -1,
            generation=env.generation if env is not None else -1,
            detail=detail)

    # ------------------------------------------------------------- liveness
    def _note_heartbeat(self, env: Envelope):
        wid = env.wid
        # measure silence between *send* instants, not drain instants:
        # beacons queue on the side channel and the server may drain late
        beat_t = env.sent_time or time.monotonic()
        w = self.workers.get(wid)
        if (self._hb_enabled and w is not None and w.alive
                and wid not in self._liveness_dead):
            last = self._last_beat.get(wid)
            interval = self.faults.heartbeat_interval
            if last is not None and beat_t > last:
                missed = int((beat_t - last) / interval)
                if missed >= self.faults.liveness_misses:
                    # the worker WAS silent past the death threshold and
                    # only now resurfaced — the sweep may not have caught
                    # it in the act, but the semantics are the same:
                    # declare the death retroactively (generation bump
                    # drops whatever it computed while partitioned), then
                    # let this very beacon revive it below
                    counted = self._miss_counted.get(wid, 0)
                    if missed > counted:
                        self._bump("heartbeat_misses", missed - counted)
                    self._liveness_dead.add(wid)
                    self._bump("liveness_deaths")
                    self._telemetry_fault("liveness_dead", wid=wid)
                    self._crash_worker(w)
        self._last_beat[wid] = max(beat_t, self._last_beat.get(wid, 0.0))
        self._miss_counted[wid] = 0
        if (wid in self._liveness_dead and w is not None and not w.alive
                and wid not in self._delivery.quarantined):
            # the silent worker is back: rejoin through the generation
            # machinery (its lost round can never commit)
            self._liveness_dead.discard(wid)
            w.alive = True
            self._bump("liveness_revivals")
            self._telemetry_fault("liveness_revive", wid=wid)
            self._dispatch(w)

    def _drain_heartbeats(self):
        if not self._hb_enabled:
            return
        while True:
            try:
                env = self._hb_channel.recv(timeout=0.0)
            except (TransportTimeout, TransportClosed):
                return
            if isinstance(env, Envelope) and env.kind == KIND_HEARTBEAT:
                self._note_heartbeat(env)

    def _check_liveness(self):
        """Declare workers whose beacons stopped dead after
        ``liveness_misses`` whole intervals — the crash/rejoin machinery
        handles the rest (generation bump drops the in-flight round; a
        returning beacon revives the worker)."""
        if not self._hb_enabled:
            return
        interval = self.faults.heartbeat_interval
        now = time.monotonic()
        for wid, w in list(self.workers.items()):
            if not w.alive or wid in self._liveness_dead:
                continue
            last = self._last_beat.get(wid)
            if last is None:
                continue
            missed = int((now - last) / interval)
            counted = self._miss_counted.get(wid, 0)
            if missed > counted:
                self._bump("heartbeat_misses", missed - counted)
                self._miss_counted[wid] = missed
            if missed >= self.faults.liveness_misses:
                self._liveness_dead.add(wid)
                self._bump("liveness_deaths")
                self._telemetry_fault("liveness_dead", wid=wid)
                self._crash_worker(w)

    # ----------------------------------------------------------- commit path
    def _is_current(self, res: RoundResult) -> bool:
        """A result counts only if it is the round its worker is waiting
        on. Task ids are engine-unique, so a departed incarnation of a
        reused wid (or a crashed generation) can never be mistaken for
        the live worker's round."""
        w = self.workers.get(res.wid)
        return w is not None and res.task_id == w.pending_task_id

    def _obtain(self, w: Worker) -> RoundResult:
        """Block until THIS worker's outstanding round has landed; results
        from other workers are parked, stale rounds dropped (lost
        in-flight rounds of crashed / departed workers)."""
        want = w.pending_task_id
        while want not in self._results:
            res = self._recv_result()
            if self._is_current(res):
                self._results[res.task_id] = res
        return self._results.pop(want)

    def _commit(self, w: Worker, res: RoundResult):
        with self._comp_lock:
            overlap = self._computing
        t0 = time.monotonic()
        rec = super()._commit(w, res)
        # materialize the outer step so busy time is real, not dispatch time
        jax.block_until_ready(self.server._pbuf if self.server.packed
                              else jax.tree.leaves(self.server.state.params))
        self.stats["server_busy_seconds"] += time.monotonic() - t0
        self.stats["overlap_samples"].append(overlap)
        self.stats["arrivals"] += 1
        return rec

    def _commit_batch(self, pairs, reason: str = "batch-full"):
        with self._comp_lock:
            overlap = self._computing
        t0 = time.monotonic()
        recs = super()._commit_batch(pairs, reason=reason)
        jax.block_until_ready(self.server._pbuf if self.server.packed
                              else jax.tree.leaves(self.server.state.params))
        self.stats["server_busy_seconds"] += time.monotonic() - t0
        self.stats["overlap_samples"].append(overlap)
        self.stats["arrivals"] += len(pairs)
        return recs

    def _crash_worker(self, w: Worker):
        if w.pending_task_id is not None:               # drop a parked result
            self._results.pop(w.pending_task_id, None)
        super()._crash_worker(w)

    def _on_worker_removed(self, w: Worker):
        if self._pool is not None:
            self._pool.kill(w.wid)
        self._last_task.pop(w.wid, None)
        inbox = self._inboxes.pop(w.wid, None)
        if inbox is not None:
            inbox.put(None)                             # poison pill
        waiter = self._ack_waiters.pop(w.wid, None)
        if waiter is not None:
            waiter.close()                              # unblock a retry loop
        stop = self._hb_stops.pop(w.wid, None)
        if stop is not None:
            stop.set()
        self._hb_threads.pop(w.wid, None)
        self._threads.pop(w.wid, None)
        if w.pending_task_id is not None:
            self._results.pop(w.pending_task_id, None)

    # ------------------------------------------------------------ lifecycle
    def _ensure_open(self):
        if self._shut:
            if not self._own_transport:
                raise RuntimeError("transport closed; inject a fresh one")
            self._fold_fault_counters()
            if self.transport_kind == "socket":
                self._hb_channel = self._heartbeat_channel()
                self.transport = self._data_channel()   # fresh pool
            else:
                self.transport = self._data_channel()
                self._hb_channel = self._heartbeat_channel()
            self._sender = self._make_sender()
            self._shut = False

    def _fold_fault_counters(self):
        """Carry injected-fault counts across channel rebuilds."""
        for name, tr in (("data", self.transport),
                         ("heartbeat", self._hb_channel)):
            if isinstance(tr, FaultyTransport):
                acc = self._channel_counters.setdefault(name, {})
                for k, v in tr.counters.items():
                    self._fault_accum[k] = self._fault_accum.get(k, 0) + v
                    acc[k] = acc.get(k, 0) + v

    def _harvest_child_counters(self):
        """Fold the per-channel counters the worker processes reported at
        graceful shutdown into the run totals: injected faults join
        ``_fault_accum`` (so ``delivery_stats`` matches the in-process
        backend), protocol retries join the delivery counters."""
        if self._pool is None:
            return
        for channel, counters in self._pool.child_counters.items():
            acc = self._channel_counters.setdefault(channel, {})
            for k, v in counters.items():
                acc[k] = acc.get(k, 0) + v
                if channel == "protocol":
                    if k in DELIVERY_COUNTERS:
                        self._bump(k, v)
                else:
                    self._fault_accum[k] = self._fault_accum.get(k, 0) + v
        self._pool.child_counters.clear()

    # ------------------------------------------- cross-process observability
    def _on_obs(self, payload: Dict) -> None:
        """One child ("ctrl", "obs", ...) frame: merge the span batch into
        the parent tracer as a per-pid process row and emit a cumulative
        "transport" telemetry record. Runs on a pool reader thread —
        everything shared is taken under ``_obs_lock``. Observation only:
        never touches the engine/jax state."""
        try:
            wid = int(payload["wid"])
            pid = int(payload["pid"])
        except (KeyError, TypeError, ValueError):
            return                       # malformed frame: drop, never raise
        metrics = payload.get("metrics") or {}
        final = bool(payload.get("final"))
        offset = float(payload.get("offset", 0.0))
        with self._obs_lock:
            self._child_wire[(wid, pid)] = dict(metrics, final=final,
                                                clock_offset_s=offset)
            if self.tracer.enabled and payload.get("spans") is not None:
                spans = payload["spans"]
                self.tracer.ingest_remote(
                    pid=pid,
                    epoch_offset=float(payload.get("epoch_offset", 0.0)),
                    events=spans.get("events", []),
                    names=spans.get("names", {}),
                    process_name=f"heloco-worker-{wid} (pid {pid})")
            if self.telemetry is not None:
                self.telemetry.record_transport(
                    wid=wid, pid=pid,
                    frames_sent=int(metrics.get("frames_sent", 0)),
                    frames_recv=int(metrics.get("frames_recv", 0)),
                    bytes_sent=int(metrics.get("bytes_sent", 0)),
                    bytes_recv=int(metrics.get("bytes_recv", 0)),
                    ser_s=float(metrics.get("ser_s", 0.0)),
                    deser_s=float(metrics.get("deser_s", 0.0)),
                    crc_rejects=int(metrics.get("crc_rejects", 0)),
                    retries=int(metrics.get("retries", 0)),
                    credit_wait_s=float(metrics.get("credit_wait_s", 0.0)),
                    rounds=int(metrics.get("rounds", 0)),
                    compute_s=float(metrics.get("compute_s", 0.0)),
                    clock_offset_s=offset, final=final)

    def child_obs_report(self) -> Dict[str, Any]:
        """What the worker processes reported in: per-wid obs frame
        counts, which wids closed with a final report, and the summed
        latest-cumulative wire counters across all (wid, pid)
        incarnations. Empty when not on the socket transport."""
        if self._pool is None:
            return {"reports": {}, "final": [], "wire": {}}
        with self._obs_lock:
            wire: Dict[str, float] = {}
            for snap in self._child_wire.values():
                for k, v in snap.items():
                    if isinstance(v, (int, float)) and not isinstance(v, bool):
                        wire[k] = wire.get(k, 0) + v
        return {"reports": dict(self._pool.obs_reports),
                "final": sorted(self._pool.obs_final),
                "wire": wire}

    def assert_child_reports(self) -> None:
        """Loud check that every worker process the run dispatched to
        actually shipped observability frames back (satellite of the
        --trace/--stats-json/--telemetry launcher contract): a silent
        child means the collection path is broken, not that the run was
        quiet. Only meaningful on the socket transport with obs on."""
        if self._pool is None or not self._pool.obs:
            return
        dispatched = set(self._pool.obs_reports)
        silent = sorted(w for w in self._last_task
                        if w not in dispatched)
        if silent:
            raise RuntimeError(
                f"cross-process observability enabled but worker(s) "
                f"{silent} never reported in over the obs control "
                f"channel (reports: {dict(self._pool.obs_reports)}) — "
                f"child-side collection is broken or the processes died "
                f"before their first report")

    def shutdown(self):
        """Tear down worker threads/processes. Idempotent; ``run``/
        ``restore`` after shutdown transparently rebuild the channel +
        workers."""
        self._shut = True
        if self._pool is not None:
            self._pool.close()          # stop -> stats harvest -> join
            self._harvest_child_counters()
        else:
            self.transport.close()
        self._hb_channel.close()
        for stop in self._hb_stops.values():
            stop.set()
        for inbox in self._inboxes.values():
            inbox.put(None)
        for waiter in self._ack_waiters.values():
            waiter.close()
        for t in list(self._threads.values()) + list(self._hb_threads.values()):
            t.join(timeout=5.0)
        self._inboxes.clear()
        self._ack_waiters.clear()
        self._threads.clear()
        self._hb_threads.clear()
        self._hb_stops.clear()
        self._results.clear()

    # ------------------------------------------- runtime health snapshots
    def _runtime_snapshot(self) -> Dict:
        """Live counters for a telemetry "runtime" record: everything
        ``stats_summary()`` reports at exit, snapshotted mid-run, plus
        liveness states and the delivery/fault counters. Observation
        only — reads counters the run maintains anyway."""
        snap = super()._runtime_snapshot()
        wall = (time.monotonic() - self._run_t0
                if self._run_t0 is not None else 0.0)
        arrivals = self.stats["arrivals"]
        snap.update(
            arrivals=arrivals,
            arrivals_per_sec=arrivals / wall if wall > 0 else 0.0,
            server_occupancy=(self.stats["server_busy_seconds"] / wall
                              if wall > 0 else 0.0),
            compute_parallelism=(self.stats["compute_seconds_total"] / wall
                                 if wall > 0 else 0.0),
            queue_depth=self.transport.depth(),
            liveness={
                "dead": len(self._liveness_dead),
                "quarantined": len(self._delivery.quarantined),
                "threads_alive": sum(1 for t in self._threads.values()
                                     if t.is_alive()),
            },
            delivery={k: float(v)
                      for k, v in self.delivery_stats().items() if v})
        return snap

    # -------------------------------------------------------------- run
    def run(self, eval_every: int = 0,
            eval_fn: Optional[Callable[[PyTree, int, float], Dict]] = None,
            ckpt_every: int = 0, ckpt_dir: str = "",
            budget=None) -> History:
        t0 = time.monotonic()
        self._run_t0 = t0
        try:
            if self.mode == "free" and not self.server.method.sync:
                hist = self._run_free(eval_every, eval_fn, ckpt_every,
                                      ckpt_dir, budget)
            else:
                hist = super().run(eval_every, eval_fn, ckpt_every, ckpt_dir,
                                   budget)
        finally:
            self.stats["wall_seconds"] += time.monotonic() - t0
            self.shutdown()
        return hist

    def _finalize(self, eval_fn) -> History:
        hist = super()._finalize(eval_fn)
        if self.telemetry is not None:
            d = self.delivery_stats()
            if any(d.values()):
                self._telemetry_fault(
                    "summary", detail={k: float(v) for k, v in d.items()})
        return hist

    # ------------------------------------------------------- free-run loop
    def _run_free(self, eval_every, eval_fn, ckpt_every, ckpt_dir,
                  budget=None) -> History:
        """True arrival order on the wall clock. ``self.time`` is reported
        in virtual seconds (wall / pace_scale) so histories stay
        comparable with the simulator; with pace_scale == 0 it is raw wall
        seconds. Failure / elastic / restart times live on that clock.
        A ``Budget`` is accounted on the same clock (fixed_wallclock) or
        on committed tokens (fixed_tokens). Heartbeat liveness runs here:
        every loop iteration drains the side channel and sweeps for
        silent workers."""
        self._ensure_telemetry_meta()
        target = self.cfg.outer_steps
        self._free_t0 = t0 = time.monotonic()
        scale = self.pace_scale if self.pace_scale > 0 else 1.0
        fail_idx = el_idx = 0
        restarts: List[Tuple[float, int]] = []
        for w in self.workers.values():
            if w.alive and not w.in_flight:
                self._dispatch(w)

        def vnow() -> float:
            return (time.monotonic() - t0) / scale

        def process_events(vt: float):
            nonlocal fail_idx, el_idx
            while (fail_idx < len(self.failures)
                   and self.failures[fail_idx].time <= vt):
                ev = self.failures[fail_idx]
                fail_idx += 1
                w = self.workers.get(ev.wid)
                if w is None:
                    continue
                self._crash_worker(w)
                restarts.append((ev.time + ev.restart_delay, ev.wid))
                restarts.sort()
            while (el_idx < len(self.elastic)
                   and self.elastic[el_idx].time <= vt):
                self._handle_elastic(self.elastic[el_idx])
                el_idx += 1
            while restarts and restarts[0][0] <= vt:
                _, wid = restarts.pop(0)
                w = self.workers.get(wid)
                if w is not None and not w.alive:
                    w.alive = True
                    self._dispatch(w)

        def progress_possible() -> bool:
            """Someone will eventually produce an arrival: a live worker,
            a pending restart, an unfired failure/elastic event, or a
            liveness-dead worker whose beacon may yet return."""
            return (any(w.alive for w in self.workers.values())
                    or bool(restarts)
                    or bool(self._liveness_dead)
                    or fail_idx < len(self.failures)
                    or el_idx < len(self.elastic))

        while self.server.t < target and not self._stop:
            process_events(vnow())
            self._drain_heartbeats()
            self._check_liveness()
            if not progress_possible():
                break                   # every worker gone: starved run
            if budget is not None and budget.over_time(vnow()):
                break                   # clock horizon: stop committing
            try:
                msg = self._recv_result(timeout=0.05)
            except TransportTimeout:
                continue                # keep event clock ticking
            if not self._is_current(msg) or not self.workers[msg.wid].alive:
                continue                # stale: crashed / departed worker
            w = self.workers[msg.wid]
            self.time = vnow()
            if budget is not None and budget.over_time(self.time):
                break                   # arrived past the horizon: drop it
            # with commit_batch > 1, drain whatever else already landed
            # (non-blocking) and coalesce into one fused flush — same
            # labelled cap discipline as the deterministic loop, so a
            # batch never overshoots an eval/ckpt/close boundary. With
            # commit_batch == 1 the cap is 1 and this is exactly the old
            # single-commit path.
            limits = [(self.server.commit_batch, "batch-full"),
                      (target - self.server.t, "close")]
            if eval_every:
                limits.append(
                    (eval_every - self.server.t % eval_every, "eval"))
            if ckpt_every:
                limits.append(
                    (ckpt_every - self.server.t % ckpt_every, "ckpt"))
            cap, flush_reason = min(limits, key=lambda kv: kv[0])
            batch: List[Tuple[Worker, RoundResult]] = [(w, msg)]
            while len(batch) < cap:
                try:
                    extra = self._recv_result(timeout=0.001)
                except TransportTimeout:
                    break               # queue drained: commit what we have
                if (not self._is_current(extra)
                        or not self.workers[extra.wid].alive):
                    continue
                batch.append((self.workers[extra.wid], extra))
            if len(batch) == 1:
                self._commit(w, msg)
            else:
                self._commit_batch(batch, reason=flush_reason)
            self._post_commit(eval_every, eval_fn, ckpt_every, ckpt_dir)
            if budget is not None and budget.over_tokens(self.history.tokens):
                break
            if self.server.t < target:
                process_events(vnow())
                for bw, _ in batch:
                    if bw.alive:
                        self._dispatch(bw)
        self.time = vnow()
        return self._finalize(eval_fn)

    # -------------------------------------------------------- sync barrier
    def _execute_sync(self, tasks: List[RoundTask]) -> List[RoundResult]:
        """Sync DiLoCo round with genuinely parallel workers: all inner
        rounds run concurrently, the barrier is the transport collect."""
        for task in tasks:
            self._submit(task)
        want = {t.task_id: i for i, t in enumerate(tasks)}
        got: Dict[int, RoundResult] = {}
        while len(got) < len(tasks):
            res = self._recv_result()
            idx = want.get(res.task_id)
            if idx is not None:
                got[idx] = res
        return [got[i] for i in range(len(tasks))]

    # ----------------------------------------------------------- reporting
    def delivery_stats(self) -> Dict[str, int]:
        """Delivery-health counters: protocol events (retries, dedups,
        checksum rejects, quarantines, heartbeat misses, liveness
        transitions) plus the injected-fault tallies of the faulty
        channel(s)."""
        with self._dlock:
            out = {k: self._delivery.counters[k] for k in DELIVERY_COUNTERS}
        for k, v in self._fault_accum.items():
            out[k] = out.get(k, 0) + v
        for tr in (self.transport, self._hb_channel):
            if isinstance(tr, FaultyTransport):
                for k, v in tr.counters.items():
                    out[k] = out.get(k, 0) + v
        for k, v in self._proc_counters.items():
            if v:
                out[k] = out.get(k, 0) + v
        return out

    def delivery_channels(self) -> Dict[str, Dict[str, int]]:
        """Per-channel view of the injected-fault / protocol counters.
        In-process mode reads the live ``FaultyTransport`` wrappers;
        socket mode reports what the worker processes tallied on their
        side of the wire (harvested at graceful shutdown), keyed
        "data" / "heartbeat" / "protocol"."""
        out = {k: dict(v) for k, v in self._channel_counters.items()}
        for name, tr in (("data", self.transport),
                         ("heartbeat", self._hb_channel)):
            if isinstance(tr, FaultyTransport):
                acc = out.setdefault(name, {})
                for k, v in tr.counters.items():
                    acc[k] = acc.get(k, 0) + v
        return out

    def stats_summary(self) -> Dict[str, Any]:
        q = self.stats["queue_depth_samples"]
        ov = self.stats["overlap_samples"]
        wall = max(self.stats["wall_seconds"], 1e-9)
        return {
            "mode": self.mode,
            "arrivals": self.stats["arrivals"],
            "wall_seconds": self.stats["wall_seconds"],
            "arrivals_per_sec": self.stats["arrivals"] / wall,
            "server_busy_seconds": self.stats["server_busy_seconds"],
            "server_occupancy": self.stats["server_busy_seconds"] / wall,
            "compute_seconds_total": self.stats["compute_seconds_total"],
            # >1.0 means workers computed more seconds than wall passed:
            # genuine concurrency
            "compute_parallelism": self.stats["compute_seconds_total"] / wall,
            "queue_depth_mean": (sum(q) / len(q)) if q else 0.0,
            "queue_depth_max": max(q) if q else 0,
            # workers mid-round at the moment the server applied an update
            "overlap_mean": (sum(ov) / len(ov)) if ov else 0.0,
            "overlap_max": max(ov) if ov else 0,
            "overlap_commits": sum(1 for x in ov if x >= 1),
            "delivery": self.delivery_stats(),
            "delivery_channels": self.delivery_channels(),
            "transport": self.transport_kind,
            "proc_exits": self._proc_counters["proc_exits"],
            "proc_restarts": self._proc_counters["proc_restarts"],
            # cross-process collection (socket + obs only; else empty)
            "child_obs": self.child_obs_report(),
            "flush": dict(getattr(self.server, "flush_totals", {})),
        }
