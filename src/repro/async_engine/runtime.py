"""Wall-clock concurrent runtime: real asynchronous workers behind the
shared ``Engine`` API.

Each worker runs in its own thread (optionally pinned to its own
``jax.devices()`` entry when more than one is visible), executes the same
functional inner round as the simulator (``EngineBase._execute``), and
pushes its compressed pseudo-gradient through a ``Transport`` (bounded
in-process queue today; the interface leaves room for a socket backend).
The server loop drains arrivals and applies the packed fused update from
``Synchronizer.on_arrival`` while the other workers keep computing — the
compute/update overlap the paper's wall-clock claims rest on.

Two commit orders:

  mode="deterministic" (default)
      The virtual-clock event loop from ``EngineBase`` runs unchanged on
      the server thread; compute is merely *eager* (dispatched to the
      worker thread at capture time) instead of lazy. Arrivals are
      committed in virtual-deadline order no matter which thread finishes
      first, so with a fixed seed this runtime reproduces the simulator's
      arrival sequence ``(wid, s_i, staleness, lang)`` exactly and its
      final parameters to fp32 tolerance — the determinism contract
      (docs/runtime.md) and the acceptance anchor for every wall-clock
      experiment.

  mode="free"
      True arrival order: first pseudo-gradient through the transport is
      applied first. ``pace_scale`` maps the configured virtual paces
      onto wall-clock sleeps (a worker with pace p takes at least
      ``h * p * pace_scale`` wall seconds per round), reproducing the
      paper's (1, 2, 6, 15)-style device heterogeneity on homogeneous
      hardware. Failure / elastic event times are interpreted on the same
      scaled clock.

Fault tolerance rides the generation counters the simulator already
uses: a crash bumps the worker's generation, so the in-flight round that
eventually lands through the transport is discarded at the server —
exactly a lost round in a real deployment. The thread itself is only
torn down on elastic leave / shutdown (poison pill + transport close).
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.async_engine.engine import (
    ElasticEvent, EngineBase, FailureEvent, History, RoundResult, RoundTask,
    Worker,
)
from repro.async_engine.transport import (
    InProcTransport, Transport, TransportClosed, TransportTimeout,
)
from repro.configs.base import RunConfig

PyTree = Any


@dataclass
class RoundError:
    """A worker thread raised; carried to the server and re-raised there."""
    wid: int
    generation: int
    round_seq: int
    error: str


class ConcurrentRuntime(EngineBase):
    ENGINE_NAME = "wallclock"

    def __init__(self, run_cfg: RunConfig, *,
                 failures: Optional[List[FailureEvent]] = None,
                 elastic: Optional[List[ElasticEvent]] = None,
                 transport: Optional[Transport] = None,
                 mode: str = "deterministic",
                 pace_scale: float = 0.0,
                 pin_devices: bool = True,
                 queue_capacity: Optional[int] = None,
                 result_timeout: float = 600.0,
                 telemetry=None):
        if mode not in ("deterministic", "free"):
            raise ValueError(f"mode must be 'deterministic' or 'free': {mode}")
        super().__init__(run_cfg, failures=failures, elastic=elastic,
                         telemetry=telemetry)
        self.mode = mode
        self.pace_scale = pace_scale
        self.result_timeout = result_timeout
        self._capacity = queue_capacity or max(2 * len(self.workers), 4)
        self._own_transport = transport is None
        self.transport = transport or InProcTransport(self._capacity)
        self._inboxes: Dict[int, "_queue.Queue"] = {}
        self._threads: Dict[int, threading.Thread] = {}
        self._results: Dict[int, RoundResult] = {}      # task_id -> result
        self._computing = 0
        self._comp_lock = threading.Lock()
        self._shut = False
        self.stats: Dict[str, Any] = {
            "mode": mode, "arrivals": 0, "server_busy_seconds": 0.0,
            "wall_seconds": 0.0, "queue_depth_samples": [],
            "overlap_samples": [], "compute_seconds_total": 0.0,
        }
        devices = jax.devices()
        if pin_devices and len(devices) > 1:
            for w in self.workers.values():
                w.device = devices[w.wid % len(devices)]

    # ------------------------------------------------------- worker threads
    def _start_worker_thread(self, wid: int):
        inbox: "_queue.Queue[Optional[RoundTask]]" = _queue.Queue()
        t = threading.Thread(target=self._worker_loop, args=(inbox,),
                             name=f"heloco-worker-{wid}", daemon=True)
        self._inboxes[wid] = inbox
        self._threads[wid] = t
        t.start()

    def _worker_loop(self, inbox):
        while True:
            task = inbox.get()
            if task is None:
                return
            t0 = time.monotonic()
            with self._comp_lock:
                self._computing += 1
            try:
                if task.device is not None:
                    with jax.default_device(task.device):
                        out: Any = self._execute(task)
                else:
                    out = self._execute(task)
            except Exception as e:                      # noqa: BLE001
                out = RoundError(task.wid, task.generation,
                                 task.round_seq, repr(e))
            finally:
                # the throttle sleep below is emulated device time, not
                # real compute: keep it out of the overlap evidence
                with self._comp_lock:
                    self._computing -= 1
            # pace throttle: a device at `pace` sec/step takes at least
            # h * pace * pace_scale wall seconds per round
            if task.sleep_per_step > 0 and not isinstance(out, RoundError):
                rest = (task.h_steps * task.sleep_per_step
                        - (time.monotonic() - t0))
                if rest > 0:
                    time.sleep(rest)
            try:
                self.transport.send(out)
            except TransportClosed:
                return                                  # shutdown race: drop

    # --------------------------------------------------------- engine hooks
    def _use_virtual_clock(self) -> bool:
        return self.mode == "deterministic"

    def _sleep_per_step(self, w: Worker) -> float:
        return w.pace * self.pace_scale if self.mode == "free" else 0.0

    def _submit(self, task: RoundTask):
        self._ensure_open()
        th = self._threads.get(task.wid)
        if th is None or not th.is_alive():
            self._start_worker_thread(task.wid)
        self._inboxes[task.wid].put(task)

    def _recv_result(self, timeout: Optional[float] = None) -> RoundResult:
        """One transport message, with stats + error unwrapping. With an
        explicit ``timeout`` the ``TransportTimeout`` propagates (polling
        callers keep their event clock ticking); without one it is a hard
        liveness failure."""
        try:
            msg = self.transport.recv(
                timeout=self.result_timeout if timeout is None else timeout)
        except TransportTimeout:
            if timeout is not None:
                raise
            raise RuntimeError(
                f"no arrival within {self.result_timeout}s — worker thread "
                f"dead or wedged (threads alive: "
                f"{[w for w, t in self._threads.items() if t.is_alive()]})")
        self.stats["queue_depth_samples"].append(self.transport.depth())
        if isinstance(msg, RoundError):
            raise RuntimeError(
                f"worker {msg.wid} round {msg.round_seq} failed: {msg.error}")
        self.stats["compute_seconds_total"] += msg.compute_seconds
        return msg

    def _is_current(self, res: RoundResult) -> bool:
        """A result counts only if it is the round its worker is waiting
        on. Task ids are engine-unique, so a departed incarnation of a
        reused wid (or a crashed generation) can never be mistaken for
        the live worker's round."""
        w = self.workers.get(res.wid)
        return w is not None and res.task_id == w.pending_task_id

    def _obtain(self, w: Worker) -> RoundResult:
        """Block until THIS worker's outstanding round has landed; results
        from other workers are parked, stale rounds dropped (lost
        in-flight rounds of crashed / departed workers)."""
        want = w.pending_task_id
        while want not in self._results:
            res = self._recv_result()
            if self._is_current(res):
                self._results[res.task_id] = res
        return self._results.pop(want)

    def _commit(self, w: Worker, res: RoundResult):
        with self._comp_lock:
            overlap = self._computing
        t0 = time.monotonic()
        rec = super()._commit(w, res)
        # materialize the outer step so busy time is real, not dispatch time
        jax.block_until_ready(self.server._pbuf if self.server.packed
                              else jax.tree.leaves(self.server.state.params))
        self.stats["server_busy_seconds"] += time.monotonic() - t0
        self.stats["overlap_samples"].append(overlap)
        self.stats["arrivals"] += 1
        return rec

    def _crash_worker(self, w: Worker):
        if w.pending_task_id is not None:               # drop a parked result
            self._results.pop(w.pending_task_id, None)
        super()._crash_worker(w)

    def _on_worker_removed(self, w: Worker):
        inbox = self._inboxes.pop(w.wid, None)
        if inbox is not None:
            inbox.put(None)                             # poison pill
        self._threads.pop(w.wid, None)
        if w.pending_task_id is not None:
            self._results.pop(w.pending_task_id, None)

    # ------------------------------------------------------------ lifecycle
    def _ensure_open(self):
        if self._shut:
            if not self._own_transport:
                raise RuntimeError("transport closed; inject a fresh one")
            self.transport = InProcTransport(self._capacity)
            self._shut = False

    def shutdown(self):
        """Tear down worker threads. Idempotent; ``run``/``restore`` after
        shutdown transparently rebuild the channel + threads."""
        self._shut = True
        self.transport.close()
        for inbox in self._inboxes.values():
            inbox.put(None)
        for t in self._threads.values():
            t.join(timeout=5.0)
        self._inboxes.clear()
        self._threads.clear()
        self._results.clear()

    # -------------------------------------------------------------- run
    def run(self, eval_every: int = 0,
            eval_fn: Optional[Callable[[PyTree, int, float], Dict]] = None,
            ckpt_every: int = 0, ckpt_dir: str = "",
            budget=None) -> History:
        t0 = time.monotonic()
        try:
            if self.mode == "free" and not self.server.method.sync:
                hist = self._run_free(eval_every, eval_fn, ckpt_every,
                                      ckpt_dir, budget)
            else:
                hist = super().run(eval_every, eval_fn, ckpt_every, ckpt_dir,
                                   budget)
        finally:
            self.stats["wall_seconds"] += time.monotonic() - t0
            self.shutdown()
        return hist

    # ------------------------------------------------------- free-run loop
    def _run_free(self, eval_every, eval_fn, ckpt_every, ckpt_dir,
                  budget=None) -> History:
        """True arrival order on the wall clock. ``self.time`` is reported
        in virtual seconds (wall / pace_scale) so histories stay
        comparable with the simulator; with pace_scale == 0 it is raw wall
        seconds. Failure / elastic / restart times live on that clock.
        A ``Budget`` is accounted on the same clock (fixed_wallclock) or
        on committed tokens (fixed_tokens)."""
        self._ensure_telemetry_meta()
        target = self.cfg.outer_steps
        t0 = time.monotonic()
        scale = self.pace_scale if self.pace_scale > 0 else 1.0
        fail_idx = el_idx = 0
        restarts: List[Tuple[float, int]] = []
        for w in self.workers.values():
            if w.alive and not w.in_flight:
                self._dispatch(w)

        def vnow() -> float:
            return (time.monotonic() - t0) / scale

        def process_events(vt: float):
            nonlocal fail_idx, el_idx
            while (fail_idx < len(self.failures)
                   and self.failures[fail_idx].time <= vt):
                ev = self.failures[fail_idx]
                fail_idx += 1
                w = self.workers.get(ev.wid)
                if w is None:
                    continue
                self._crash_worker(w)
                restarts.append((ev.time + ev.restart_delay, ev.wid))
                restarts.sort()
            while (el_idx < len(self.elastic)
                   and self.elastic[el_idx].time <= vt):
                self._handle_elastic(self.elastic[el_idx])
                el_idx += 1
            while restarts and restarts[0][0] <= vt:
                _, wid = restarts.pop(0)
                w = self.workers.get(wid)
                if w is not None and not w.alive:
                    w.alive = True
                    self._dispatch(w)

        def progress_possible() -> bool:
            """Someone will eventually produce an arrival: a live worker,
            a pending restart, or an unfired failure/elastic event."""
            return (any(w.alive for w in self.workers.values())
                    or bool(restarts)
                    or fail_idx < len(self.failures)
                    or el_idx < len(self.elastic))

        while self.server.t < target:
            process_events(vnow())
            if not progress_possible():
                break                   # every worker gone: starved run
            if budget is not None and budget.over_time(vnow()):
                break                   # clock horizon: stop committing
            try:
                msg = self._recv_result(timeout=0.05)
            except TransportTimeout:
                continue                # keep event clock ticking
            if not self._is_current(msg) or not self.workers[msg.wid].alive:
                continue                # stale: crashed / departed worker
            w = self.workers[msg.wid]
            self.time = vnow()
            if budget is not None and budget.over_time(self.time):
                break                   # arrived past the horizon: drop it
            self._commit(w, msg)
            self._post_commit(eval_every, eval_fn, ckpt_every, ckpt_dir)
            if budget is not None and budget.over_tokens(self.history.tokens):
                break
            if self.server.t < target:
                process_events(vnow())
                if w.alive:
                    self._dispatch(w)
        self.time = vnow()
        return self._finalize(eval_fn)

    # -------------------------------------------------------- sync barrier
    def _execute_sync(self, tasks: List[RoundTask]) -> List[RoundResult]:
        """Sync DiLoCo round with genuinely parallel workers: all inner
        rounds run concurrently, the barrier is the transport collect."""
        for task in tasks:
            self._submit(task)
        want = {t.task_id: i for i, t in enumerate(tasks)}
        got: Dict[int, RoundResult] = {}
        while len(got) < len(tasks):
            res = self._recv_result()
            idx = want.get(res.task_id)
            if idx is not None:
                got[idx] = res
        return [got[i] for i in range(len(tasks))]

    # ----------------------------------------------------------- reporting
    def stats_summary(self) -> Dict[str, Any]:
        q = self.stats["queue_depth_samples"]
        ov = self.stats["overlap_samples"]
        wall = max(self.stats["wall_seconds"], 1e-9)
        return {
            "mode": self.mode,
            "arrivals": self.stats["arrivals"],
            "wall_seconds": self.stats["wall_seconds"],
            "arrivals_per_sec": self.stats["arrivals"] / wall,
            "server_busy_seconds": self.stats["server_busy_seconds"],
            "server_occupancy": self.stats["server_busy_seconds"] / wall,
            "compute_seconds_total": self.stats["compute_seconds_total"],
            # >1.0 means workers computed more seconds than wall passed:
            # genuine concurrency
            "compute_parallelism": self.stats["compute_seconds_total"] / wall,
            "queue_depth_mean": (sum(q) / len(q)) if q else 0.0,
            "queue_depth_max": max(q) if q else 0,
            # workers mid-round at the moment the server applied an update
            "overlap_mean": (sum(ov) / len(ov)) if ov else 0.0,
            "overlap_max": max(ov) if ov else 0,
            "overlap_commits": sum(1 for x in ov if x >= 1),
        }
