"""Multi-process socket Transport: real worker processes behind the
delivery protocol PR 6 built for exactly this backend.

Everything concurrent before this module was threads in one process.
Here the parent spawns worker processes (``multiprocessing`` "spawn"
context — fresh interpreters, no forked JAX state), a socket rendezvous
assigns worker ids, and the existing ``Envelope``/``Ack`` CRC frames
travel over length-prefixed sockets. The at-least-once machinery is
reused VERBATIM: children run the same ``ReliableSender`` retry loop
(``repro.async_engine.transport``) and the same ``execute_round`` inner
round (``repro.async_engine.engine``) the threaded runtime uses, the
parent keeps its ``DeliveryTracker`` dedup/quarantine bookkeeping, and
``FaultyTransport`` wraps the child's wire channels without touching the
protocol — ``make chaos`` runs unchanged over sockets
(``TRANSPORT=socket``).

Wire format
-----------

One frame = ``!II`` header (payload length, CRC32 of the payload bytes)
followed by a pickled tuple ``(tag, ...)``:

  parent <- child   ("join", {nonce, pid})        rendezvous hello
                    ("msg", Envelope)             credited data frame
                    ("hb", Envelope)              uncredited heartbeat
                    ("ctrl", "stats", {...})      per-channel fault tally
                    ("ctrl", "obs", {...})        low-rate span batch +
                                                  wire/compute counters
  parent -> child   ("assign", {wid, credit, cfg, faults, mode,
                               t_parent, obs, ...})
                    ("reject", reason)            no rendezvous slot
                    ("task", RoundTask, clock)    dispatched round
                    ("ack", Ack)                  delivery receipt
                    ("credit", n)                 flow-control window top-up
                    ("stop",)                     graceful shutdown

A corrupted frame on the wire (header CRC mismatch) raises ``WireError``
and tears the connection down — distinct from *injected* payload
corruption, which flips ``Envelope.crc`` before pickling and is rejected
by the parent's ``DeliveryTracker`` exactly as on the in-process path.

Rendezvous
----------

``WorkerProcessPool.ensure(wid)`` registers a one-time nonce, spawns the
child with ``(address, nonce)``, and blocks until the child connects and
presents the nonce; the parent then ASSIGNS the worker id (and ships the
``RunConfig`` + ``FaultSpec``) in the reply — ids are assigned over the
socket, never baked into argv. A join with an unknown/used nonce is
rejected (duplicate-join defense); a child that dies first fails
``ensure`` with a rendezvous error; ``close()`` stops, joins, and
terminates any straggler so no orphan process survives the parent.

Flow control
------------

Bounded backpressure matches ``InProcTransport`` semantics: each
connection holds ``capacity`` credits, a data frame costs one, and the
parent returns a credit when ``recv`` pops the message — a producer that
outruns the server parks in ``send`` (and honours timeout deadlines
exactly), no message is ever dropped by the channel itself.

Crash recovery
--------------

A dying worker process surfaces as a ``WorkerExit`` sentinel in the
parent's receive stream. The runtime respawns the process and resubmits
the pending ``RoundTask`` snapshot — a deterministic recompute of the
same round (same task_id), so deterministic mode replays the sim goldens
trace-identically straight through a mid-run process kill.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import pickle
import queue as _queue
import socket
import struct
import sys
import tempfile
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.async_engine.engine import (
    RoundResult, RoundTask, execute_round,
)
from repro.async_engine.faults import FaultyTransport
from repro.async_engine.transport import (
    AckWaiter, Envelope, KIND_ERROR, KIND_HEARTBEAT, KIND_RESULT,
    ReliableSender, Transport, TransportClosed, TransportTimeout,
    payload_crc,
)

_HDR = struct.Struct("!II")          # (payload length, CRC32 of payload)
_MAX_FRAME = 1 << 30


class WireError(Exception):
    """Malformed / checksum-failed frame on the wire (connection-fatal)."""


class RendezvousRejected(Exception):
    """The parent refused this join (unknown or already-used nonce)."""


@dataclass(frozen=True)
class WorkerExit:
    """Sentinel surfaced in the parent's receive stream when a worker
    process' connection drops outside a graceful shutdown."""
    wid: int
    incarnation: int


# ---------------------------------------------------------------------------
# Frame I/O
# ---------------------------------------------------------------------------

def _send_frame(sock: socket.socket, lock: threading.Lock, obj: Any,
                stats: Optional[Dict[str, Any]] = None) -> None:
    if stats is None:
        data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    else:
        t0 = time.perf_counter()
        data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        stats["ser_s"] += time.perf_counter() - t0
    hdr = _HDR.pack(len(data), zlib.crc32(data) & 0xFFFFFFFF)
    with lock:
        sock.sendall(hdr + data)
        if stats is not None:
            stats["frames_sent"] += 1
            stats["bytes_sent"] += len(hdr) + len(data)


def _read_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            raise EOFError("connection closed mid-frame")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def _recv_frame(sock: socket.socket,
                stats: Optional[Dict[str, Any]] = None) -> Any:
    length, crc = _HDR.unpack(_read_exact(sock, _HDR.size))
    if length > _MAX_FRAME:
        raise WireError(f"frame length {length} exceeds cap")
    data = _read_exact(sock, length)
    if zlib.crc32(data) & 0xFFFFFFFF != crc:
        if stats is not None:
            stats["crc_rejects"] += 1
        raise WireError("frame CRC mismatch on the wire")
    if stats is None:
        return pickle.loads(data)
    t0 = time.perf_counter()
    obj = pickle.loads(data)
    stats["deser_s"] += time.perf_counter() - t0
    stats["frames_recv"] += 1
    stats["bytes_recv"] += _HDR.size + length
    return obj


def _new_wire_stats() -> Dict[str, Any]:
    """Per-connection wire counters (the transport-metrics vocabulary of
    ``repro.telemetry.schema.TransportMetrics``, minus the compute
    fields). Updated under the send lock / by the single reader thread,
    so plain dict math is race-free."""
    return {"frames_sent": 0, "frames_recv": 0, "bytes_sent": 0,
            "bytes_recv": 0, "ser_s": 0.0, "deser_s": 0.0,
            "crc_rejects": 0, "credit_wait_s": 0.0}


# ---------------------------------------------------------------------------
# Host-side serialization of pytree payloads
# ---------------------------------------------------------------------------

def _np_tree(tree: Any) -> Any:
    """Device -> host: every leaf to ``np.asarray`` (fp32 bytes round-trip
    exactly, so ``payload_crc`` is identical on either side of the wire)."""
    return jax.tree.map(np.asarray, tree)


def host_task(task: RoundTask) -> RoundTask:
    """Wire form of a dispatched round: pytrees host-ified, the
    unpicklable device pin stripped (children own their devices)."""
    return dataclasses.replace(
        task, params=_np_tree(task.params), opt=_np_tree(task.opt),
        ef=_np_tree(task.ef), device=None)


def _host_envelope(env: Envelope) -> Envelope:
    if isinstance(env.payload, RoundResult):
        p = env.payload
        return dataclasses.replace(
            env, payload=dataclasses.replace(
                p, delta=_np_tree(p.delta), opt=_np_tree(p.opt),
                ef=_np_tree(p.ef)))
    return env


# ---------------------------------------------------------------------------
# Parent side: SocketTransport
# ---------------------------------------------------------------------------

class _Conn:
    """One accepted connection (registry entry + best-effort sender)."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.lock = threading.Lock()
        self.wid: Optional[int] = None
        self.incarnation: int = 0
        self.alive = True

    def send(self, obj: Any) -> bool:
        try:
            _send_frame(self.sock, self.lock, obj)
            return True
        except (OSError, ValueError):
            self.alive = False
            return False

    def kill(self):
        self.alive = False
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


def _default_family() -> str:
    fam = os.environ.get("REPRO_SOCKET_FAMILY", "")
    if fam in ("unix", "tcp"):
        return fam
    return "unix" if hasattr(socket, "AF_UNIX") else "tcp"


class SocketTransport(Transport):
    """The parent/receiver end of the socket backend — a genuine
    ``Transport``: ``send`` goes through a lazily-created loopback client
    over the real wire (so the backend is a drop-in for every transport-
    semantics test and can be wrapped by ``FaultyTransport``), ``recv``
    drains frames pushed by the per-connection reader threads. Bounded,
    FIFO per connection, close-wakes-everyone, exact timeout deadlines —
    the ``InProcTransport`` contract over sockets."""

    def __init__(self, capacity: int = 8, family: Optional[str] = None,
                 hb_sink: Optional[Transport] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.family = family or _default_family()
        self.hb_sink = hb_sink
        # pool hooks (None on a standalone transport):
        self.on_join: Optional[Callable[["_Conn", Dict], Optional[Dict]]] \
            = None
        self.on_ready: Optional[Callable[["_Conn"], None]] = None
        self.on_exit: Optional[Callable[["_Conn"], None]] = None
        self.on_control: Optional[Callable[["_Conn", str, Any], None]] = None
        self._dq: "list" = []                    # [(msg, conn-or-None)]
        lock = threading.Lock()
        self._not_empty = threading.Condition(lock)
        self._reg_lock = threading.Lock()
        self._conns: list = []
        self._closed = False
        self._tmpdir: Optional[str] = None
        self._loop_client: Optional["SocketClient"] = None
        self._loop_lock = threading.Lock()
        if self.family == "unix":
            self._tmpdir = tempfile.mkdtemp(prefix="heloco-sock-")
            path = os.path.join(self._tmpdir, "s")
            self._listener = socket.socket(socket.AF_UNIX,
                                           socket.SOCK_STREAM)
            self._listener.bind(path)
            self.address: Tuple[str, Any] = ("unix", path)
        else:
            self._listener = socket.socket(socket.AF_INET,
                                           socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
            self._listener.bind(("127.0.0.1", 0))
            self.address = ("tcp", self._listener.getsockname())
        self._listener.listen(64)
        self._acceptor = threading.Thread(target=self._accept_loop,
                                          name="heloco-sock-accept",
                                          daemon=True)
        self._acceptor.start()

    # -------------------------------------------------------------- accept
    def _accept_loop(self):
        while True:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return                           # listener closed
            conn = _Conn(sock)
            threading.Thread(target=self._conn_loop, args=(conn,),
                             name="heloco-sock-conn", daemon=True).start()

    def _conn_loop(self, conn: _Conn):
        try:
            frame = _recv_frame(conn.sock)
        except (EOFError, OSError, WireError, pickle.UnpicklingError):
            conn.kill()
            return
        if not (isinstance(frame, tuple) and frame
                and frame[0] == "join"):
            conn.send(("reject", "expected a join frame"))
            conn.kill()
            return
        info = frame[1] if len(frame) > 1 else {}
        if self.on_join is not None:
            payload = self.on_join(conn, info)
        else:                                    # standalone / loopback
            payload = {"wid": None, "credit": self.capacity}
        if payload is None:
            conn.send(("reject", "no pending rendezvous slot for this "
                                 "join (duplicate or unknown nonce)"))
            conn.kill()
            return
        with self._reg_lock:
            if self._closed:
                conn.send(("reject", "transport closed"))
                conn.kill()
                return
            self._conns.append(conn)
        if not conn.send(("assign", payload)):
            return
        if self.on_ready is not None:
            self.on_ready(conn)
        try:
            while True:
                frame = _recv_frame(conn.sock)
                tag = frame[0]
                if tag == "msg":
                    with self._not_empty:
                        self._dq.append((frame[1], conn))
                        self._not_empty.notify()
                elif tag == "hb":
                    if self.hb_sink is not None:
                        try:
                            self.hb_sink.send(frame[1], timeout=0.01)
                        except (TransportTimeout, TransportClosed):
                            pass                 # side channel full: drop
                    else:
                        with self._not_empty:
                            self._dq.append((frame[1], None))
                            self._not_empty.notify()
                elif tag == "ctrl":
                    if self.on_control is not None:
                        self.on_control(conn, frame[1], frame[2])
        except (EOFError, OSError, WireError, pickle.UnpicklingError):
            pass
        finally:
            conn.kill()
            with self._reg_lock:
                if conn in self._conns:
                    self._conns.remove(conn)
            if not self._closed and self.on_exit is not None:
                self.on_exit(conn)

    # ------------------------------------------------------- local inject
    def push_local(self, msg: Any):
        """Parent-side sentinel injection (``WorkerExit``): bypasses the
        wire and the credit window."""
        with self._not_empty:
            self._dq.append((msg, None))
            self._not_empty.notify()

    # ---------------------------------------------------------- Transport
    def _loopback(self) -> "SocketClient":
        with self._loop_lock:
            if self._loop_client is None or self._loop_client.closed:
                if self._closed:
                    raise TransportClosed("send on closed transport")
                self._loop_client = SocketClient.connect(
                    self.address, {"kind": "loopback"}, timeout=10.0)
                self._loop_client.start()
            return self._loop_client

    def send(self, msg: Any, timeout: Optional[float] = None) -> None:
        if self._closed:
            raise TransportClosed("send on closed transport")
        self._loopback().send_data(msg, timeout=timeout)

    def recv(self, timeout: Optional[float] = None) -> Any:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._not_empty:
            while True:
                if self._dq:
                    msg, conn = self._dq.pop(0)
                    break
                if self._closed:
                    raise TransportClosed("recv on closed, drained "
                                          "transport")
                if deadline is None:
                    self._not_empty.wait()
                else:
                    rest = deadline - time.monotonic()
                    if rest <= 0:
                        raise TransportTimeout(f"recv idle > {timeout}s")
                    self._not_empty.wait(rest)
        if conn is not None and conn.alive:
            conn.send(("credit", 1))             # return the flow credit
        return msg

    def close(self) -> None:
        with self._not_empty:
            if self._closed:
                return
            self._closed = True
            self._not_empty.notify_all()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._loop_lock:
            if self._loop_client is not None:
                self._loop_client.close()
        with self._reg_lock:
            conns, self._conns = list(self._conns), []
        for conn in conns:
            conn.kill()
        if self._tmpdir is not None:
            try:
                os.unlink(self.address[1])
            except OSError:
                pass
            try:
                os.rmdir(self._tmpdir)
            except OSError:
                pass
            self._tmpdir = None

    def depth(self) -> int:
        return len(self._dq)


# ---------------------------------------------------------------------------
# Client side (children + loopback)
# ---------------------------------------------------------------------------

class SocketClient:
    """The worker end of a connection: credited data sends, uncredited
    heartbeats, and a reader thread routing acks / tasks / credits /
    stop back to callbacks."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._cond = threading.Condition()
        self._credits = 0
        self.closed = False
        self.assign: Dict[str, Any] = {}
        #: cumulative wire counters (frames/bytes/ser/deser/crc/credit)
        self.wire: Dict[str, Any] = _new_wire_stats()
        #: child->parent perf_counter offset estimated at rendezvous
        #: (parent_time ~= child_time + clock_offset); 0.0 when the
        #: assign reply carried no parent timestamp (standalone mode)
        self.clock_offset = 0.0
        self.on_ack: Optional[Callable[[Any], None]] = None
        self.on_task: Optional[Callable[[Any, Any], None]] = None
        self.on_stop: Optional[Callable[[], None]] = None
        self.on_disconnect: Optional[Callable[[], None]] = None
        self._reader: Optional[threading.Thread] = None

    @classmethod
    def connect(cls, address: Tuple[str, Any], join_info: Dict,
                timeout: float = 30.0) -> "SocketClient":
        family, target = address
        if family == "unix":
            sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            sock.settimeout(timeout)
            sock.connect(target)
        else:
            sock = socket.create_connection(tuple(target), timeout=timeout)
        client = cls(sock)
        try:
            # the join->assign round trip doubles as the clock-offset
            # probe: the parent stamps its perf_counter into the assign
            # payload, and the midpoint of [t0, t1] estimates when that
            # stamp was taken on the child's clock (docs/observability.md,
            # "Cross-process collection")
            t0 = time.perf_counter()
            _send_frame(sock, client._send_lock, ("join", dict(join_info)),
                        client.wire)
            frame = _recv_frame(sock, client.wire)
            t1 = time.perf_counter()
        except (EOFError, OSError, WireError) as e:
            sock.close()
            raise RendezvousRejected(f"rendezvous failed: {e!r}") from e
        if frame[0] == "reject":
            sock.close()
            raise RendezvousRejected(frame[1])
        if frame[0] != "assign":
            sock.close()
            raise RendezvousRejected(f"unexpected frame {frame[0]!r}")
        sock.settimeout(None)
        client.assign = frame[1]
        client._credits = int(client.assign.get("credit", 8))
        t_parent = client.assign.get("t_parent")
        if t_parent is not None:
            client.clock_offset = float(t_parent) - (t0 + t1) / 2.0
        return client

    def start(self):
        self._reader = threading.Thread(target=self._read_loop,
                                        name="heloco-sock-client",
                                        daemon=True)
        self._reader.start()

    def _read_loop(self):
        try:
            while True:
                frame = _recv_frame(self._sock, self.wire)
                tag = frame[0]
                if tag == "credit":
                    with self._cond:
                        self._credits += frame[1]
                        self._cond.notify_all()
                elif tag == "ack":
                    if self.on_ack is not None:
                        self.on_ack(frame[1])
                elif tag == "task":
                    if self.on_task is not None:
                        self.on_task(frame[1], frame[2])
                elif tag == "stop":
                    if self.on_stop is not None:
                        self.on_stop()
        except (EOFError, OSError, WireError, pickle.UnpicklingError):
            pass
        finally:
            with self._cond:
                self.closed = True
                self._cond.notify_all()
            if self.on_disconnect is not None:
                self.on_disconnect()

    # --------------------------------------------------------------- sends
    def send_data(self, msg: Any, timeout: Optional[float] = None) -> None:
        """Credited send with ``InProcTransport`` blocking semantics."""
        if isinstance(msg, Envelope):
            msg = _host_envelope(msg)
        deadline = None if timeout is None else time.monotonic() + timeout
        t_wait = time.perf_counter()
        with self._cond:
            while True:
                if self.closed:
                    raise TransportClosed("send on closed transport")
                if self._credits > 0:
                    self._credits -= 1
                    # stall time spent parked on the credit window (the
                    # flow-control backpressure the panels surface)
                    self.wire["credit_wait_s"] += (time.perf_counter()
                                                   - t_wait)
                    break
                if deadline is None:
                    self._cond.wait()
                else:
                    rest = deadline - time.monotonic()
                    if rest <= 0:
                        raise TransportTimeout(
                            f"send blocked > {timeout}s (window "
                            f"exhausted)")
                    self._cond.wait(rest)
        try:
            _send_frame(self._sock, self._send_lock, ("msg", msg),
                        self.wire)
        except (OSError, ValueError) as e:
            raise TransportClosed(f"send failed: {e!r}") from e

    def send_hb(self, env: Envelope) -> None:
        """Uncredited heartbeat beacon (side channel semantics)."""
        if self.closed:
            raise TransportClosed("heartbeat on closed transport")
        try:
            _send_frame(self._sock, self._send_lock, ("hb", env), self.wire)
        except (OSError, ValueError) as e:
            raise TransportClosed(f"heartbeat failed: {e!r}") from e

    def send_ctrl(self, tag: str, obj: Any) -> None:
        _send_frame(self._sock, self._send_lock, ("ctrl", tag, obj),
                    self.wire)

    def close(self):
        with self._cond:
            self.closed = True
            self._cond.notify_all()
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


class _ChildChannel(Transport):
    """Child-side ``Transport`` facade over the shared ``SocketClient``
    — one per logical channel so ``FaultyTransport`` wraps data and
    heartbeats independently, exactly as the threaded runtime does."""

    def __init__(self, client: SocketClient, kind: str):
        assert kind in ("data", "hb")
        self.client = client
        self.kind = kind

    def send(self, msg: Any, timeout: Optional[float] = None) -> None:
        if self.kind == "data":
            self.client.send_data(msg, timeout=timeout)
        else:
            self.client.send_hb(msg)

    def recv(self, timeout: Optional[float] = None) -> Any:
        raise RuntimeError("child channels are send-only")

    def close(self) -> None:
        self.client.close()

    def depth(self) -> int:
        return 0


# ---------------------------------------------------------------------------
# Parent side: the worker-process pool
# ---------------------------------------------------------------------------

class WorkerProcessPool:
    """Spawns and tracks one process per worker id, owns the rendezvous,
    and bridges the runtime's submit/ack API onto per-connection frames."""

    RENDEZVOUS_TIMEOUT = 120.0

    def __init__(self, run_cfg, *, capacity: int = 8, faults=None,
                 mode: str = "deterministic", pace_scale: float = 0.0,
                 hb_sink: Optional[Transport] = None,
                 family: Optional[str] = None,
                 obs: bool = False, obs_every: int = 4):
        self.run_cfg = run_cfg
        self.faults = faults
        self.mode = mode
        self.pace_scale = pace_scale
        #: cross-process observability: when set, children run their own
        #: SpanTracer + wire counters and ship ("ctrl","obs",...) frames
        #: every ``obs_every`` rounds and at graceful stop
        self.obs = bool(obs)
        self.obs_every = max(1, int(obs_every))
        #: parent hook receiving each child obs payload (runtime-owned)
        self.on_obs: Optional[Callable[[Dict], None]] = None
        #: wid -> number of obs reports received (any incarnation)
        self.obs_reports: Dict[int, int] = {}
        #: wids whose graceful final obs report arrived
        self.obs_final: set = set()
        self.transport = SocketTransport(capacity=capacity, family=family,
                                         hb_sink=hb_sink)
        self.transport.on_join = self._on_join
        self.transport.on_ready = self._on_ready
        self.transport.on_exit = self._on_exit
        self.transport.on_control = self._on_control
        self._ctx = mp.get_context("spawn")
        self._lock = threading.Lock()
        self._pending: Dict[str, Tuple[int, int]] = {}   # nonce->(wid,inc)
        self._conns: Dict[int, _Conn] = {}
        self._procs: Dict[int, Any] = {}
        self._inc: Dict[int, int] = {}
        self._ready: Dict[Tuple[int, int], threading.Event] = {}
        self._closing = False
        #: per-channel fault/protocol counters reported by children at
        #: graceful shutdown: {"data": {...}, "heartbeat": {...},
        #: "protocol": {"retries": n}}
        self.child_counters: Dict[str, Dict[str, int]] = {}
        self.proc_exits = 0
        self.clock: Tuple[Optional[float], float] = (None, pace_scale)

    # ----------------------------------------------------------- rendezvous
    def _on_join(self, conn: _Conn, info: Dict) -> Optional[Dict]:
        nonce = info.get("nonce")
        with self._lock:
            ent = self._pending.pop(nonce, None) if nonce else None
            if ent is None or self._closing:
                return None                      # reject (duplicate join)
            wid, inc = ent
            conn.wid, conn.incarnation = wid, inc
            self._conns[wid] = conn
        # t_parent lets the child estimate its clock offset against the
        # parent's perf_counter (midpoint of the join->assign round trip)
        return {"wid": wid, "credit": self.transport.capacity,
                "cfg": self.run_cfg, "faults": self.faults,
                "mode": self.mode, "pace_scale": self.pace_scale,
                "t_parent": time.perf_counter(),
                "obs": self.obs, "obs_every": self.obs_every}

    def _on_ready(self, conn: _Conn):
        ev = self._ready.get((conn.wid, conn.incarnation))
        if ev is not None:
            ev.set()

    def _on_exit(self, conn: _Conn):
        with self._lock:
            if self._closing or conn.wid is None:
                return
            if self._conns.get(conn.wid) is not conn:
                return                           # stale incarnation
            del self._conns[conn.wid]
            self.proc_exits += 1
        self.transport.push_local(WorkerExit(conn.wid, conn.incarnation))

    def _on_control(self, conn: _Conn, tag: str, obj: Any):
        if tag == "obs" and isinstance(obj, dict):
            wid = obj.get("wid", conn.wid)
            with self._lock:
                if wid is not None:
                    self.obs_reports[wid] = self.obs_reports.get(wid, 0) + 1
                    if obj.get("final"):
                        self.obs_final.add(wid)
            hook = self.on_obs
            if hook is not None:
                hook(obj)
            return
        if tag != "stats" or not isinstance(obj, dict):
            return
        with self._lock:
            for channel, counters in obj.items():
                acc = self.child_counters.setdefault(channel, {})
                for k, v in counters.items():
                    acc[k] = acc.get(k, 0) + int(v)

    # ------------------------------------------------------------ lifecycle
    def incarnation(self, wid: int) -> int:
        return self._inc.get(wid, 0)

    def alive(self, wid: int) -> bool:
        conn = self._conns.get(wid)
        return conn is not None and conn.alive

    def ensure(self, wid: int) -> Optional[int]:
        """Spawn (or respawn) the worker process for ``wid`` and complete
        the rendezvous. Returns the new incarnation when a process was
        started, None when a live one already serves the wid."""
        with self._lock:
            if self._closing:
                raise TransportClosed("worker pool closed")
            conn = self._conns.get(wid)
            if conn is not None and conn.alive:
                return None
            inc = self._inc.get(wid, 0) + 1
            self._inc[wid] = inc
            nonce = f"w{wid}-i{inc}-p{os.getpid()}"
            self._pending[nonce] = (wid, inc)
            ready = threading.Event()
            self._ready[(wid, inc)] = ready
        # children must see the parent's backend: spawn inherits the env
        prev = os.environ.get("JAX_PLATFORMS")
        os.environ["JAX_PLATFORMS"] = prev or jax.default_backend()
        try:
            proc = self._ctx.Process(target=_worker_main,
                                     args=(self.transport.address, nonce),
                                     name=f"heloco-proc-{wid}",
                                     daemon=True)
            proc.start()
        finally:
            if prev is None:
                os.environ.pop("JAX_PLATFORMS", None)
            else:
                os.environ["JAX_PLATFORMS"] = prev
        with self._lock:
            self._procs[wid] = proc
        deadline = time.monotonic() + self.RENDEZVOUS_TIMEOUT
        while not ready.wait(0.05):
            if not proc.is_alive():
                with self._lock:
                    self._pending.pop(nonce, None)
                    self._ready.pop((wid, inc), None)
                raise RuntimeError(
                    f"worker {wid} died before the rendezvous completed "
                    f"(exit code {proc.exitcode})")
            if time.monotonic() > deadline:
                proc.terminate()
                with self._lock:
                    self._pending.pop(nonce, None)
                    self._ready.pop((wid, inc), None)
                raise RuntimeError(f"worker {wid} rendezvous timed out "
                                   f"after {self.RENDEZVOUS_TIMEOUT}s")
        self._ready.pop((wid, inc), None)
        return inc

    # ------------------------------------------------------------- data path
    def submit(self, wid: int, task: RoundTask) -> None:
        """Frame a dispatched round to the worker's process. A send to a
        connection that just died is NOT an error: the reader thread
        surfaces a ``WorkerExit`` and the runtime resubmits."""
        conn = self._conns.get(wid)
        if conn is None:
            raise TransportClosed(f"worker {wid} has no live process")
        conn.send(("task", host_task(task), self.clock))

    def send_ack(self, wid: int, ack) -> None:
        conn = self._conns.get(wid)
        if conn is not None:
            conn.send(("ack", ack))

    def kill(self, wid: int) -> None:
        """Hard-remove a worker process (elastic leave / test kill).
        Deregisters first so no ``WorkerExit`` sentinel is emitted."""
        with self._lock:
            conn = self._conns.pop(wid, None)
            proc = self._procs.pop(wid, None)
        if conn is not None:
            conn.kill()
        if proc is not None and proc.is_alive():
            proc.terminate()
            proc.join(timeout=5.0)

    def close(self) -> None:
        """Graceful stop -> stats harvest -> join -> terminate stragglers
        -> close the listener. No orphan process survives this."""
        with self._lock:
            if self._closing:
                return
            self._closing = True
            conns = list(self._conns.values())
            self._conns.clear()
            procs = list(self._procs.values())
            self._procs.clear()
        for conn in conns:
            conn.send(("stop",))
        for proc in procs:
            proc.join(timeout=10.0)
        for proc in procs:
            if proc.is_alive():
                proc.terminate()
        for proc in procs:
            if proc.is_alive():
                proc.join(timeout=5.0)
        self.transport.close()


# ---------------------------------------------------------------------------
# Child side: the worker process entry point
# ---------------------------------------------------------------------------

_STOP = object()
_EOF = object()


def _worker_main(address: Tuple[str, Any], nonce: str) -> None:
    """Worker process entry (top-level: spawn re-imports this module).

    Rendezvous -> reconstruct the immutable run state from the ASSIGNED
    ``RunConfig`` (model, language specs, int8 layout — all deterministic
    in the config, so results are bit-identical to an in-process round)
    -> loop: execute ``RoundTask`` frames with the shared
    ``execute_round`` and deliver results through the shared
    ``ReliableSender``, optionally behind child-side ``FaultyTransport``
    wrappers (stream 0 = data, stream 1 = heartbeats — the same dice keys
    as the threaded runtime, so chaos runs replay identically)."""
    try:
        client = SocketClient.connect(address,
                                      {"nonce": nonce, "pid": os.getpid()})
    except RendezvousRejected:
        sys.exit(3)
    assign = client.assign
    wid = assign["wid"]
    cfg = assign["cfg"]
    faults = assign["faults"]
    mode = assign.get("mode", "deterministic")

    from repro.async_engine.runtime import RoundError
    from repro.core import packing
    from repro.data.synthetic import make_language_specs
    from repro.models import build_model

    model = build_model(cfg.model)
    specs = make_language_specs(cfg.model.vocab_size,
                                n_langs=max(cfg.n_workers, 2),
                                seed=cfg.seed)
    layout = None
    if cfg.outer.compression == "int8":
        init_params = model.init(jax.random.PRNGKey(cfg.seed))
        layout = packing.build_layout(init_params, None)
        del init_params

    clock = {"t0": None, "scale": assign.get("pace_scale", 0.0)}

    def vnow() -> float:
        t0 = clock["t0"]
        if t0 is None:
            return 0.0
        scale = clock["scale"] if clock["scale"] > 0 else 1.0
        return (time.monotonic() - t0) / scale

    tasks: "_queue.Queue" = _queue.Queue()
    waiter = AckWaiter()
    client.on_ack = waiter.put

    def on_task(task, clk):
        clock["t0"], clock["scale"] = clk
        tasks.put(task)

    def on_stop():
        tasks.put(_STOP)
        waiter.close()                   # abandon an in-flight retry loop

    def on_disconnect():
        waiter.close()
        tasks.put(_EOF)

    client.on_task = on_task
    client.on_stop = on_stop
    client.on_disconnect = on_disconnect
    client.start()

    # cross-process observability (docs/observability.md): when the
    # assign payload enables it, this child runs its own SpanTracer and
    # ships incremental span batches + cumulative wire counters to the
    # parent as low-rate ("ctrl", "obs", ...) frames every obs_every
    # rounds and once more (final=True) at graceful stop. Times stay in
    # this process's clock; the parent re-bases them via epoch_offset =
    # child_epoch + clock_offset (estimated at rendezvous).
    obs_on = bool(assign.get("obs"))
    obs_every = max(1, int(assign.get("obs_every", 4)))
    tracer = None
    compute = {"rounds": 0, "compute_s": 0.0}
    if obs_on:
        from repro.obs.spans import SpanTracer
        tracer = SpanTracer()

    def _ship_obs(final: bool = False) -> None:
        if not obs_on:
            return
        payload = {
            "wid": wid, "pid": os.getpid(), "final": bool(final),
            "offset": client.clock_offset,
            "metrics": {**client.wire, "retries": retries["n"],
                        **compute},
            "epoch_offset": tracer._epoch + client.clock_offset,
            "spans": tracer.export_new(),
        }
        try:
            client.send_ctrl("obs", payload)
        except (OSError, TransportClosed):
            pass

    data_tx: Transport = _ChildChannel(client, "data")
    hb_tx: Transport = _ChildChannel(client, "hb")
    if faults is not None:
        data_tx = FaultyTransport(data_tx, faults, stream=0, clock=vnow)
        hb_tx = FaultyTransport(hb_tx, faults, stream=1, clock=vnow)
    retries = {"n": 0}
    sender = ReliableSender(
        data_tx, spec=faults, tracer=tracer,
        on_retry=lambda env, att: retries.__setitem__("n",
                                                      retries["n"] + 1))

    last_gen = {"g": 0}
    hb_stop = threading.Event()
    if faults is not None and faults.liveness_enabled and mode == "free":
        def hb_loop():
            seq = 0
            while not hb_stop.wait(faults.heartbeat_interval):
                seq += 1
                try:
                    hb_tx.send(Envelope(wid=wid, generation=last_gen["g"],
                                        seq=seq, kind=KIND_HEARTBEAT,
                                        payload=None,
                                        sent_time=time.monotonic()),
                               timeout=0.01)
                except TransportTimeout:
                    continue
                except TransportClosed:
                    return
        threading.Thread(target=hb_loop, daemon=True).start()

    seq = 0
    while True:
        task = tasks.get()
        if task is _STOP or task is _EOF:
            break
        last_gen["g"] = task.generation
        t0 = time.monotonic()
        try:
            out: Any = execute_round(task, model=model, cfg=cfg,
                                     specs=specs, layout=layout,
                                     tracer=tracer)
        except Exception as e:                           # noqa: BLE001
            out = RoundError(task.wid, task.generation, task.round_seq,
                             repr(e))
        compute["rounds"] += 1
        compute["compute_s"] += time.monotonic() - t0
        if task.sleep_per_step > 0 and not isinstance(out, RoundError):
            rest = (task.h_steps * task.sleep_per_step
                    - (time.monotonic() - t0))
            if rest > 0:
                time.sleep(rest)
        seq += 1
        if isinstance(out, RoundError):
            env = Envelope(wid=wid, generation=task.generation, seq=seq,
                           kind=KIND_ERROR, payload=out)
        else:
            env = Envelope(wid=wid, generation=task.generation, seq=seq,
                           kind=KIND_RESULT, payload=out,
                           crc=payload_crc(out))
        if not sender.send(env, waiter):
            break                                # channel torn down
        if compute["rounds"] % obs_every == 0:
            _ship_obs()
    hb_stop.set()
    _ship_obs(final=True)
    stats: Dict[str, Dict[str, int]] = {
        "protocol": {"retries": retries["n"]}}
    if isinstance(data_tx, FaultyTransport):
        stats["data"] = dict(data_tx.counters)
        stats["heartbeat"] = dict(hb_tx.counters)
    try:
        client.send_ctrl("stats", stats)
    except (OSError, TransportClosed):
        pass
    client.close()
